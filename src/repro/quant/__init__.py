"""One quantization representation from controller to kernel (DESIGN.md §11).

Before this package the repo carried three divergent quantization
representations: training fake-quant (gates + learnable ranges in
``core/quantizer.py`` / ``core/gates.py``), the serving export's ad-hoc
``{codes, scale, bias, bits}`` dicts, and the serve-time ``QuantContext``
re-deriving bit-widths from gates. They are consolidated here:

  * ``spec.QuantSpec``      — one per-site spec (bit-widths, range, sign) the
                              CGMQ controller emits; a pytree, so it rides
                              through jit / scan exactly like the gates did.
  * ``spec.QuantizedTensor``— one frozen weight: (packed) integer codes plus
                              the affine dequant terms, at a 2/4/8-bit
                              storage class. What the exporter produces and
                              the kernels consume.
  * ``kv``                  — the KV-cache codec: ``KVQuantSpec`` plus pure
                              group-wise quantize/dequantize for the paged
                              serving cache (DESIGN.md §14).
  * ``pack``                — sub-byte bit packing (2/4-bit codes into int8
                              words) with round-trip guarantees.
  * ``export``              — the model-agnostic exporter: capture weights
                              via an export-mode forward, freeze each
                              eligible site, ledger the rest.
  * ``report``              — the bytes/BOPs ledger (``quant_report``): what
                              the served artifact actually costs vs fp32 and
                              vs uniform int8.
"""

from .export import (ActExportEntry, ExportLedger,  # noqa: F401
                     export_act_sites, export_sites)
from .kv import (KVQuantSpec, bytes_per_cached_token,  # noqa: F401
                 dequantize_kv, kv_cache_report, quantize_kv,
                 spec_from_cache)
from .pack import (blockwise_int8_decode, blockwise_int8_encode,  # noqa: F401
                   pack_codes, unpack_codes)
from .report import quant_report  # noqa: F401
from .spec import (ActQuantSpec, QuantSpec,  # noqa: F401
                   QuantizedTensor, specs_from_state)
