"""KV-cache quantization codec (DESIGN.md §14).

Weights already serve through ``QuantSpec``/``QuantizedTensor`` (§11); this
module extends the same cost-certificate philosophy to the serving cache.
A ``KVQuantSpec`` describes how one attention layer's K/V vectors are
stored: ``bits`` (8 or 4) integer codes with **symmetric per-group absmax
scales along head_dim** (group-wise sub-channel granularity — one fp16
scale per ``group_size`` contiguous head elements, so a single outlier
channel cannot blow up the whole vector's grid).

Codec contract (property-tested in ``tests/test_kv_quant.py``):

  * ``scale = max(absmax / qmax, SCALE_FLOOR)`` rounded to fp16. The floor
    is an fp16-normal value, and with fp16 storage the codec is **exactly
    idempotent**: ``quantize(dequantize(x)) == (codes, scale)`` bit-for-bit.
    That is what makes copy-on-write safe — codes+aux can be copied
    verbatim with no dequant->requant round trip, and a resumed (preempted)
    stream re-deriving a block from the same floats lands on the same bits.
  * per-element round-trip error is bounded by ``scale/2`` per group (plus
    fp rounding), the usual symmetric-grid guarantee.
  * ragged tails: ``head_dim`` need not divide ``group_size``; the codec
    pads internally and the tail group's scale covers only real elements.
    (The serving engine additionally *requires* ``head_dim % group_size
    == 0`` so the fused kernel path never sees a ragged group.)

int4 codes are packed two-per-byte along head_dim by reusing ``pack.py``'s
little-endian biased layout (byte ``i`` = codes ``2i`` low nibble, ``2i+1``
high nibble, biased by +8), so the pool array for a 4-bit cache really is
``ceil(head_dim/2)`` bytes per vector.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .pack import pack_codes

# fp16 scales: half the aux bytes of fp32 at KV-cache-irrelevant precision
# loss, and (with the floor below) still an exactly idempotent codec.
SCALE_DTYPE = jnp.float16
# fp16-normal scale floor: keeps all-zero / denormal groups on a fixed
# grid so requantization recovers the identical scale bit-for-bit.
SCALE_FLOOR = 1e-4

_QMAX = {8: 127, 4: 7}
_CODE_DTYPE = {8: jnp.int8, 4: jnp.uint8}  # 4-bit stores packed bytes


@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Storage spec for one attention layer's quantized KV cache.

    A plain frozen dataclass (NOT a pytree): it only parameterizes cache
    *construction*; at decode time the quantized path is recovered
    structurally from the cache pytree itself (``spec_from_cache``), so no
    spec object ever crosses a jit boundary.
    """

    bits: int = 8
    group_size: int = 32
    head_dim: int = 64

    def __post_init__(self):
        if self.bits not in _QMAX:
            raise ValueError(f"KV bits must be one of {sorted(_QMAX)}, "
                             f"got {self.bits}")
        if self.group_size <= 0 or self.head_dim <= 0:
            raise ValueError("group_size and head_dim must be positive")

    @property
    def qmax(self) -> int:
        return _QMAX[self.bits]

    @property
    def num_groups(self) -> int:
        return -(-self.head_dim // self.group_size)

    @property
    def padded_head(self) -> int:
        return self.num_groups * self.group_size

    @property
    def packed_head(self) -> int:
        """Trailing axis of the stored codes array (bytes per vector)."""
        return self.head_dim if self.bits == 8 else -(-self.head_dim // 2)

    @property
    def code_dtype(self):
        return _CODE_DTYPE[self.bits]

    @property
    def scale_dtype(self):
        return SCALE_DTYPE

    def bytes_per_vector(self) -> int:
        """Device bytes for ONE K or V head vector: codes + fp16 scales."""
        return self.packed_head + self.num_groups * jnp.dtype(SCALE_DTYPE).itemsize

    def aux_bytes_per_vector(self) -> int:
        return self.num_groups * jnp.dtype(SCALE_DTYPE).itemsize


def quantize_kv(x: jnp.ndarray, spec: KVQuantSpec):
    """Quantize float K/V vectors ``(..., head_dim)``.

    Returns ``(codes, scale)``: codes ``(..., packed_head)`` in
    ``spec.code_dtype`` (int4 packed two-per-byte), scale ``(..., ng)``
    fp16. Pure and shape-polymorphic over leading dims; safe under jit.
    """
    assert x.shape[-1] == spec.head_dim, (x.shape, spec)
    lead = x.shape[:-1]
    xf = x.astype(jnp.float32)
    pad = spec.padded_head - spec.head_dim
    if pad:
        width = [(0, 0)] * (xf.ndim - 1) + [(0, pad)]
        xf = jnp.pad(xf, width)
    g = xf.reshape(lead + (spec.num_groups, spec.group_size))
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.maximum(absmax / spec.qmax, SCALE_FLOOR).astype(SCALE_DTYPE)
    s32 = scale.astype(jnp.float32)
    codes = jnp.clip(jnp.round(g / s32[..., None]), -spec.qmax, spec.qmax)
    codes = codes.reshape(lead + (spec.padded_head,))[..., :spec.head_dim]
    codes = codes.astype(jnp.int8)
    if spec.bits == 4:
        codes = pack_codes(codes[..., None], 4)[..., 0]
    return codes, scale


def unpack_int4(packed: jnp.ndarray, head_dim: int) -> jnp.ndarray:
    """uint8 ``(..., ceil(hd/2))`` -> centered int32 codes ``(..., hd)``.

    Pure jnp bit ops (no gather/pad), so it lowers inside the Pallas
    kernel as well as in the jnp oracle. Inverse of ``pack.pack_codes``'s
    byte layout: low nibble first, bias +8.
    """
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    c = jnp.stack([lo, hi], axis=-1)
    c = c.reshape(p.shape[:-1] + (p.shape[-1] * 2,))
    return c[..., :head_dim] - 8


def dequant_codes(codes: jnp.ndarray, scale: jnp.ndarray,
                  head_dim: int, group_size: int) -> jnp.ndarray:
    """Centered int codes ``(..., hd)`` + scales ``(..., ng)`` -> fp32.

    The fused-kernel building block: a reshape, a broadcast multiply, a
    reshape back — applied in-register after the block gather. Handles a
    ragged tail via internal zero-padding (never hit on the engine path,
    which asserts divisibility).
    """
    ng = scale.shape[-1]
    padded = ng * group_size
    c = codes.astype(jnp.float32)
    if padded != head_dim:
        width = [(0, 0)] * (c.ndim - 1) + [(0, padded - head_dim)]
        c = jnp.pad(c, width)
    g = c.reshape(c.shape[:-1] + (ng, group_size))
    out = g * scale.astype(jnp.float32)[..., None]
    return out.reshape(c.shape[:-1] + (padded,))[..., :head_dim]


def dequantize_kv(codes: jnp.ndarray, scale: jnp.ndarray,
                  spec: KVQuantSpec) -> jnp.ndarray:
    """Inverse of ``quantize_kv``: fp32 ``(..., head_dim)``."""
    if spec.bits == 4:
        codes = unpack_int4(codes, spec.head_dim)
    return dequant_codes(codes, scale, spec.head_dim, spec.group_size)


def spec_from_cache(entry: dict, head_dim: int) -> KVQuantSpec | None:
    """Recover the spec structurally from a cache/pool entry, or None.

    Quantized entries carry a ``"k_scale"`` leaf next to ``"k"``; bits
    come from the codes dtype (int8 -> 8, packed uint8 -> 4) and the group
    size from the scale trailing axis. Only valid for engine-built caches
    (``head_dim % group_size == 0``); ragged codec uses carry their spec
    explicitly.
    """
    if not isinstance(entry, dict) or "k_scale" not in entry:
        return None
    bits = 8 if entry["k"].dtype == jnp.int8 else 4
    ng = entry["k_scale"].shape[-1]
    assert head_dim % ng == 0, (head_dim, ng)
    return KVQuantSpec(bits=bits, group_size=head_dim // ng,
                       head_dim=head_dim)


# ---------------------------------------------------------------------------
# Footprint accounting (the quant_report KV section, DESIGN.md §14)
# ---------------------------------------------------------------------------


def bytes_per_cached_token(kv_heads: int, head_dim: int, *,
                           spec: KVQuantSpec | None = None,
                           dtype=jnp.bfloat16) -> int:
    """Device bytes ONE attention layer holds per cached token (K + V).

    Quantized: ceil-packed codes plus fp16 per-group scales — the real
    resident bytes, aux included, mirroring the weight ledger's
    convention. Float: ``2 * kv_heads * head_dim * itemsize``.
    """
    if spec is not None:
        assert spec.head_dim == head_dim, (spec, head_dim)
        return 2 * kv_heads * spec.bytes_per_vector()
    return 2 * kv_heads * head_dim * jnp.dtype(dtype).itemsize


def kv_cache_report(kinds: list[str], kv_heads: int, head_dim: int, *,
                    spec: KVQuantSpec | None = None,
                    dtype=jnp.bfloat16, kv_dtype: str = "bf16") -> dict:
    """The ``quant_report`` KV section: bytes/cached-token, per layer.

    ``kinds`` is the model's per-layer mixer list; only attention layers
    ("global"/"local") hold KV blocks. Returns plain JSON with per-layer
    rows and totals against bf16 and fp32 pools of the same geometry.
    """
    attn = [(i, k) for i, k in enumerate(kinds) if k in ("global", "local")]
    per = {f"{i}:{k}": bytes_per_cached_token(kv_heads, head_dim,
                                              spec=spec, dtype=dtype)
           for i, k in attn}
    total = sum(per.values())
    bf16 = len(attn) * bytes_per_cached_token(kv_heads, head_dim,
                                              dtype=jnp.bfloat16)
    fp32 = len(attn) * bytes_per_cached_token(kv_heads, head_dim,
                                              dtype=jnp.float32)
    aux = (2 * kv_heads * spec.aux_bytes_per_vector() * len(attn)
           if spec is not None else 0)
    return {
        "kv_dtype": kv_dtype,
        "bits": spec.bits if spec is not None else None,
        "group_size": spec.group_size if spec is not None else None,
        "kv_heads": kv_heads,
        "head_dim": head_dim,
        "attention_layers": len(attn),
        "per_layer": per,
        "bytes_per_cached_token": total,
        "bytes_aux_per_token": aux,
        "bf16_bytes_per_cached_token": bf16,
        "fp32_bytes_per_cached_token": fp32,
        "vs_bf16": total / max(bf16, 1),
        "vs_fp32": total / max(fp32, 1),
    }
