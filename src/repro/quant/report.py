"""The bytes/BOPs ledger: what the served artifact actually costs
(DESIGN.md §11).

CGMQ certifies a BOP budget at training time; ``quant_report`` verifies the
*deployed* artifact realizes it — per-site device bytes under packed
sub-byte storage, and the model BOP count — against two baselines: fp32 and
a uniform-int8 export (what the old serving path shipped for every model,
regardless of certified 2/4-bit sites). Surfaced by
``benchmarks/run.py --json`` into ``BENCH_serving.json`` and asserted by CI
(bytes/weight strictly below the uniform-int8 baseline on a mixed export).
"""

from __future__ import annotations

from repro.core import bop as bop_lib


def quant_report(ledger, gates: dict, kv: dict | None = None) -> dict:
    """Bytes + BOPs of an export vs fp32 and uniform-int8 baselines.

    ``ledger``: the ``ExportLedger`` from ``quant.export.export_sites``;
    ``gates``: the trained gate pytree (for the certified BOP count);
    ``kv``: optional KV-cache section (``quant.kv.kv_cache_report``, see
    DESIGN.md §14) — bytes per cached token per attention layer, so one
    report covers the whole serving footprint: weights AND cache.

    Returns a plain-JSON dict:
      per_site:  key -> {served, bits, storage_bits?, bytes, weight_count}
      totals:    weight_count, bytes_packed (codes/fp tensors), bytes_aux
                 (fp32 scale+bias — real device residents, counted in every
                 headline number), bytes_device, bytes_uniform_int8,
                 bytes_fp32, bytes_per_weight, uniform_int8_bytes_per_weight,
                 packed_vs_int8 / packed_vs_fp32 ratios, fallback_sites
      bops:      model (certified, from gates), fp32, uniform_int8, rbop

    Baseline convention: the uniform-int8 baseline is what the pre-§11
    serving path shipped — every exported site at 1 byte/code with the SAME
    affine terms (identical scale/bias shapes at any storage class), and
    fallback sites at their fp32 bytes. So packed-vs-int8 isolates exactly
    the storage-class change, with aux bytes on both sides of the ratio.
    """
    per_site = {}
    total_w = 0
    bytes_packed = 0
    bytes_aux = 0
    bytes_int8 = 0
    for key, e in ledger.entries.items():
        n = e["weight_count"]
        total_w += n
        if e["served"] == "int":
            site_bytes = e["codes_bytes"]
            bytes_aux += e["aux_bytes"]
            bytes_int8 += n  # uniform int8: one byte per code, same aux
        else:
            site_bytes = e["fp_bytes"]  # fallback keeps the fp32 tensor
            bytes_int8 += e["fp_bytes"]
        bytes_packed += site_bytes
        per_site[key] = {
            "served": e["served"],
            "bits": e["bits"],
            "storage_bits": e.get("storage_bits"),
            "reason": e.get("reason"),
            "bytes": site_bytes + e.get("aux_bytes", 0),
            "weight_count": n,
        }
    sites = ledger.sites
    bops_fp32 = bop_lib.fp32_bop(sites)
    bops_int8 = sum(s.macs_per_token * s.stack * 8.0 * 8.0
                    for s in sites.values() if s.act_quantized)
    bops_model = float(bop_lib.model_bop(sites, gates)) if gates else 0.0
    bytes_device = bytes_packed + bytes_aux
    bytes_uniform_int8 = bytes_int8 + bytes_aux
    totals = {
        "weight_count": total_w,
        "bytes_packed": bytes_packed,
        "bytes_aux": bytes_aux,
        "bytes_device": bytes_device,
        "bytes_uniform_int8": bytes_uniform_int8,
        "bytes_fp32": 4 * total_w,
        "bytes_per_weight": bytes_device / max(total_w, 1),
        "uniform_int8_bytes_per_weight": bytes_uniform_int8 / max(total_w, 1),
        "packed_vs_int8": bytes_device / max(bytes_uniform_int8, 1),
        "packed_vs_fp32": bytes_device / max(4 * total_w, 1),
        "fallback_sites": len(ledger.fallbacks()),
        "exported_sites": len(ledger.exported()),
    }
    # Activation (".in") coverage (DESIGN.md §16): which GEMMs run integer
    # MACs vs float inputs. ``covered == total`` means every quantized-output
    # matmul serves int8×int8 — the condition CI's int-serving gate asserts.
    act_entries = getattr(ledger, "act_entries", None) or {}
    acts = {
        "total": sum(1 for e in act_entries.values() if e.served != "excluded"),
        "covered": len(ledger.act_exported()) if act_entries else 0,
        "fallback_sites": sorted(k for k, e in act_entries.items()
                                 if e.served == "fake_quant"),
        "bits": {k: e.bits for k, e in act_entries.items()
                 if e.served == "int"},
    }
    out = {
        "per_site": per_site,
        "totals": totals,
        "acts": acts,
        "bops": {
            "model": bops_model,
            "fp32": bops_fp32,
            "uniform_int8": bops_int8,
            "rbop": bops_model / bops_fp32 if bops_fp32 else 0.0,
        },
    }
    if kv is not None:
        out["kv_cache"] = kv
    return out
