"""QuantSpec / QuantizedTensor: the single quantization representation
(DESIGN.md §11).

``QuantSpec`` is what the CGMQ controller emits for one site: the bit-width
array implied by its (clamped) gates, the learned range, and the sign
convention — as a registered pytree, so specs thread through jit and
``lax.scan`` exactly like the raw gate arrays they replace. Serve-mode
``QuantContext`` consumes specs directly (no gates at inference time), the
exporter freezes weights against them, and the kernels consume the result.

``QuantizedTensor`` is one frozen weight: integer codes — bit-packed for
2/4-bit storage classes — plus the affine dequant terms, with the storage
class and logical K as static metadata. ``dequantize()`` lands on the same
grid as ``core.quantizer.quantize`` (via ``quantize_to_int``; values agree
to fp32 rounding), and packing is lossless: the packed path's unpacked
codes equal the int8 layout bit-for-bit, so packed serving is bitwise
identical to the int8 oracle path.

The gate→bits→storage-class logic that used to be copy-pasted between
``serving/engine.py`` and the quantizer lives in ``QuantSpec.from_gate`` /
``storage_bits`` — every call site imports it from here.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gates import gate_to_bits
from repro.core.quantizer import affine_grid, quantize_to_int

from .pack import pack_codes, unpack_codes

# Integer storage classes the serving path can carry (bits -> packed words).
STORAGE_CLASSES = (2, 4, 8)
SERVE_MIN_BITS = 2
SERVE_MAX_BITS = 8


def storage_class_for(max_bits: int) -> int | None:
    """Smallest 2/4/8-bit storage class holding ``max_bits``-bit codes.

    ``None`` when the site exceeds the serving GEMM's 8-bit ceiling — the
    canonical clamp-to-[2, 8] decision, deduplicated here from the old
    ``serving/engine.py`` / quantizer copies.
    """
    max_bits = max(int(max_bits), SERVE_MIN_BITS)
    for b in STORAGE_CLASSES:
        if max_bits <= b:
            return b
    return None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantSpec:
    """Per-site quantization spec: bits + range + sign, as one pytree.

    ``bits``/``beta`` are gate-group shaped (per-tensor scalar, per-channel
    ``(N,)``, per-weight full shape; leading stack axis for scan-stacked
    sites) and broadcast against the tensor exactly like the gate arrays
    they were derived from. ``signed`` is static (python bool).
    """

    bits: jnp.ndarray
    beta: jnp.ndarray
    signed: bool

    def tree_flatten(self):
        return (self.bits, self.beta), (self.signed,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bits, beta = children
        return cls(bits=bits, beta=beta, signed=aux[0])

    @classmethod
    def from_gate(cls, gate, beta, signed: bool) -> "QuantSpec":
        """Freeze a trained gate into a spec: ``bits = T(max(g, 0.5))``.

        This is THE gate→bits entry point for deployment — the controller's
        Eq. 4 transform with the no-pruning clamp, shared by the model
        exporter, the single-tensor export helper and the serve-time
        activation quantizers.
        """
        return cls(bits=gate_to_bits(jnp.asarray(gate)),
                   beta=jnp.asarray(beta, jnp.float32), signed=bool(signed))

    # ---- host-side (concrete) queries ------------------------------------
    def max_bits(self) -> int:
        """Largest bit-width in the spec (host sync; export-time only)."""
        return int(np.asarray(jax.device_get(self.bits)).max())

    def storage_bits(self) -> int | None:
        """The site's integer storage class, or None (> 8 bits: fp fallback).
        """
        return storage_class_for(self.max_bits())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ActQuantSpec:
    """Per-TENSOR affine activation spec: the ``.in`` sites (DESIGN.md §16).

    The activation variant of ``QuantSpec``: ``bits`` and ``signed`` are
    STATIC (python scalars, pytree aux data), so the integer-GEMM dispatch
    and the int8 code dtype specialize per site under jit/scan; ``beta`` is
    the EMA-calibrated range (a traced leaf, with a leading stack axis for
    scan-stacked sites, sliced per layer exactly like weight specs). The
    serve path quantizes the incoming activation tile on the fly against
    this grid and hands int8 codes to the int8×int8 kernel.
    """

    bits: int
    beta: jnp.ndarray
    signed: bool = True

    def tree_flatten(self):
        return (self.beta,), (self.bits, self.signed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(bits=aux[0], beta=children[0], signed=aux[1])

    @classmethod
    def from_gate(cls, gate, beta, signed: bool) -> "ActQuantSpec":
        """Freeze a concrete activation gate (host sync, export-time only)."""
        bits = int(np.asarray(
            jax.device_get(gate_to_bits(jnp.asarray(gate)))).max())
        return cls(bits=bits, beta=jnp.asarray(beta, jnp.float32),
                   signed=bool(signed))

    def affine(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """``(scale, bias)`` of the stored grid: dequant = codes*scale+bias.
        """
        return affine_grid(self.bits, self.beta, self.signed)

    def zero_point(self) -> jnp.ndarray:
        """Integer zero-point ``z`` with ``x ~ scale * (codes - z)``."""
        scale, bias = self.affine()
        return -bias / scale


def specs_from_state(gates: dict, betas: dict, signed: dict) -> dict:
    """Controller state -> spec pytree: one ``QuantSpec`` per gated key.

    ``gates``/``betas``/``signed`` are the ``quant_state`` maps produced by
    training (``.w`` and ``.a`` keys). This is what a serve-mode
    ``QuantContext`` carries instead of raw gates + ranges.
    """
    return {k: QuantSpec.from_gate(g, betas[k], signed[k])
            for k, g in gates.items()}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """One exported weight: (packed) integer codes + affine dequant terms.

    ``codes`` is uint8 bit-packed ``(..., ceil(K/per), N)`` for 2/4-bit
    storage, int8 ``(..., K, N)`` for the 8-bit class (the unpacked oracle
    layout). ``scale``/``bias`` broadcast to the unpacked code shape;
    ``codes * scale + bias`` equals the fake-quant forward exactly.
    ``storage_bits`` and the logical fan-in ``k`` are static, so jit/scan
    specialization dispatches the right kernel per site. ``colsum`` is the
    precomputed ``(..., N)`` int32 K-axis sum of the (unpacked) codes — the
    zero-point correction term of the integer GEMM (DESIGN.md §16), frozen
    at export so decode never recomputes a GEMM-sized reduction per tick.
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    bias: jnp.ndarray
    storage_bits: int
    k: int
    colsum: jnp.ndarray | None = None

    def tree_flatten(self):
        return ((self.codes, self.scale, self.bias, self.colsum),
                (self.storage_bits, self.k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale, bias, colsum = children
        return cls(codes=codes, scale=scale, bias=bias,
                   storage_bits=aux[0], k=aux[1], colsum=colsum)

    @property
    def packed(self) -> bool:
        return self.storage_bits < 8

    @classmethod
    def from_float(cls, w, bits, beta, signed: bool, *,
                   storage_bits: int, pack: bool = True) -> "QuantizedTensor":
        """Freeze ``w`` on the ``bits`` grid into ``storage_bits`` storage.

        ``bits``/``beta`` broadcast against ``w`` (mixed per-channel widths
        ride in scale/bias; codes of a ``b <= storage_bits`` channel always
        fit the storage class). ``pack=False`` keeps the int8 oracle layout
        regardless of storage class — the packed path's equivalence
        reference.
        """
        codes, scale, bias = quantize_to_int(w, bits, beta, signed)
        k = int(w.shape[-2])
        colsum = jnp.sum(codes.astype(jnp.int32), axis=-2)
        if pack and storage_bits < 8:
            return cls(codes=pack_codes(codes, storage_bits), scale=scale,
                       bias=bias, storage_bits=storage_bits, k=k,
                       colsum=colsum)
        return cls(codes=codes.astype(jnp.int8), scale=scale, bias=bias,
                   storage_bits=8, k=k, colsum=colsum)

    def int8_codes(self) -> jnp.ndarray:
        """Unpacked centered codes ``(..., K, N)`` int8 (oracle layout)."""
        if not self.packed:
            return self.codes
        return unpack_codes(self.codes, self.storage_bits, self.k)

    def code_colsum(self) -> jnp.ndarray:
        """``(..., N)`` int32 K-sum of the unpacked codes (§16 correction).

        Uses the exported leaf when present; falls back to reducing the
        unpacked codes for tensors frozen before the leaf existed.
        """
        if self.colsum is not None:
            return self.colsum
        return jnp.sum(self.int8_codes().astype(jnp.int32), axis=-2)

    def dequantize(self) -> jnp.ndarray:
        """fp32 weight on the exact fake-quant grid."""
        return self.int8_codes().astype(jnp.float32) * self.scale + self.bias

    # ---- accounting (static; no device sync) ------------------------------
    def codes_bytes(self) -> int:
        """Device bytes of the code array (1 byte per stored word)."""
        n = 1
        for d in self.codes.shape:
            n *= int(d)
        return n

    def aux_bytes(self) -> int:
        """Device bytes of the affine terms (fp32 scale + bias)."""
        n = 0
        for a in (self.scale, self.bias):
            m = 1
            for d in jnp.shape(a):
                m *= int(d)
            n += 4 * m
        return n

    def weight_count(self) -> int:
        """Logical weight element count (unpacked: stack x K x N)."""
        n = 1
        for d in self.codes.shape[:-2]:
            n *= int(d)
        return n * self.k * int(self.codes.shape[-1])
