"""Sub-byte bit packing for quantized weight codes (DESIGN.md §11).

2- and 4-bit codes are packed along the K (fan-in) axis into int8-sized
words so the device array for a b-bit site really is ``ceil(K * b / 8)``
bytes per output channel — the memory the CGMQ controller certified, not a
byte per code.

Layout (consumed by the packed ``quant_matmul`` kernel): byte ``i`` of a
column holds codes ``i*per + j`` for ``j in 0..per-1`` (``per = 8 // bits``),
code ``j`` in bits ``[j*b, (j+1)*b)`` — little-endian within the byte, K
consecutive within a word. Codes are stored *biased* (centered code +
``2^(b-1)``, i.e. unsigned), so packing needs no sign handling; unpacking
subtracts the offset back. A K tail shorter than ``per`` is zero-padded;
``unpack_codes`` slices it off, and the matmul kernels instead mask the
matching activation columns (padding codes only ever multiply a zeroed x).

Round-trip guarantee: ``unpack_codes(pack_codes(c, b), b, K) == c`` for any
int codes in ``[-2^(b-1), 2^(b-1)-1]``, any K (odd / ragged included), any
leading batch/stack dims — property-tested in ``tests/test_quant_spec.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

# Codes per packed byte for each sub-byte storage class.
CODES_PER_BYTE = {2: 4, 4: 2, 8: 1}


def packed_rows(k: int, bits: int) -> int:
    """Packed K-axis length: ``ceil(k / (8 // bits))``."""
    per = CODES_PER_BYTE[bits]
    return -(-k // per)


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack centered int codes (..., K, N) into uint8 (..., ceil(K/per), N).

    ``bits`` in {2, 4}; 8-bit codes have nothing to pack (use them as-is).
    Values must lie in the signed b-bit range ``[-2^(b-1), 2^(b-1)-1]``.
    """
    assert bits in (2, 4), bits
    per = CODES_PER_BYTE[bits]
    offset = 1 << (bits - 1)
    k = codes.shape[-2]
    pad = (-k) % per
    biased = (codes.astype(jnp.int32) + offset).astype(jnp.uint8)
    if pad:
        width = [(0, 0)] * codes.ndim
        width[-2] = (0, pad)
        biased = jnp.pad(biased, width)  # tail values never unpacked/attended
    kp = (k + pad) // per
    grouped = biased.reshape(biased.shape[:-2] + (kp, per, biased.shape[-1]))
    out = jnp.zeros(grouped.shape[:-2] + grouped.shape[-1:], jnp.uint8)
    for j in range(per):
        out = out | (grouped[..., j, :] << (j * bits))
    return out


def unpack_codes(packed: jnp.ndarray, bits: int, k: int) -> jnp.ndarray:
    """Inverse of ``pack_codes``: uint8 (..., Kp, N) -> int8 (..., k, N)."""
    assert bits in (2, 4), bits
    per = CODES_PER_BYTE[bits]
    offset = 1 << (bits - 1)
    mask = (1 << bits) - 1
    p = packed.astype(jnp.int32)
    cols = [(p >> (j * bits)) & mask for j in range(per)]
    stacked = jnp.stack(cols, axis=-2)  # (..., Kp, per, N)
    flat = stacked.reshape(stacked.shape[:-3]
                           + (stacked.shape[-3] * per, stacked.shape[-1]))
    sl = [slice(None)] * flat.ndim
    sl[-2] = slice(0, k)
    return (flat[tuple(sl)] - offset).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Blockwise symmetric int8 (the gradient-compression wire format)
# ---------------------------------------------------------------------------


def blockwise_int8_encode(x: jnp.ndarray, block: int):
    """Flatten ``x`` and absmax-quantize int8 per ``block`` elements.

    Returns ``(codes (nblk, block) int8, scale (nblk, 1) fp32)`` — the
    symmetric per-block grid used by the inter-pod gradient compression
    (``optim/compression.py``); the same affine-grid family as the weight
    export, kept here so every integer wire/storage format lives in one
    package.
    """
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                        1e-12) / 127.0
    codes = jnp.round(blocks / scale).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def blockwise_int8_decode(codes: jnp.ndarray, scale: jnp.ndarray,
                          shape: tuple[int, ...]) -> jnp.ndarray:
    """Inverse of ``blockwise_int8_encode`` (crops the block padding)."""
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)
