"""Model-agnostic quantized-weight export (DESIGN.md §11).

``export_sites`` turns the weights captured by an export-mode forward
(``QuantContext(mode="export")`` records every site's full weight tensor
under its canonical name) into ``QuantizedTensor``s at the learned per-site
bit-widths, and ledgers EVERY site — exported or not. The transformer
wrapper is ``serving.engine.export_int_model``; LeNet exports through
``models.lenet.export_qweights``; both share this code path, so the old
per-model ad-hoc export dicts are gone.

The ledger is the fix for the silent >8-bit fallback: a site the exporter
rejects (trained above 8 bits, per-weight granularity, non-2-D weight) used
to vanish from the report and silently serve fake-quant — a "quantized"
model could ship fp32 sites with no trace. Now every rejection is recorded
with its reason, and an export with >8-bit rejections warns once.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gates import gate_to_bits

from .spec import QuantizedTensor, storage_class_for


@dataclasses.dataclass
class ExportLedger:
    """Per-site record of what the export did (one entry per ``.w`` key).

    Entry fields: ``served`` ("int" | "fake_quant"), ``bits`` (max learned
    bit-width; None for ungated sites), ``storage_bits`` (2/4/8, exported
    sites only), ``reason`` (fallback sites only: "bits>8" | "granularity"
    | "shape" | "ungated"), ``weight_count``, ``codes_bytes`` /
    ``aux_bytes`` (exported) or ``fp_bytes`` (fallback: the fp32 tensor
    keeps living on device).
    """

    entries: dict[str, dict] = dataclasses.field(default_factory=dict)
    sites: dict[str, Any] = dataclasses.field(default_factory=dict)
    act_entries: dict[str, "ActExportEntry"] = dataclasses.field(
        default_factory=dict)

    def exported(self) -> dict[str, dict]:
        return {k: e for k, e in self.entries.items() if e["served"] == "int"}

    def fallbacks(self) -> dict[str, dict]:
        return {k: e for k, e in self.entries.items()
                if e["served"] == "fake_quant"}

    def max_bits(self) -> dict[str, int]:
        """Site -> max learned bit-width (the old ``report`` dict, exported
        sites only — kept for engine/benchmark summaries)."""
        return {k: e["bits"] for k, e in self.exported().items()}

    # ---- activation (".in") sites (DESIGN.md §16) -------------------------
    def act_exported(self) -> dict[str, "ActExportEntry"]:
        return {k: e for k, e in self.act_entries.items()
                if e.served == "int"}

    def act_fallbacks(self) -> dict[str, "ActExportEntry"]:
        return {k: e for k, e in self.act_entries.items()
                if e.served != "int"}


@dataclasses.dataclass
class ActExportEntry:
    """One activation (``.in``) site in the ledger (DESIGN.md §16).

    ``served`` is "int" (the site's GEMM runs int8×int8 against this
    per-tensor affine grid), "fake_quant" (no calibrated spec — the GEMM
    input stays float, visible exactly like weight fp fallbacks), or
    "excluded" (the site's activation is unquantized by design, e.g. the
    LM head's logits input). ``scale``/``zero_point`` carry a leading stack
    axis for scan-stacked sites.
    """

    served: str
    bits: int | None = None
    scale: Any = None
    zero_point: Any = None
    reason: str | None = None


def export_act_sites(act_specs: dict, sites: dict, *,
                     warn: bool = True) -> dict[str, "ActExportEntry"]:
    """Ledger every matmul site's input-activation quantization state.

    ``act_specs`` maps "<site>.in" -> ``ActQuantSpec``; ``sites`` is the
    collected ``SiteInfo`` map. Every site gets an entry — served integer
    grids export their scale/zero-point alongside the packed weights, and
    sites WITHOUT a spec stay visible as fp fallbacks instead of silently
    serving float GEMMs under an "integer" banner.
    """
    entries: dict[str, ActExportEntry] = {}
    for name, site in sites.items():
        key = name + ".in"
        spec = act_specs.get(key)
        if spec is not None:
            scale, _ = spec.affine()
            entries[key] = ActExportEntry(
                served="int", bits=int(spec.bits), scale=scale,
                zero_point=spec.zero_point())
        elif getattr(site, "act_quantized", True):
            entries[key] = ActExportEntry(served="fake_quant",
                                          reason="no_act_spec")
        else:
            entries[key] = ActExportEntry(served="excluded",
                                          reason="act_unquantized_site")
    missing = sorted(k for k, e in entries.items()
                     if e.served == "fake_quant")
    if warn and act_specs and missing:
        warnings.warn(
            f"act export: {len(missing)} matmul site(s) have no calibrated "
            f"activation spec and will serve float GEMM inputs: "
            f"{missing[:4]}{'...' if len(missing) > 4 else ''}",
            UserWarning, stacklevel=2)
    return entries


def _expand_group(a, w, stacked: bool):
    """Broadcast a gate-group array against weight ``w``.

    Group shapes are () (per-tensor) or (N,) (per-channel), with a leading
    stack axis when ``stacked``; channels align with w's LAST axis.
    """
    a = jnp.asarray(a, jnp.float32)
    if stacked:
        core = a.shape[1:]
        return a.reshape((a.shape[0],) + (1,) * (w.ndim - 1 - len(core)) + core)
    if a.ndim == 0:
        return a
    return a.reshape((1,) * (w.ndim - a.ndim) + a.shape)


def _weight_count(w) -> int:
    n = 1
    for d in w.shape:
        n *= int(d)
    return n


def export_sites(qc, gates: dict, betas: dict, signed: dict, *,
                 pack: bool = True, warn: bool = True):
    """Freeze every eligible captured site; ledger all of them.

    ``qc`` is an export-mode ``QuantContext`` that has been run through a
    forward (``qc.weight_stats`` holds the tensors, ``qc.sites`` the
    metadata). Eligible: per-tensor / per-channel gates over a 2-D weight
    (scan-stacked allowed), learned max bit-width <= 8. The int grid
    reproduces the fake-quant grid EXACTLY (mixed per-channel widths ride in
    scale/bias; codes are stored at the site's 2/4/8 storage class, packed
    sub-byte when ``pack``). ``pack=False`` forces the unpacked int8 oracle
    layout — the packed path's bit-for-bit reference.

    Returns ``(qweights, ledger)``: ``qweights`` maps "<site>.w" ->
    ``QuantizedTensor`` (absent for fallback sites, which serve fake-quant
    at their learned bits); ``ledger`` is the complete ``ExportLedger``.
    """
    qweights: dict[str, QuantizedTensor] = {}
    ledger = ExportLedger(sites=dict(qc.sites))
    for key, w in qc.weight_stats.items():
        site = qc.sites.get(key[: -len(".w")])
        if site is None:
            continue
        w = jnp.asarray(w)
        if key not in gates:
            # A captured site the quant_state knows nothing about (config /
            # checkpoint mismatch): it will serve full precision — record
            # it, don't let it vanish.
            ledger.entries[key] = {
                "served": "fake_quant", "bits": None, "reason": "ungated",
                "weight_count": _weight_count(w),
                "fp_bytes": 4 * _weight_count(w)}
            continue
        g = jnp.asarray(gates[key])
        bits = gate_to_bits(g)
        max_bits = int(np.asarray(jax.device_get(bits)).max())
        entry = {"served": "fake_quant", "bits": max_bits,
                 "weight_count": _weight_count(w), "fp_bytes": 4 * _weight_count(w)}
        ledger.entries[key] = entry
        if len(site.weight_shape) != 2:
            entry["reason"] = "shape"
            continue
        stacked = w.ndim == len(site.weight_shape) + 1
        core = g.shape[1:] if stacked else g.shape
        if core not in ((), (w.shape[-1],)):
            entry["reason"] = "granularity"  # per-weight: no per-element scale
            continue
        if stacked and (g.ndim == 0 or g.shape[0] != w.shape[0]):
            entry["reason"] = "granularity"
            continue
        storage = storage_class_for(max_bits)
        if storage is None:
            entry["reason"] = "bits>8"  # int storage can't carry the grid
            continue
        qt = QuantizedTensor.from_float(
            w, _expand_group(bits, w, stacked),
            _expand_group(jnp.asarray(betas[key]), w, stacked),
            bool(signed[key]), storage_bits=storage, pack=pack)
        qweights[key] = qt
        entry.update(served="int", storage_bits=qt.storage_bits,
                     codes_bytes=qt.codes_bytes(), aux_bytes=qt.aux_bytes())
        del entry["fp_bytes"]
    high = [k for k, e in ledger.entries.items()
            if e.get("reason") in ("bits>8", "ungated")]
    if warn and high:
        warnings.warn(
            f"export: {len(high)} site(s) (trained above 8 bits, or absent "
            f"from the quant state) keep full-precision weights on device: "
            f"{sorted(high)[:4]}{'...' if len(high) > 4 else ''} — the "
            f"served model is NOT fully integer-quantized",
            UserWarning, stacklevel=2)
    return qweights, ledger
