"""LeNet-5 (classic LeCun variant) with CGMQ quantization sites.

The paper's experimental network (§4.1, "LeNet-5 as is done by Liu et al.").
Conv/FC weights and all hidden activations carry quantization sites; the
head's output stays floating point and the input is quantized to a fixed 8
bits (paper §4.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sites import QuantContext

# (name, kind, params) — the classic 28x28 LeNet-5.
#   conv1: 1->6 5x5 same, relu, maxpool2   -> 14x14x6
#   conv2: 6->16 5x5 valid, relu, maxpool2 -> 5x5x16
#   fc1: 400->120 relu; fc2: 120->84 relu; fc3: 84->10 (fp head)


def init_params(key) -> dict:
    k = jax.random.split(key, 5)

    def he(k, shape, fan_in):
        return (jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)).astype(
            jnp.float32
        )

    return {
        "conv1_w": he(k[0], (5, 5, 1, 6), 25),
        "conv1_b": jnp.zeros((6,)),
        "conv2_w": he(k[1], (5, 5, 6, 16), 150),
        "conv2_b": jnp.zeros((16,)),
        "fc1_w": he(k[2], (400, 120), 400),
        "fc1_b": jnp.zeros((120,)),
        "fc2_w": he(k[3], (120, 84), 120),
        "fc2_b": jnp.zeros((84,)),
        "fc3_w": he(k[4], (84, 10), 84),
        "fc3_b": jnp.zeros((10,)),
    }


def _conv(x, w, padding):
    """im2col conv: window gather + ONE matmul (exact vs lax.conv).

    Matmul form keeps the backward pass gather/GEMM-only, which (a) is
    MXU-shaped on TPU like every other site in this repo and (b) stays fast
    inside ``lax.scan`` epochs — XLA:CPU compiles convolutions in a While
    body ~2x slower than at top level, which made scan epochs lose to the
    python loop before this change (see DESIGN.md §9).
    """
    kh, kw, cin, cout = w.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    b, hh, ww, _ = x.shape
    oh, ow = hh - kh + 1, ww - kw + 1
    ii = jnp.arange(oh)[:, None] + jnp.arange(kh)[None, :]
    jj = jnp.arange(ow)[:, None] + jnp.arange(kw)[None, :]
    pats = x[:, ii][:, :, :, jj]               # (B, OH, KH, OW, KW, C)
    pats = pats.transpose(0, 1, 3, 2, 4, 5)    # (B, OH, OW, KH, KW, C)
    out = pats.reshape(b * oh * ow, kh * kw * cin) @ w.reshape(kh * kw * cin,
                                                               cout)
    return out.reshape(b, oh, ow, cout)


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(qc: QuantContext, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 28, 28, 1) normalized images -> (B, 10) logits."""
    x = qc.input(x)

    w = qc.weight("conv1", params["conv1_w"])
    qc.register_matmul("conv1", params["conv1_w"].shape, fan_in=5 * 5 * 1,
                       out_features=6, positions=28 * 28)
    h = _conv(x, w, "SAME") + params["conv1_b"]
    h = jax.nn.relu(h)
    h = qc.act("conv1", h)
    h = _maxpool2(h)  # 14x14x6

    w = qc.weight("conv2", params["conv2_w"])
    qc.register_matmul("conv2", params["conv2_w"].shape, fan_in=5 * 5 * 6,
                       out_features=16, positions=10 * 10)
    h = _conv(h, w, "VALID") + params["conv2_b"]
    h = jax.nn.relu(h)
    h = qc.act("conv2", h)
    h = _maxpool2(h)  # 5x5x16

    h = h.reshape(h.shape[0], -1)  # 400

    w = qc.weight("fc1", params["fc1_w"])
    qc.register_matmul("fc1", params["fc1_w"].shape, fan_in=400, out_features=120)
    h = jax.nn.relu(h @ w + params["fc1_b"])
    h = qc.act("fc1", h)

    w = qc.weight("fc2", params["fc2_w"])
    qc.register_matmul("fc2", params["fc2_w"].shape, fan_in=120, out_features=84)
    h = jax.nn.relu(h @ w + params["fc2_b"])
    h = qc.act("fc2", h)

    w = qc.weight("fc3", params["fc3_w"])
    qc.register_matmul("fc3", params["fc3_w"].shape, fan_in=84, out_features=10,
                       act_quantized=False)  # fp head (paper §4.2)
    return h @ w + params["fc3_b"]


WEIGHT_LOOKUP = {
    "conv1": "conv1_w",
    "conv2": "conv2_w",
    "fc1": "fc1_w",
    "fc2": "fc2_w",
    "fc3": "fc3_w",
}


def weight_lookup(params):
    return lambda name: params.get(WEIGHT_LOOKUP.get(name, ""), None)


def export_qweights(params, gates, betas, signed, *, pack: bool = True):
    """Freeze a CGMQ-trained LeNet for deployment (DESIGN.md §11).

    Same path as the transformer exporter: one export-mode forward captures
    every site's weight under its canonical name, then
    ``quant.export.export_sites`` packs the eligible ones (the fc matmuls;
    the 4-D conv kernels are ledgered as shape fallbacks and serve
    fake-quant). Serve with ``QuantContext(mode="serve",
    specs=quant.specs_from_state(gates, betas, signed), qweights=...)`` —
    ``qc.weight`` dequantizes the frozen codes for the explicit ``h @ w``
    matmuls, so the classification path serves the same artifact format as
    the LLM engine.
    """
    from repro.quant import export_sites

    qc = QuantContext(mode="export")
    forward(qc, params, jnp.zeros((1, 28, 28, 1), jnp.float32))
    return export_sites(qc, gates, betas, signed, pack=pack)
