"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

TPU adaptation (DESIGN.md §3): the chunked SSD algorithm maps naturally onto
MXU matmuls — quadratic attention-like einsums within chunks, a short scan
across chunk states. We implement:

  * ``ssd_chunked``      — training/prefill forward (chunked dual form)
  * ``ssd_decode_step``  — single-token recurrence for serving
  * ``ssd_reference``    — naive O(L) recurrence oracle (tests)

The carried SSM state and the decay chain stay fp32 (quantizing carried state
feeds error back through time; see DESIGN.md §5); the in/out projections and
their activations are CGMQ sites.

Layout: heads H = d_inner / head_dim P, single B/C group (G=1), state size N.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext

from .layers import COMPUTE_DTYPE, qmatmul, rms_norm


def init_ssd(key, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 3)
    proj_out = 2 * din + 2 * n + h  # [z, x, B, C, dt]

    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    return {
        "in_proj": w(ks[0], (d, proj_out), d),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_kernel, din + 2 * n)),
        "conv_b": jnp.zeros((din + 2 * n,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, h))),
        "gate_norm": jnp.zeros((din,)),
        "out_proj": w(ks[2], (din, d), din),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv along time. xbc: (B, L, C). Returns (y, state).

    ``conv_state``: (B, k-1, C) trailing context (decode path).
    """
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, L+k-1, C)
    y = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(k)
    ) + conv_b[None, None, :]
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(y), new_state


def ssd_chunked(
    qc: QuantContext, p, xin, cfg: ModelConfig, *, conv_state=None,
    ssm_state=None, plan=None,
):
    """Full-sequence SSD forward. xin: (B, L, d). Returns (y, (conv_st, ssm_st)).

    L must be a multiple of ``cfg.ssm_chunk`` (pad upstream if needed).
    """
    b, l, _ = xin.shape
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cs = min(cfg.ssm_chunk, l)
    assert l % cs == 0, (l, cs)
    nc = l // cs

    zxbcdt = qmatmul(qc, "ssm_in", xin, p["in_proj"])
    zxbcdt = qc.act("ssm_in", zxbcdt)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x = xbc[..., :din]
    bmat = xbc[..., din : din + n]          # (B, L, N)
    cmat = xbc[..., din + n :]              # (B, L, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B, L, H)
    a = -jnp.exp(p["A_log"]).astype(jnp.float32)                     # (H,)
    da = dt * a                                                      # (B, L, H)

    xh = x.reshape(b, l, h, pdim).astype(jnp.float32)
    # chunk views
    xc = xh.reshape(b, nc, cs, h, pdim)
    bc = bmat.reshape(b, nc, cs, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, cs, n).astype(jnp.float32)
    dac = da.reshape(b, nc, cs, h)
    dtc = dt.reshape(b, nc, cs, h)

    # cumulative decay within chunks
    seg = jnp.cumsum(dac, axis=2)                                    # (B,nc,cs,H)
    # intra-chunk (quadratic) term: decay(i<-j) = exp(seg_i - seg_j)
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]              # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((cs, cs), bool))
    # mask BEFORE exp: exp of the (positive) acausal region would overflow and
    # poison gradients through jnp.where.
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    decay = jnp.exp(rel)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)                   # (B,nc,i,j)
    yd = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                    scores, decay, dtc, xc)                          # diag block

    # chunk-final states: S_c = sum_j exp(seg_last - seg_j) dt_j B_j x_j^T
    last = seg[:, :, -1:, :]                                         # (B,nc,1,H)
    w_end = jnp.exp(last - seg) * dtc                                # (B,nc,cs,H)
    chunk_states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_end, bc, xc)

    # inter-chunk recurrence over nc chunk states (small sequential scan)
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))                      # (B,nc,H)

    def scan_fn(carry, inp):
        s_prev = carry
        s_c, dec = inp
        s_new = s_prev * dec[..., None, None] + s_c
        return s_new, s_prev  # emit the state ENTERING this chunk

    init = (
        jnp.zeros((b, h, n, pdim), jnp.float32)
        if ssm_state is None
        else ssm_state.astype(jnp.float32)
    )
    s_final, s_in = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)                                  # (B,nc,H,N,P)

    # inter-chunk contribution: y_i += C_i exp(seg_i) . S_in
    yo = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, jnp.exp(seg), s_in)

    y = (yd + yo).reshape(b, l, h, pdim)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, l, din)
    # gated RMSNorm (mamba2): norm(y * silu(z)); stays fp (recurrent output,
    # DESIGN.md §5) — the out-projection's OWN output is the quant site.
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["gate_norm"],
                 cfg.norm_eps)
    y = y.astype(COMPUTE_DTYPE)
    out = qmatmul(qc, "ssm_out", y, p["out_proj"])
    out = qc.act("ssm_out", out)
    return out, (new_conv, s_final)


def ssd_decode_step(
    qc: QuantContext, p, xin, conv_state, ssm_state, cfg: ModelConfig, *, plan=None
):
    """One-token SSD step. xin: (B, 1, d). Returns (y, (conv_st, ssm_st))."""
    b = xin.shape[0]
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = qmatmul(qc, "ssm_in", xin, p["in_proj"])
    zxbcdt = qc.act("ssm_in", zxbcdt)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x = xbc[..., :din]
    bvec = xbc[..., din : din + n].astype(jnp.float32)[:, 0]     # (B, N)
    cvec = xbc[..., din + n :].astype(jnp.float32)[:, 0]         # (B, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B, H)
    a = -jnp.exp(p["A_log"]).astype(jnp.float32)
    decay = jnp.exp(dt * a)                                       # (B, H)

    xh = x.reshape(b, h, pdim).astype(jnp.float32)
    s = ssm_state.astype(jnp.float32)                             # (B,H,N,P)
    s = s * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bvec, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec, s)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["gate_norm"],
                 cfg.norm_eps)
    y = y.astype(COMPUTE_DTYPE)
    out = qmatmul(qc, "ssm_out", y, p["out_proj"])
    out = qc.act("ssm_out", out)
    return out, (new_conv, s)


def ssd_reference(p, xin, cfg: ModelConfig):
    """Naive per-step recurrence oracle (fp32, no quantization)."""
    from repro.core.sites import QuantContext

    qc = QuantContext(mode="off")
    b, l, _ = xin.shape
    conv_state = jnp.zeros((b, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.ssm_state))
    ssm_state = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim))
    ys = []
    for t in range(l):
        y, (conv_state, ssm_state) = ssd_decode_step(
            qc, p, xin[:, t : t + 1], conv_state, ssm_state, cfg
        )
        ys.append(y)
    return jnp.concatenate(ys, axis=1), ssm_state


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros(
            (batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype
        ),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), dtype
        ),
    }
