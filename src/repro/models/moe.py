"""Mixture-of-Experts FFN: top-k routing with GShard-style capacity dispatch.

Two implementations selected by config:

  * ``capacity`` (default) — tokens grouped into fixed-size groups; per-group
    dispatch/combine einsums with capacity ``C = group * top_k * cf / E``.
    Static shapes, GSPMD-friendly (the expert axis shards over `model` when
    divisible — arctic's 128 experts — otherwise experts ride the grouped-GEMM
    batch dim with d_ff sharded — mixtral's 8). Overflow tokens are dropped
    (standard GShard semantics), which vanishes as cf grows.
  * ``dense_all`` — every expert computes every token, masked combine. Exact
    routing semantics, E/k-times the FLOPs; used by small smoke tests and as
    the oracle in tests/test_moe.py.

Router weights stay fp32 and are NOT quantization sites (tiny, precision
critical — DESIGN.md §5); expert weights carry per-expert gates, so CGMQ can
assign different bit-widths to different experts (beyond-paper extension).

BOP accounting: ``active_frac = top_k / n_experts`` — deployment cost counts
activated expert MACs only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext

from .layers import COMPUTE_DTYPE


def init_moe(key, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_dff
    k = jax.random.split(key, 4)

    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    return {
        "router": w(k[0], (d, e), d),
        "w_gate": w(k[1], (e, d, f), d),
        "w_up": w(k[2], (e, d, f), d),
        "w_down": w(k[3], (e, f, d), f),
    }


def _register_expert_sites(qc: QuantContext, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_dff
    frac = cfg.top_k / cfg.n_experts
    for nm, shp, fi, of in (
        ("moe_gate", (e, d, f), d, f),
        ("moe_up", (e, d, f), d, f),
        ("moe_down", (e, f, d), f, d),
    ):
        # positions=e: the stacked expert dim multiplies the MAC count; the
        # active fraction then scales it down to activated experts.
        qc.register_matmul(nm, shp, fan_in=fi, out_features=of, positions=e,
                           active_frac=frac)


def _expert_ffn(qc: QuantContext, p, x):
    """Batched expert GLU-FFN. x: (E, C, d) -> (E, C, d)."""
    wg = qc.weight("moe_gate", p["w_gate"]).astype(COMPUTE_DTYPE)
    wu = qc.weight("moe_up", p["w_up"]).astype(COMPUTE_DTYPE)
    wd = qc.weight("moe_down", p["w_down"]).astype(COMPUTE_DTYPE)
    x = x.astype(COMPUTE_DTYPE)
    g = jnp.einsum("ecd,edf->ecf", x, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, wu, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(COMPUTE_DTYPE)
    h = qc.act("moe_up", h)
    y = jnp.einsum("ecf,efd->ecd", h.astype(COMPUTE_DTYPE), wd,
                   preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    y = qc.act("moe_down", y)
    return y


def _router(qc: QuantContext, p, x, cfg: ModelConfig):
    """Top-k softmax router. x: (T, d) -> (weights (T,k), idx (T,k))."""
    logits = x.astype(jnp.float32) @ p["router"]  # fp32, not a quant site
    topv, topi = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(topv, axis=-1)  # mixtral-style renormalized top-k
    return weights, topi


def moe_ffn(qc: QuantContext, p, x, cfg: ModelConfig, *, impl: str = "capacity",
            plan=None):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    _register_expert_sites(qc, cfg)
    xt = x.reshape(b * s, d)
    weights, topi = _router(qc, p, xt, cfg)

    if impl == "dense_all":
        y = _moe_dense_all(qc, p, xt, weights, topi, cfg)
    else:
        y = _moe_capacity(qc, p, xt, weights, topi, cfg, plan)
    return y.reshape(b, s, d).astype(x.dtype)


def _moe_dense_all(qc, p, xt, weights, topi, cfg):
    t, d = xt.shape
    e = cfg.n_experts
    yo = _expert_ffn(qc, p, jnp.broadcast_to(xt[None], (e, t, d)))  # (E, T, d)
    # combine: sum_k w_k * y[expert_k]
    mask = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (T, k, E)
    comb = jnp.einsum("tke,tk->et", mask, weights)
    return jnp.einsum("et,etd->td", comb.astype(COMPUTE_DTYPE), yo,
                      preferred_element_type=jnp.float32)


def _moe_capacity(qc, p, xt, weights, topi, cfg, plan=None):
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group, t)
    assert t % g == 0, (t, g)
    ng = t // g
    cap = max(1, int(g * k * cfg.capacity_factor / e))

    xg = xt.reshape(ng, g, d)
    wg = weights.reshape(ng, g, k)
    ig = topi.reshape(ng, g, k)

    onehot = jax.nn.one_hot(ig, e, dtype=jnp.float32)        # (ng, g, k, E)
    # position of each token within its expert's queue (priority: slot 0 first)
    pos = jnp.cumsum(onehot.reshape(ng, g * k, e), axis=1).reshape(ng, g, k, e)
    pos = pos * onehot - 1.0                                  # -1 where unrouted
    keep = ((pos >= 0) & (pos < cap)).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = ((onehot * keep)[..., None] * pos_oh).sum(axis=2)  # (ng, g, E, C)
    # combine weights: dispatch slots weighted by router prob
    comb = ((wg[..., None] * onehot * keep)[..., None] * pos_oh).sum(axis=2)

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch.astype(COMPUTE_DTYPE),
                           xg.astype(COMPUTE_DTYPE))          # (ng, E, C, d)
    if plan is not None:
        expert_in = plan.shard_moe(expert_in)
    # fold groups into the expert token dim for one batched FFN call
    ei = jnp.moveaxis(expert_in, 1, 0).reshape(e, ng * cap, d)    # (E, ng*C, d)
    eo = _expert_ffn(qc, p, ei)                                   # (E, ng*C, d)
    expert_out = jnp.moveaxis(eo.reshape(e, ng, cap, d), 1, 0)    # (ng, E, C, d)
    if plan is not None:
        expert_out = plan.shard_moe(expert_out)
    y = jnp.einsum("ngec,necd->ngd", comb.astype(COMPUTE_DTYPE),
                   expert_out.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    return y.reshape(t, d)
