"""Shared model layers: norms, rotary embeddings, MLP variants.

All layers are pure functions taking explicit params; quantization flows
through the ``QuantContext`` (``qc``) handle. Matmul compute dtype is bf16
(TPU-native) with fp32 accumulation via ``preferred_element_type``; master
params stay fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sites import QuantContext

COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x, gain, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gain.astype(jnp.float32))).astype(x.dtype)


def qmatmul(qc: QuantContext, name: str, x, w, *, positions: int = 1,
            act_quantized: bool = True, act_name: str | None = None,
            register: bool = True):
    """Quantized matmul over the last axis of ``x``: (..., in) @ (in, out).

    Registers the site, fake-quantizes the weight, performs the contraction in
    bf16 with fp32 accumulation. The *output activation* quantization is the
    caller's job (after the nonlinearity, paper Fig. 1) via ``qc.act``.

    In serve mode, sites with an int-code export dispatch the bit-width-
    matched fused-dequant GEMM instead (Pallas on TPU, jnp reference
    elsewhere — DESIGN.md §8/§11): the fp weight is never materialized,
    ``y = x @ (codes * scale + bias)`` comes straight off the int8 codes —
    unpacked in-kernel for 2/4-bit packed storage.
    """
    if register:
        qc.register_matmul(
            name, w.shape, fan_in=int(w.shape[0]), out_features=int(w.shape[-1]),
            positions=positions, act_quantized=act_quantized,
        )
    qw = qc.serving_weight(name)
    if qw is not None:
        from repro.kernels.quant_matmul.ops import quant_matmul_qt

        # With a calibrated ``.in`` spec the GEMM goes fully integer: the
        # kernel quantizes the activation tile on the fly and accumulates
        # int8×int8 in int32 (DESIGN.md §16). Without one, the int-weight ×
        # fp32-act fused-dequant path runs — the asserted oracle.
        y = quant_matmul_qt(
            x, qw, act_spec=qc.input_spec(name),
            use_pallas=qc.matmul_impl != "ref",
            interpret=qc.matmul_impl != "pallas",
        )
        return y.astype(COMPUTE_DTYPE)
    x = qc.act_in(name, x)
    wq = qc.weight(name, w)
    y = jax.lax.dot_general(
        x.astype(COMPUTE_DTYPE), wq.astype(COMPUTE_DTYPE),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return y.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, ...]):
    """Qwen2-VL M-RoPE: rotary halves split into (t, h, w) sections.

    x: (B, S, H, hd); positions3: (3, B, S) int positions per component;
    ``sections`` sums to hd/2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # per-frequency position source: section i uses positions3[i]
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )  # (half,)
    pos = jnp.take(positions3, sec_ids, axis=0)  # (half, B, S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def glu_mlp(qc: QuantContext, p, x, kind: str):
    """SwiGLU / GeGLU / plain-GELU MLP with quantization sites."""
    if kind in ("swiglu", "geglu"):
        g = qmatmul(qc, "mlp_gate", x, p["w_gate"])
        u = qmatmul(qc, "mlp_up", x, p["w_up"])
        act = jax.nn.silu if kind == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        h = act(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
        h = qc.act("mlp_up", h)
        y = qmatmul(qc, "mlp_down", h, p["w_down"])
        y = qc.act("mlp_down", y)
        return y
    # plain gelu (musicgen / t5-style)
    h = qmatmul(qc, "mlp_in", x, p["w_in"])
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(COMPUTE_DTYPE)
    h = qc.act("mlp_in", h)
    y = qmatmul(qc, "mlp_out", h, p["w_out"])
    y = qc.act("mlp_out", y)
    return y


def init_glu_mlp(key, d_model: int, d_ff: int, kind: str):
    k = jax.random.split(key, 3)

    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": w(k[0], (d_model, d_ff), d_model),
            "w_up": w(k[1], (d_model, d_ff), d_model),
            "w_down": w(k[2], (d_ff, d_model), d_ff),
        }
    return {
        "w_in": w(k[0], (d_model, d_ff), d_model),
        "w_out": w(k[1], (d_ff, d_model), d_ff),
    }


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
