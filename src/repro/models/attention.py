"""Attention: GQA with global / sliding-window kinds, softcap, qk-norm, M-RoPE.

Two code paths (DESIGN.md §4):

  * train/prefill — dense masked attention with KV heads materialized to the
    full head count (keeps the head axis uniformly shardable over `model`).
  * decode — grouped-query attention against a KV cache; the cache sequence
    axis is sharded (FlashDecoding-style split-KV falls out of GSPMD's
    partial-reduction handling), heads stay replicated.

Local attention uses a ring-buffer cache of ``window`` slots at decode time so
sliding-window archs (mixtral, gemma2 local layers, recurrentgemma) stay O(w)
memory at 500k context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext
from repro.quant import kv as kv_codec

from .layers import COMPUTE_DTYPE, apply_mrope, apply_rope, qmatmul, rms_norm, softcap

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    k = jax.random.split(key, 4)

    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    p = {
        "wq": w(k[0], (d, h * hd), d),
        "wk": w(k[1], (d, kv * hd), d),
        "wv": w(k[2], (d, kv * hd), d),
        "wo": w(k[3], (h * hd, d), h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(qc: QuantContext, p, x, cfg: ModelConfig, positions, mrope_pos):
    """Shared q/k/v projection + norm + rope. x: (B, S, d)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = qmatmul(qc, "attn_q", x, p["wq"])
    k = qmatmul(qc, "attn_k", x, p["wk"])
    v = qmatmul(qc, "attn_v", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(t, groups: int):
    """(B, S, KV, hd) -> (B, S, KV*groups, hd)."""
    b, s, kv, hd = t.shape
    t = jnp.broadcast_to(t[:, :, :, None, :], (b, s, kv, groups, hd))
    return t.reshape(b, s, kv * groups, hd)


def attention_train(
    qc: QuantContext,
    p,
    x,
    cfg: ModelConfig,
    kind: str,
    *,
    positions=None,
    mrope_pos=None,
    plan=None,
):
    """Causal (optionally sliding-window) attention. Returns (y, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(qc, p, x, cfg, positions, mrope_pos)
    groups = cfg.n_heads // cfg.n_kv_heads
    k_r, v_r = _repeat_kv(k, groups), _repeat_kv(v, groups)
    if plan is not None:
        q, k_r, v_r = plan.shard_attn_qkv(q, k_r, v_r)

    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(COMPUTE_DTYPE), k_r.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = qi >= ki
    if kind == "local":
        mask &= (qi - ki) < cfg.window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_r,
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    # NOTE: the QK^T / PV products are activation-activation matmuls with no
    # weight operand — not BOP-constrained sites (DESIGN.md §3).
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, (k, v)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                    dtype=jnp.bfloat16,
                    spec: kv_codec.KVQuantSpec | None = None):
    """Ring/contiguous decode cache; with ``spec`` set, quantized storage
    (packed codes + fp16 group scales — same flat layout as the paged pool,
    DESIGN.md §14)."""
    slots = min(cfg.window, max_seq) if kind == "local" else max_seq
    if spec is not None:
        assert spec.head_dim == cfg.head_dim, (spec, cfg.head_dim)
        cshape = (batch, slots, cfg.n_kv_heads, spec.packed_head)
        sshape = (batch, slots, cfg.n_kv_heads, spec.num_groups)
        return {"k": jnp.zeros(cshape, spec.code_dtype),
                "v": jnp.zeros(cshape, spec.code_dtype),
                "k_scale": jnp.zeros(sshape, spec.scale_dtype),
                "v_scale": jnp.zeros(sshape, spec.scale_dtype)}
    shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attention_decode(
    qc: QuantContext,
    p,
    x,
    cache: dict,
    pos,
    cfg: ModelConfig,
    kind: str,
    *,
    mrope_pos=None,
    plan=None,
):
    """One-token decode. x: (B, 1, d); pos: (B,) int32 per-row positions
    (tokens so far) — scalars broadcast, so single-sequence callers can pass
    a plain int. Rows decode independently: each row's K/V lands at its own
    position and its mask admits only its own history, which is what lets a
    continuous-batching engine keep slots at unrelated positions in one
    batched step.

    Local layers treat the cache as a ring buffer of ``window`` slots.
    Returns (y, new_cache).
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    mp = None
    if cfg.mrope_sections is not None:
        mp = (
            mrope_pos
            if mrope_pos is not None
            else jnp.broadcast_to(positions[None], (3, b, 1))
        )
    q, k, v = _project_qkv(qc, p, x, cfg, positions, mp)

    slots = cache["k"].shape[1]
    slot = pos % slots if kind == "local" else jnp.minimum(pos, slots - 1)
    rows = jnp.arange(b)
    spec = kv_codec.spec_from_cache(cache, cfg.head_dim)
    if spec is not None:
        # write-site quantization (§14): floats never land in the cache
        kc, ksc = kv_codec.quantize_kv(k[:, 0], spec)
        vc, vsc = kv_codec.quantize_kv(v[:, 0], spec)
        new_cache = {
            "k": cache["k"].at[rows, slot].set(kc),
            "v": cache["v"].at[rows, slot].set(vc),
            "k_scale": cache["k_scale"].at[rows, slot].set(ksc),
            "v_scale": cache["v_scale"].at[rows, slot].set(vsc),
        }
        ck = kv_codec.dequantize_kv(new_cache["k"], new_cache["k_scale"], spec)
        cv = kv_codec.dequantize_kv(new_cache["v"], new_cache["v_scale"], spec)
        if plan is not None:
            ck = plan.shard_cache(ck)
            cv = plan.shard_cache(cv)
    else:
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        if plan is not None:
            ck = plan.shard_cache(ck)
            cv = plan.shard_cache(cv)
        new_cache = {"k": ck, "v": cv}

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, groups, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(COMPUTE_DTYPE), ck.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    sids = jnp.arange(slots)[None, :]
    posb = pos[:, None]
    if kind == "local":
        # ring buffer: slot s holds absolute position ap with ap % slots == s
        # and ap <= pos; valid iff pos - ap < window and ap <= pos.
        ap = posb - ((posb - sids) % slots)
        valid = (ap >= 0) & (ap <= posb) & ((posb - ap) < cfg.window)
    else:
        valid = sids <= posb
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv,
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, new_cache


def attention_decode_paged(
    qc: QuantContext,
    p,
    x,
    pool: dict,
    block_table,
    pos,
    cfg: ModelConfig,
    kind: str,
    *,
    mrope_pos=None,
    plan=None,
    write_mask=None,
):
    """One-token decode through a paged KV pool (DESIGN.md §10).

    ``pool``: {"k", "v"} of (num_blocks, bs, KV, hd) — one layer's physical
    block pool; ``block_table``: (B, max_blocks) int32 mapping each row's
    logical blocks to physical ids (-1 = unallocated); ``pos``: (B,) int32.

    The new K/V lands at physical block ``table[b, pos // bs]`` offset
    ``pos % bs``; rows outside ``write_mask`` (idle slots, teacher steps for
    another slot) are routed to the reserved garbage block 0 so they can
    never corrupt pool blocks they don't own. The attend then gathers
    through the table (``kernels/paged_attention``: jnp oracle, or the
    Pallas kernel per ``qc.matmul_impl``). Local layers keep full history in
    blocks and mask to the window — the ring buffer's O(window) residency is
    traded for block-granular allocation.

    Returns (y, new_pool).
    """
    from repro.kernels.paged_attention.ops import paged_attention_op

    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    mp = None
    if cfg.mrope_sections is not None:
        mp = (
            mrope_pos
            if mrope_pos is not None
            else jnp.broadcast_to(pos[None, :, None], (3, b, 1))
        )
    q, k, v = _project_qkv(qc, p, x, cfg, pos[:, None], mp)

    bs = pool["k"].shape[1]
    mb = block_table.shape[1]
    lp = jnp.clip(pos, 0, mb * bs - 1)
    rows = jnp.arange(b)
    phys = block_table[rows, lp // bs]
    ok = phys >= 0
    if write_mask is not None:
        ok &= write_mask.astype(bool)
    tgt = jnp.where(ok, phys, 0)
    off = lp % bs
    spec = kv_codec.spec_from_cache(pool, cfg.head_dim)
    if spec is not None:
        # write-site quantization (§14): codes + group scales land together
        kc, ksc = kv_codec.quantize_kv(k[:, 0], spec)
        vc, vsc = kv_codec.quantize_kv(v[:, 0], spec)
        new_pool = {
            "k": pool["k"].at[tgt, off].set(kc),
            "v": pool["v"].at[tgt, off].set(vc),
            "k_scale": pool["k_scale"].at[tgt, off].set(ksc),
            "v_scale": pool["v_scale"].at[tgt, off].set(vsc),
        }
        scales = {"k_scale": new_pool["k_scale"],
                  "v_scale": new_pool["v_scale"]}
    else:
        new_pool = {
            "k": pool["k"].at[tgt, off].set(k[:, 0].astype(pool["k"].dtype)),
            "v": pool["v"].at[tgt, off].set(v[:, 0].astype(pool["v"].dtype)),
        }
        scales = {"k_scale": None, "v_scale": None}
    if plan is not None:
        new_pool = {name: plan.shard_pool(a) for name, a in new_pool.items()}
        scales = {"k_scale": new_pool.get("k_scale"),
                  "v_scale": new_pool.get("v_scale")}

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(b, cfg.n_kv_heads, groups, cfg.head_dim)
    impl = qc.matmul_impl
    out = paged_attention_op(
        qg.astype(COMPUTE_DTYPE), new_pool["k"], new_pool["v"],
        block_table, pos,
        window=cfg.window if kind == "local" else None,
        softcap=cfg.attn_softcap,
        use_pallas=impl != "ref", interpret=impl != "pallas",
        **scales,
    )
    out = out.astype(COMPUTE_DTYPE).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, new_pool


def write_prefill_slot(cfg: ModelConfig, kind: str, cache: dict, k, v, slot,
                       plen):
    """Write one serving slot's prefill K/V range in one shot.

    ``cache``: {"k", "v"} of shape (B, slots, KV, hd), with an optional
    leading scan axis (R, B, slots, KV, hd); ``k``/``v``: the batched-prefill
    K/V for the slot's right-padded prompt, shaped like the cache with B=1
    and the sequence axis S_pad in place of ``slots``. ``slot``/``plen`` may
    be traced scalars.

    Global caches take positions [0, S_pad) verbatim. Ring (local) caches
    gather, for each ring slot r, the unique prompt position p ≡ r (mod ring)
    in (plen - ring, plen]. Right-padding beyond ``plen`` (and ring slots a
    short prompt never reached) is written but never attended: the decode
    mask only admits positions <= pos, and decode overwrites each position in
    the same step that first exposes it.

    Quantized caches quantize here — after the ring gather, before the
    slice write — so codes and scales land through the identical update.
    """
    if kind == "local":
        ring = cache["k"].shape[-3]
        r = jnp.arange(ring)
        p = plen - 1 - ((plen - 1 - r) % ring)
        p = jnp.clip(p, 0, k.shape[-3] - 1)
        k = jnp.take(k, p, axis=-3)
        v = jnp.take(v, p, axis=-3)
    spec = kv_codec.spec_from_cache(cache, cfg.head_dim)
    if spec is not None:
        kc, ksc = kv_codec.quantize_kv(k, spec)
        vc, vsc = kv_codec.quantize_kv(v, spec)
        entries = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
    else:
        entries = {"k": k, "v": v}
    out = {}
    for name, x in entries.items():
        tgt = cache[name]
        start = [0] * tgt.ndim
        start[-4] = slot  # the batch (slot) axis, stacked or not
        out[name] = jax.lax.dynamic_update_slice(
            tgt, x.astype(tgt.dtype), tuple(start))
    return out


def fill_cache_from_prefill(cfg: ModelConfig, kind: str, k, v, max_seq: int):
    """Build a decode cache from full prefill K/V ((B, S, KV, hd))."""
    b, s, kv, hd = k.shape
    cache = init_attn_cache(cfg, kind, b, max_seq, dtype=COMPUTE_DTYPE)
    slots = cache["k"].shape[1]
    if kind == "local":
        # place the last `min(s, slots)` tokens at their ring positions
        take = min(s, slots)
        idx = (jnp.arange(s - take, s)) % slots
        cache["k"] = cache["k"].at[:, idx].set(k[:, s - take:].astype(COMPUTE_DTYPE))
        cache["v"] = cache["v"].at[:, idx].set(v[:, s - take:].astype(COMPUTE_DTYPE))
    else:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(COMPUTE_DTYPE), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(COMPUTE_DTYPE), (0, 0, 0, 0))
    return cache
