"""Attention: GQA with global / sliding-window kinds, softcap, qk-norm, M-RoPE.

Two code paths (DESIGN.md §4):

  * train/prefill — dense masked attention with KV heads materialized to the
    full head count (keeps the head axis uniformly shardable over `model`).
  * decode — grouped-query attention against a KV cache; the cache sequence
    axis is sharded (FlashDecoding-style split-KV falls out of GSPMD's
    partial-reduction handling), heads stay replicated.

Local attention uses a ring-buffer cache of ``window`` slots at decode time so
sliding-window archs (mixtral, gemma2 local layers, recurrentgemma) stay O(w)
memory at 500k context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext
from repro.quant import kv as kv_codec

from .layers import COMPUTE_DTYPE, apply_mrope, apply_rope, qmatmul, rms_norm, softcap

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    k = jax.random.split(key, 4)

    def w(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    p = {
        "wq": w(k[0], (d, h * hd), d),
        "wk": w(k[1], (d, kv * hd), d),
        "wv": w(k[2], (d, kv * hd), d),
        "wo": w(k[3], (h * hd, d), h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(qc: QuantContext, p, x, cfg: ModelConfig, positions, mrope_pos):
    """Shared q/k/v projection + norm + rope. x: (B, S, d)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = qmatmul(qc, "attn_q", x, p["wq"])
    k = qmatmul(qc, "attn_k", x, p["wk"])
    v = qmatmul(qc, "attn_v", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(t, groups: int):
    """(B, S, KV, hd) -> (B, S, KV*groups, hd)."""
    b, s, kv, hd = t.shape
    t = jnp.broadcast_to(t[:, :, :, None, :], (b, s, kv, groups, hd))
    return t.reshape(b, s, kv * groups, hd)


def _resolve_window(window, kind: str, cfg: ModelConfig):
    """Resolve the engine's static ``(window, sink_tokens)`` mask tuple
    (DESIGN.md §17) for one layer. Local layers tighten their architectural
    window and drop sinks — the ring layout physically overwrites positions
    older than ``cfg.window``, so a sink there would be unservable; the
    sink contract covers full-history layers only. Global layers take the
    tuple verbatim. Returns ``(effective_window | None, sink_tokens)``;
    ``None`` means causal-only. The tuple (not a WindowSpec) keeps
    ``repro.models`` free of serving imports."""
    if window is None:
        return (cfg.window if kind == "local" else None, 0)
    w, sinks = window
    if kind == "local":
        return (min(cfg.window, w), 0)
    return (w, sinks)


def attention_train(
    qc: QuantContext,
    p,
    x,
    cfg: ModelConfig,
    kind: str,
    *,
    positions=None,
    mrope_pos=None,
    plan=None,
    window=None,
):
    """Causal (optionally sliding-window) attention. Returns (y, (k, v)).

    ``window``: optional engine ``(window, sink_tokens)`` tuple (§17) layered
    on top of the architectural mask via ``_resolve_window``."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(qc, p, x, cfg, positions, mrope_pos)
    groups = cfg.n_heads // cfg.n_kv_heads
    k_r, v_r = _repeat_kv(k, groups), _repeat_kv(v, groups)
    if plan is not None:
        q, k_r, v_r = plan.shard_attn_qkv(q, k_r, v_r)

    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(COMPUTE_DTYPE), k_r.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = qi >= ki
    eff, sinks = _resolve_window(window, kind, cfg)
    if eff is not None:
        in_win = (qi - ki) < eff
        if sinks:
            in_win |= ki < sinks
        mask &= in_win
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_r,
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    # NOTE: the QK^T / PV products are activation-activation matmuls with no
    # weight operand — not BOP-constrained sites (DESIGN.md §3).
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, (k, v)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                    dtype=jnp.bfloat16,
                    spec: kv_codec.KVQuantSpec | None = None):
    """Ring/contiguous decode cache; with ``spec`` set, quantized storage
    (packed codes + fp16 group scales — same flat layout as the paged pool,
    DESIGN.md §14)."""
    slots = min(cfg.window, max_seq) if kind == "local" else max_seq
    if spec is not None:
        assert spec.head_dim == cfg.head_dim, (spec, cfg.head_dim)
        cshape = (batch, slots, cfg.n_kv_heads, spec.packed_head)
        sshape = (batch, slots, cfg.n_kv_heads, spec.num_groups)
        return {"k": jnp.zeros(cshape, spec.code_dtype),
                "v": jnp.zeros(cshape, spec.code_dtype),
                "k_scale": jnp.zeros(sshape, spec.scale_dtype),
                "v_scale": jnp.zeros(sshape, spec.scale_dtype)}
    shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attention_decode(
    qc: QuantContext,
    p,
    x,
    cache: dict,
    pos,
    cfg: ModelConfig,
    kind: str,
    *,
    mrope_pos=None,
    plan=None,
    window=None,
):
    """One-token decode. x: (B, 1, d); pos: (B,) int32 per-row positions
    (tokens so far) — scalars broadcast, so single-sequence callers can pass
    a plain int. Rows decode independently: each row's K/V lands at its own
    position and its mask admits only its own history, which is what lets a
    continuous-batching engine keep slots at unrelated positions in one
    batched step.

    Local layers treat the cache as a ring buffer of ``window`` slots.
    ``window``: optional engine ``(window, sink_tokens)`` tuple (§17) — the
    contiguous global cache masks to it by absolute position (rows past the
    window stay resident here; only the paged layout evicts them).
    Returns (y, new_cache).
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    mp = None
    if cfg.mrope_sections is not None:
        mp = (
            mrope_pos
            if mrope_pos is not None
            else jnp.broadcast_to(positions[None], (3, b, 1))
        )
    q, k, v = _project_qkv(qc, p, x, cfg, positions, mp)

    slots = cache["k"].shape[1]
    slot = pos % slots if kind == "local" else jnp.minimum(pos, slots - 1)
    rows = jnp.arange(b)
    spec = kv_codec.spec_from_cache(cache, cfg.head_dim)
    if spec is not None:
        # write-site quantization (§14): floats never land in the cache
        kc, ksc = kv_codec.quantize_kv(k[:, 0], spec)
        vc, vsc = kv_codec.quantize_kv(v[:, 0], spec)
        new_cache = {
            "k": cache["k"].at[rows, slot].set(kc),
            "v": cache["v"].at[rows, slot].set(vc),
            "k_scale": cache["k_scale"].at[rows, slot].set(ksc),
            "v_scale": cache["v_scale"].at[rows, slot].set(vsc),
        }
        ck = kv_codec.dequantize_kv(new_cache["k"], new_cache["k_scale"], spec)
        cv = kv_codec.dequantize_kv(new_cache["v"], new_cache["v_scale"], spec)
        if plan is not None:
            ck = plan.shard_cache(ck)
            cv = plan.shard_cache(cv)
    else:
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        if plan is not None:
            ck = plan.shard_cache(ck)
            cv = plan.shard_cache(cv)
        new_cache = {"k": ck, "v": cv}

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, groups, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(COMPUTE_DTYPE), ck.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    sids = jnp.arange(slots)[None, :]
    posb = pos[:, None]
    eff, sinks = _resolve_window(window, kind, cfg)
    if kind == "local":
        # ring buffer: slot s holds absolute position ap with ap % slots == s
        # and ap <= pos; valid iff pos - ap < window and ap <= pos.
        ap = posb - ((posb - sids) % slots)
        valid = (ap >= 0) & (ap <= posb) & ((posb - ap) < eff)
    else:
        valid = sids <= posb
        if eff is not None:
            in_win = (posb - sids) < eff
            if sinks:
                in_win |= sids < sinks
            valid &= in_win
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv,
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, new_cache


def attention_decode_paged(
    qc: QuantContext,
    p,
    x,
    pool: dict,
    block_table,
    pos,
    cfg: ModelConfig,
    kind: str,
    *,
    mrope_pos=None,
    plan=None,
    write_mask=None,
    window=None,
):
    """One-token decode through a paged KV pool (DESIGN.md §10).

    ``pool``: {"k", "v"} of (num_blocks, bs, KV, hd) — one layer's physical
    block pool; ``block_table``: (B, max_blocks) int32 mapping each row's
    logical blocks to physical ids (-1 = unallocated); ``pos``: (B,) int32.

    The new K/V lands at physical block ``table[b, pos // bs]`` offset
    ``pos % bs``; rows outside ``write_mask`` (idle slots, teacher steps for
    another slot) are routed to the reserved garbage block 0 so they can
    never corrupt pool blocks they don't own. The attend then gathers
    through the table (``kernels/paged_attention``: jnp oracle, or the
    Pallas kernel per ``qc.matmul_impl``). Local layers keep full history in
    blocks and mask to the window — the ring buffer's O(window) residency is
    traded for block-granular allocation.

    ``window``: optional engine ``(window, sink_tokens)`` tuple (§17),
    resolved per layer kind and forwarded as static args so the kernel's
    first-live-block walk skips dead blocks entirely.

    Returns (y, new_pool).
    """
    from repro.kernels.paged_attention.ops import paged_attention_op

    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    mp = None
    if cfg.mrope_sections is not None:
        mp = (
            mrope_pos
            if mrope_pos is not None
            else jnp.broadcast_to(pos[None, :, None], (3, b, 1))
        )
    q, k, v = _project_qkv(qc, p, x, cfg, pos[:, None], mp)

    bs = pool["k"].shape[1]
    mb = block_table.shape[1]
    lp = jnp.clip(pos, 0, mb * bs - 1)
    rows = jnp.arange(b)
    phys = block_table[rows, lp // bs]
    ok = phys >= 0
    if write_mask is not None:
        ok &= write_mask.astype(bool)
    tgt = jnp.where(ok, phys, 0)
    off = lp % bs
    spec = kv_codec.spec_from_cache(pool, cfg.head_dim)
    if spec is not None:
        # write-site quantization (§14): codes + group scales land together
        kc, ksc = kv_codec.quantize_kv(k[:, 0], spec)
        vc, vsc = kv_codec.quantize_kv(v[:, 0], spec)
        new_pool = {
            "k": pool["k"].at[tgt, off].set(kc),
            "v": pool["v"].at[tgt, off].set(vc),
            "k_scale": pool["k_scale"].at[tgt, off].set(ksc),
            "v_scale": pool["v_scale"].at[tgt, off].set(vsc),
        }
        scales = {"k_scale": new_pool["k_scale"],
                  "v_scale": new_pool["v_scale"]}
    else:
        new_pool = {
            "k": pool["k"].at[tgt, off].set(k[:, 0].astype(pool["k"].dtype)),
            "v": pool["v"].at[tgt, off].set(v[:, 0].astype(pool["v"].dtype)),
        }
        scales = {"k_scale": None, "v_scale": None}
    if plan is not None:
        new_pool = {name: plan.shard_pool(a) for name, a in new_pool.items()}
        scales = {"k_scale": new_pool.get("k_scale"),
                  "v_scale": new_pool.get("v_scale")}

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(b, cfg.n_kv_heads, groups, cfg.head_dim)
    impl = qc.matmul_impl
    eff, sinks = _resolve_window(window, kind, cfg)
    out = paged_attention_op(
        qg.astype(COMPUTE_DTYPE), new_pool["k"], new_pool["v"],
        block_table, pos,
        window=eff, sinks=sinks,
        softcap=cfg.attn_softcap,
        use_pallas=impl != "ref", interpret=impl != "pallas",
        **scales,
    )
    out = out.astype(COMPUTE_DTYPE).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, new_pool


def attention_prefill_chunk(
    qc: QuantContext,
    p,
    x,
    cache: dict,
    pos0,
    clen,
    cfg: ModelConfig,
    kind: str,
    *,
    slot=0,
    block_table=None,
    positions=None,
    mrope_pos=None,
    plan=None,
    window=None,
):
    """Chunk-resumable prefill attention for ONE serving slot (DESIGN.md §15).

    ``x``: (1, C, d) hidden states for the prompt positions
    ``pos0 .. pos0+clen-1`` (lanes past ``clen`` are padding). The chunk's
    K/V is written into the slot's cache AT ITS OFFSET first, then the
    queries attend THROUGH the cache — the multi-token generalization of
    ``attention_decode``'s write-then-attend. Every query position therefore
    reads identical cache content over a static key axis no matter where the
    chunk boundaries fall, which is what makes chunked streams bit-identical
    across any split (quantized KV included: each position's codes are a
    pure function of that position's K/V).

    Ring caches: ``cache`` is one layer's (slots, S, KV, ·) entry. Local
    layers require ``clen <= ring`` (the engine clamps chunk sizes to the
    window) or earlier in-chunk queries would lose their ring slots to later
    writes. Paged: ``block_table`` is the slot's (max_blocks,) physical row;
    unallocated/padding lanes route to the reserved garbage block 0.

    ``pos0``/``clen``/``slot`` may be traced scalars. ``window``: optional
    engine ``(window, sink_tokens)`` tuple (§17). On the paged path a
    binding window switches the key gather from the whole table to a
    bounded O(sinks + window + C) two-segment gather — the sink prefix
    blocks plus the blocks the sliding window can reach from this chunk —
    so long-context chunked prefill never materializes dead blocks. When
    the window cannot bind (small tables, or ``window=None``) the gather
    stays whole-table so logits remain bit-identical to the unwindowed
    path. Returns (y (1, C, d), new_cache_entry).
    """
    b, c, _ = x.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    clen = jnp.asarray(clen, jnp.int32)
    lanes = jnp.arange(c)
    if positions is None:
        positions = (pos0 + lanes)[None, :]
    mp = None
    if cfg.mrope_sections is not None:
        mp = (
            mrope_pos
            if mrope_pos is not None
            else jnp.broadcast_to(positions[None], (3, b, c))
        )
    q, k, v = _project_qkv(qc, p, x, cfg, positions, mp)
    kc, vc = k[0], v[0]  # (C, KV, hd)
    qpos = positions[0]  # (C,) absolute query positions (garbage past clen)
    eff, sinks = _resolve_window(window, kind, cfg)
    spec = kv_codec.spec_from_cache(cache, cfg.head_dim)
    if spec is not None:
        # write-site quantization (§14): the whole chunk quantizes before it
        # lands, so cache content matches the decode write path per position
        kq, ksc = kv_codec.quantize_kv(kc, spec)
        vq, vsc = kv_codec.quantize_kv(vc, spec)
        entries = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    else:
        entries = {"k": kc, "v": vc}

    if block_table is None:
        ring = cache["k"].shape[1]
        if kind == "local":
            # Ring buffers hold only the last `ring` positions, so writing
            # the chunk first would evict positions the chunk's EARLIER
            # queries still need. Instead: per-query gather over a canonical
            # key axis of exactly `window` lanes ordered by absolute
            # position, sourcing each position from the chunk (storage
            # dtype round-tripped, so its value matches what a later chunk
            # would read back) or from the pre-chunk ring. The reduction
            # layout is position-indexed and static, hence bit-identical
            # under any chunk split.
            old = {name: cache[name][slot] for name in entries}
            if spec is not None:
                ck = kv_codec.dequantize_kv(entries["k"], entries["k_scale"],
                                            spec)
                cv = kv_codec.dequantize_kv(entries["v"], entries["v_scale"],
                                            spec)
                rk = kv_codec.dequantize_kv(old["k"], old["k_scale"], spec)
                rv = kv_codec.dequantize_kv(old["v"], old["v_scale"], spec)
            else:
                ck = entries["k"].astype(cache["k"].dtype)
                cv = entries["v"].astype(cache["v"].dtype)
                rk, rv = old["k"], old["v"]
            allk = jnp.concatenate([ck.astype(COMPUTE_DTYPE),
                                    rk.astype(COMPUTE_DTYPE)], axis=0)
            allv = jnp.concatenate([cv.astype(COMPUTE_DTYPE),
                                    rv.astype(COMPUTE_DTYPE)], axis=0)
            w = cfg.window
            kp = qpos[:, None] - w + 1 + jnp.arange(w)[None, :]  # (C, W)
            src = jnp.where(kp >= pos0, jnp.clip(kp - pos0, 0, c - 1),
                            c + (kp % ring))
            valid = kp >= 0
            if eff != cfg.window:  # engine window tightens the local layer
                valid &= (qpos[:, None] - kp) < eff
            keys_k = allk[src]  # (C, W, KV, hd)
            keys_v = allv[src]
            # now land the chunk: ring slot r ends holding absolute position
            # hold(r) = f - ((f - r) mod ring), f the chunk's final position;
            # only slots the chunk reached are replaced.
            f = pos0 + clen - 1
            r = jnp.arange(ring)
            hold = f - ((f - r) % ring)
            write = hold >= pos0
            ci = jnp.clip(hold - pos0, 0, c - 1)
            new_cache = {}
            for name, xv in entries.items():
                upd = jnp.where(
                    write.reshape((ring,) + (1,) * (old[name].ndim - 1)),
                    jnp.take(xv, ci, axis=0).astype(cache[name].dtype),
                    old[name])
                new_cache[name] = cache[name].at[slot].set(upd)
            groups = cfg.n_heads // cfg.n_kv_heads
            qg = q[0].reshape(c, cfg.n_kv_heads, groups, cfg.head_dim)
            scale = cfg.head_dim ** -0.5
            logits = jnp.einsum(
                "ckgd,cwkd->ckgw", qg.astype(COMPUTE_DTYPE), keys_k,
                preferred_element_type=jnp.float32,
            ) * scale
            logits = softcap(logits, cfg.attn_softcap)
            logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
            probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
            out = jnp.einsum("ckgw,cwkd->ckgd", probs, keys_v,
                             preferred_element_type=jnp.float32)
            out = out.astype(COMPUTE_DTYPE).reshape(
                1, c, cfg.n_heads * cfg.head_dim)
            y = qmatmul(qc, "attn_o", out, p["wo"])
            y = qc.act("attn_o", y)
            return y, new_cache
        else:
            # scatter with padding lanes pushed out of bounds — JAX drops
            # out-of-bounds scatter updates, so pad lanes never land
            idx = jnp.where(lanes < clen, pos0 + lanes, ring)
            new_cache = {}
            for name, xv in entries.items():
                new_cache[name] = cache[name].at[slot, idx].set(
                    xv.astype(cache[name].dtype))
            sids = jnp.arange(ring)[None, :]
            valid = sids <= qpos[:, None]
            if eff is not None:
                in_win = (qpos[:, None] - sids) < eff
                if sinks:
                    in_win |= sids < sinks
                valid &= in_win
        if spec is not None:
            keys_k = kv_codec.dequantize_kv(
                new_cache["k"][slot], new_cache["k_scale"][slot], spec)
            keys_v = kv_codec.dequantize_kv(
                new_cache["v"][slot], new_cache["v_scale"][slot], spec)
        else:
            keys_k = new_cache["k"][slot]
            keys_v = new_cache["v"][slot]
    else:
        bs = cache["k"].shape[1]
        mb = block_table.shape[0]
        nb = cache["k"].shape[0]
        p_abs = pos0 + lanes
        phys = block_table[jnp.clip(p_abs // bs, 0, mb - 1)]
        ok = (lanes < clen) & (phys >= 0)
        tgt = jnp.where(ok, phys, 0)  # garbage block for invalid lanes
        off = p_abs % bs
        new_cache = {}
        for name, xv in entries.items():
            new_cache[name] = cache[name].at[tgt, off].set(
                xv.astype(cache[name].dtype))
        sb = -(-sinks // bs)
        nw = min(mb, -(-(eff + c) // bs) + 1) if eff is not None else mb
        if window is not None and eff is not None and sb + nw < mb:
            # Bounded two-segment gather (docstring): the pinned sink blocks
            # plus the `nw` blocks the sliding window can reach from any
            # query in this chunk — O(sinks + window + C) keys however long
            # the prompt. `fl0` is the first window-reachable block, clamped
            # so the segment stays inside the table; when it clamps low the
            # segments overlap, and the window lanes' `kp >= sinks` term
            # de-duplicates them (sink positions count exactly once).
            fl0 = jnp.clip((pos0 - eff + 1) // bs, 0, mb - nw)
            blks = jnp.concatenate(
                [jnp.arange(sb), fl0 + jnp.arange(nw)])  # (sb + nw,)
            rowt = block_table[blks]
            rowb = jnp.clip(rowt, 0, nb - 1)
            kpos = (blks[:, None] * bs + jnp.arange(bs)[None, :]).reshape(-1)
            alloc_ok = jnp.repeat(rowt >= 0, bs)
            win_lane = jnp.repeat(jnp.arange(sb + nw) >= sb, bs)
            valid = alloc_ok[None, :] & (kpos[None, :] <= qpos[:, None])
            win_ok = (((qpos[:, None] - kpos[None, :]) < eff)
                      & (kpos[None, :] >= sinks))
            valid &= win_ok | ~win_lane[None, :]
        else:
            rowb = jnp.clip(block_table, 0, nb - 1)
            kpos = jnp.arange(mb * bs)
            alloc_ok = (block_table >= 0)[kpos // bs]
            valid = alloc_ok[None, :] & (kpos[None, :] <= qpos[:, None])
            if eff is not None:
                in_win = (qpos[:, None] - kpos[None, :]) < eff
                if sinks:
                    in_win |= kpos[None, :] < sinks
                valid &= in_win
        if spec is not None:
            gk = kv_codec.dequantize_kv(
                new_cache["k"][rowb], new_cache["k_scale"][rowb], spec)
            gv = kv_codec.dequantize_kv(
                new_cache["v"][rowb], new_cache["v_scale"][rowb], spec)
        else:
            gk = new_cache["k"][rowb]
            gv = new_cache["v"][rowb]
        keys_k = gk.reshape(-1, cfg.n_kv_heads, cfg.head_dim)
        keys_v = gv.reshape(-1, cfg.n_kv_heads, cfg.head_dim)

    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q[0].reshape(c, cfg.n_kv_heads, groups, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum(
        "ckgd,skd->ckgs", qg.astype(COMPUTE_DTYPE),
        keys_k.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    ) * scale
    logits = softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("ckgs,skd->ckgd", probs, keys_v.astype(COMPUTE_DTYPE),
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    out = out.reshape(1, c, cfg.n_heads * cfg.head_dim)
    y = qmatmul(qc, "attn_o", out, p["wo"])
    y = qc.act("attn_o", y)
    return y, new_cache


def write_prefill_slot(cfg: ModelConfig, kind: str, cache: dict, k, v, slot,
                       plen):
    """Write one serving slot's prefill K/V range in one shot.

    ``cache``: {"k", "v"} of shape (B, slots, KV, hd), with an optional
    leading scan axis (R, B, slots, KV, hd); ``k``/``v``: the batched-prefill
    K/V for the slot's right-padded prompt, shaped like the cache with B=1
    and the sequence axis S_pad in place of ``slots``. ``slot``/``plen`` may
    be traced scalars.

    Global caches take positions [0, S_pad) verbatim. Ring (local) caches
    gather, for each ring slot r, the unique prompt position p ≡ r (mod ring)
    in (plen - ring, plen]. Right-padding beyond ``plen`` (and ring slots a
    short prompt never reached) is written but never attended: the decode
    mask only admits positions <= pos, and decode overwrites each position in
    the same step that first exposes it.

    Quantized caches quantize here — after the ring gather, before the
    slice write — so codes and scales land through the identical update.
    """
    if kind == "local":
        ring = cache["k"].shape[-3]
        r = jnp.arange(ring)
        p = plen - 1 - ((plen - 1 - r) % ring)
        p = jnp.clip(p, 0, k.shape[-3] - 1)
        k = jnp.take(k, p, axis=-3)
        v = jnp.take(v, p, axis=-3)
    spec = kv_codec.spec_from_cache(cache, cfg.head_dim)
    if spec is not None:
        kc, ksc = kv_codec.quantize_kv(k, spec)
        vc, vsc = kv_codec.quantize_kv(v, spec)
        entries = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
    else:
        entries = {"k": k, "v": v}
    out = {}
    for name, x in entries.items():
        tgt = cache[name]
        start = [0] * tgt.ndim
        start[-4] = slot  # the batch (slot) axis, stacked or not
        out[name] = jax.lax.dynamic_update_slice(
            tgt, x.astype(tgt.dtype), tuple(start))
    return out


def fill_cache_from_prefill(cfg: ModelConfig, kind: str, k, v, max_seq: int):
    """Build a decode cache from full prefill K/V ((B, S, KV, hd))."""
    b, s, kv, hd = k.shape
    cache = init_attn_cache(cfg, kind, b, max_seq, dtype=COMPUTE_DTYPE)
    slots = cache["k"].shape[1]
    if kind == "local":
        # place the last `min(s, slots)` tokens at their ring positions
        take = min(s, slots)
        idx = (jnp.arange(s - take, s)) % slots
        cache["k"] = cache["k"].at[:, idx].set(k[:, s - take:].astype(COMPUTE_DTYPE))
        cache["v"] = cache["v"].at[:, idx].set(v[:, s - take:].astype(COMPUTE_DTYPE))
    else:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(COMPUTE_DTYPE), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(COMPUTE_DTYPE), (0, 0, 0, 0))
    return cache
