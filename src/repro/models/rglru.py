"""RG-LRU recurrent block (Griffin / RecurrentGemma — arXiv:2402.19427).

Block structure (the paper's "recurrent block"):

    x-branch: linear -> causal conv1d(k=4) -> RG-LRU
    y-branch: linear -> GeLU
    merge:    elementwise product -> output linear

RG-LRU recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the affine maps
(h -> a h + b is associative), giving log-depth HLO; decode is the one-step
recurrence. The carried state is fp32 (DESIGN.md §5); all four projections
are CGMQ sites.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext

from .layers import COMPUTE_DTYPE, qmatmul

_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)

    def mk(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    # Lambda init so a^c spans (0.9, 0.999) as in the Griffin paper.
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "wx": mk(ks[0], (d, w), d),           # x-branch in
        "wy": mk(ks[1], (d, w), d),           # y-branch in
        "conv_w": 0.1 * jax.random.normal(ks[2], (cfg.conv_kernel, w)),
        "conv_b": jnp.zeros((w,)),
        "gate_a": mk(ks[3], (w, w), w),       # recurrence gate
        "gate_a_b": jnp.zeros((w,)),
        "gate_x": mk(jax.random.fold_in(ks[3], 1), (w, w), w),
        "gate_x_b": jnp.zeros((w,)),
        "lam": lam,
        "wo": mk(jax.random.fold_in(ks[0], 2), (w, d), w),
    }


def _conv1d(x, conv_w, conv_b, conv_state=None):
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * conv_w[i][None, None, :] for i in range(k)
    ) + conv_b[None, None, :]
    return y, xp[:, -(k - 1) :, :]


def _gates(qc: QuantContext, p, x):
    """x: (B, L, w) -> (a_t, gated input) in fp32."""
    r = qmatmul(qc, "lru_gate_a", x, p["gate_a"]) + p["gate_a_b"].astype(COMPUTE_DTYPE)
    i = qmatmul(qc, "lru_gate_x", x, p["gate_x"]) + p["gate_x_b"].astype(COMPUTE_DTYPE)
    r = jax.nn.sigmoid(r.astype(jnp.float32))
    i = jax.nn.sigmoid(i.astype(jnp.float32))
    r = qc.act("lru_gate_a", r).astype(jnp.float32)
    i = qc.act("lru_gate_x", i).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]).astype(jnp.float32) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * x.astype(jnp.float32)
    return a, b


def rglru_forward(
    qc: QuantContext, p, xin, cfg: ModelConfig, *, conv_state=None, h0=None,
    plan=None,
):
    """Full-sequence recurrent block. xin: (B, L, d) -> (y, (conv_st, h))."""
    x = qmatmul(qc, "lru_x", xin, p["wx"])
    x = qc.act("lru_x", x)
    y_br = qmatmul(qc, "lru_y", xin, p["wy"])
    y_br = jax.nn.gelu(y_br.astype(jnp.float32), approximate=True)
    y_br = qc.act("lru_y", y_br.astype(COMPUTE_DTYPE))

    x, new_conv = _conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    a, b = _gates(qc, p, x)

    if h0 is not None:
        # fold the initial state into the first step: h1 = a1 h0 + b1
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_last = h[:, -1, :]

    merged = (h.astype(COMPUTE_DTYPE)) * y_br  # recurrent output stays fp
    out = qmatmul(qc, "lru_o", merged, p["wo"])
    out = qc.act("lru_o", out)
    return out, (new_conv, h_last)


def rglru_forward_seq(
    qc: QuantContext, p, xin, cfg: ModelConfig, *, conv_state=None, h0=None,
    plan=None,
):
    """Left-fold variant of ``rglru_forward`` for chunk-resumable prefill.

    ``associative_scan``'s combine tree depends on the sequence length, so a
    prompt split into chunks at different boundaries gets bitwise-different
    states out of it. This variant runs the recurrence as a sequential
    ``lax.scan`` (h_t = a_t h_{t-1} + b_t, exactly the decode step's math),
    which makes the carried state a pure left fold over the input — splitting
    the sequence anywhere and threading ``(conv_state, h0)`` across the calls
    reproduces the unsplit result bit-for-bit (DESIGN.md §15). Same quant
    sites and projections as ``rglru_forward``; only the scan differs.
    """
    x = qmatmul(qc, "lru_x", xin, p["wx"])
    x = qc.act("lru_x", x)
    y_br = qmatmul(qc, "lru_y", xin, p["wy"])
    y_br = jax.nn.gelu(y_br.astype(jnp.float32), approximate=True)
    y_br = qc.act("lru_y", y_br.astype(COMPUTE_DTYPE))

    x, new_conv = _conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    a, b = _gates(qc, p, x)

    init = (jnp.zeros_like(b[:, 0, :]) if h0 is None
            else h0.astype(jnp.float32))

    def step(h, ab):
        at, bt = ab
        hn = at * h + bt
        return hn, hn

    h_last, hs = jax.lax.scan(
        step, init, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    h = jnp.moveaxis(hs, 0, 1)

    merged = (h.astype(COMPUTE_DTYPE)) * y_br
    out = qmatmul(qc, "lru_o", merged, p["wo"])
    out = qc.act("lru_o", out)
    return out, (new_conv, h_last)


def rglru_decode_step(
    qc: QuantContext, p, xin, conv_state, h, cfg: ModelConfig, *, plan=None
):
    """One-token step. xin: (B, 1, d). Returns (y, (conv_st, h))."""
    x = qmatmul(qc, "lru_x", xin, p["wx"])
    x = qc.act("lru_x", x)
    y_br = qmatmul(qc, "lru_y", xin, p["wy"])
    y_br = jax.nn.gelu(y_br.astype(jnp.float32), approximate=True)
    y_br = qc.act("lru_y", y_br.astype(COMPUTE_DTYPE))

    x, new_conv = _conv1d(x, p["conv_w"], p["conv_b"], conv_state)
    a, b = _gates(qc, p, x)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]

    merged = h_new[:, None, :].astype(COMPUTE_DTYPE) * y_br
    out = qmatmul(qc, "lru_o", merged, p["wo"])
    out = qc.act("lru_o", out)
    return out, (new_conv, h_new)


def init_rglru_cache(cfg: ModelConfig, batch: int):
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.lru_width), jnp.float32),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
