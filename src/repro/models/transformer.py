"""Config-driven decoder model: the integration layer over all block kinds.

Supports every assigned architecture through ``ModelConfig``:
  * block kinds: 'global' / 'local' attention, 'ssm' (Mamba-2 SSD),
    'recurrent' (RG-LRU) — cycled through ``cfg.block_pattern``.
  * dense GLU MLPs, MoE (+ arctic's dense-residual), gemma2 sandwich norms
    and softcaps, qwen M-RoPE / qk-norm / qkv-bias, modality-stub inputs.

Layer stacking: the repeating pattern is scanned (``lax.scan`` over
``R = n_layers // len(pattern)`` super-blocks, remat'd), which keeps the HLO
compact for 80-layer models; remainder layers are unrolled. Quantization
state (gates / ranges / probes) for scanned sites is stacked along the scan
axis and sliced per layer inside the body; per-layer stats come back as scan
outputs (see core/sites.py child-context protocol).

Entry points:
  init_params(cfg, key)
  forward_train(qc, params, batch, cfg, ...)       -> logits
  prefill(qc, params, batch, cfg, ...)             -> logits, cache
  prefill_slot(qc, params, tokens, plen, cache, slot, cfg, ...)
                                                   -> logits, cache
  decode_step(qc, params, cache, tokens, cfg, ...) -> logits, cache
  init_cache(cfg, batch, max_seq)

The decode cache keeps a per-row ``pos`` vector, so a continuous-batching
engine can hold every serving slot at its own position and still run ONE
jitted decode_step per tick (DESIGN.md §8).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext

from . import attention as attn
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import ssd as ssd_lib
from .layers import COMPUTE_DTYPE, glu_mlp, init_glu_mlp, qmatmul, rms_norm, softcap


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,))}
    if kind in ("global", "local"):
        p["attn"] = attn.init_attn(ks[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,))
        if cfg.n_experts:
            p["moe"] = moe_lib.init_moe(ks[1], cfg)
            if cfg.dense_residual:
                p["mlp"] = init_glu_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp)
        else:
            p["mlp"] = init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp)
        if cfg.post_norm:
            p["ln1_post"] = jnp.zeros((cfg.d_model,))
            p["ln2_post"] = jnp.zeros((cfg.d_model,))
    elif kind == "ssm":
        p["ssd"] = ssd_lib.init_ssd(ks[0], cfg)
    elif kind == "recurrent":
        p["rglru"] = rglru_lib.init_rglru(ks[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,))
        p["mlp"] = init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp)
        if cfg.post_norm:
            p["ln1_post"] = jnp.zeros((cfg.d_model,))
            p["ln2_post"] = jnp.zeros((cfg.d_model,))
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    pat = cfg.block_pattern
    reps = cfg.pattern_repeats
    blocks = []
    for pi, kind in enumerate(pat):
        per_rep = [
            _init_block(keys[r * len(pat) + pi], cfg, kind) for r in range(reps)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    rem = [
        _init_block(keys[reps * len(pat) + i], cfg, kind)
        for i, kind in enumerate(cfg.remainder_kinds)
    ]
    params = {
        "blocks": blocks,
        "rem": rem,
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if cfg.embed_input:
        params["embed"] = (
            jax.random.normal(keys[-1], (cfg.padded_vocab, cfg.d_model)) * 0.02
        ).astype(jnp.float32)
        if not cfg.tie_embeddings:
            params["head"] = (
                jax.random.normal(keys[-2], (cfg.d_model, cfg.padded_vocab)) * 0.02
            ).astype(jnp.float32)
    else:
        # modality stub: frame/patch embeddings come in; output head only
        params["head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.padded_vocab)) * 0.02
        ).astype(jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Blocks (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block_full(qc, bp, h, cfg: ModelConfig, kind: str, *, positions,
                      mrope_pos, plan, moe_impl, init_entry=None,
                      window=None):
    """Full-sequence block application. Returns (h, cache_entry).

    ``init_entry`` threads a slot's carried recurrent state into the chunked
    scans (ssm / recurrent), so a prefill can continue from where a previous
    forward left off — the one-batched-forward SSM tail (DESIGN.md §8).
    Attention blocks have no carried-state analogue here (continuing them
    needs past-KV attention), so they require ``init_entry=None``.
    """
    resid = h
    hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        assert init_entry is None, "attention blocks can't resume mid-prefill"
        with qc.scope("attn"):
            y, (k, v) = attn.attention_train(
                qc, bp["attn"], hn, cfg, kind,
                positions=positions, mrope_pos=mrope_pos, plan=plan,
                window=window,
            )
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            if cfg.n_experts:
                y = moe_lib.moe_ffn(qc, bp["moe"], hn, cfg, impl=moe_impl, plan=plan)
                if cfg.dense_residual:
                    with qc.scope("dense"):
                        y = y + glu_mlp(qc, bp["mlp"], hn, cfg.mlp).astype(y.dtype)
            else:
                y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        cache_entry = {"k": k.astype(COMPUTE_DTYPE), "v": v.astype(COMPUTE_DTYPE)}
    elif kind == "ssm":
        with qc.scope("ssd"):
            y, (conv_st, ssm_st) = ssd_lib.ssd_chunked(
                qc, bp["ssd"], hn, cfg, plan=plan,
                conv_state=None if init_entry is None else init_entry["conv"],
                ssm_state=None if init_entry is None else init_entry["ssm"])
        h = resid + y.astype(resid.dtype)
        cache_entry = {"conv": conv_st.astype(jnp.float32), "ssm": ssm_st}
    elif kind == "recurrent":
        with qc.scope("rglru"):
            y, (conv_st, h_last) = rglru_lib.rglru_forward(
                qc, bp["rglru"], hn, cfg, plan=plan,
                conv_state=None if init_entry is None else init_entry["conv"],
                h0=None if init_entry is None else init_entry["h"])
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        cache_entry = {"conv": conv_st.astype(jnp.float32), "h": h_last}
    else:
        raise ValueError(kind)
    if plan is not None:
        h = plan.shard_hidden(h)
    return h, cache_entry


def _apply_block_decode(qc, bp, h, cache, pos, cfg: ModelConfig, kind: str, *,
                        mrope_pos, plan, block_table=None, write_mask=None,
                        window=None):
    resid = h
    hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        with qc.scope("attn"):
            if block_table is not None:
                y, new_cache = attn.attention_decode_paged(
                    qc, bp["attn"], hn, cache, block_table, pos, cfg, kind,
                    mrope_pos=mrope_pos, plan=plan, write_mask=write_mask,
                    window=window,
                )
            else:
                y, new_cache = attn.attention_decode(
                    qc, bp["attn"], hn, cache, pos, cfg, kind,
                    mrope_pos=mrope_pos, plan=plan, window=window,
                )
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            if cfg.n_experts:
                y = moe_lib.moe_ffn(qc, bp["moe"], hn, cfg, impl="dense_all",
                                    plan=plan)
                if cfg.dense_residual:
                    with qc.scope("dense"):
                        y = y + glu_mlp(qc, bp["mlp"], hn, cfg.mlp).astype(y.dtype)
            else:
                y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
    elif kind == "ssm":
        with qc.scope("ssd"):
            y, (conv_st, ssm_st) = ssd_lib.ssd_decode_step(
                qc, bp["ssd"], hn, cache["conv"], cache["ssm"], cfg, plan=plan)
        h = resid + y.astype(resid.dtype)
        new_cache = {"conv": conv_st.astype(jnp.float32), "ssm": ssm_st}
    elif kind == "recurrent":
        with qc.scope("rglru"):
            y, (conv_st, h_last) = rglru_lib.rglru_decode_step(
                qc, bp["rglru"], hn, cache["conv"], cache["h"], cfg, plan=plan)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        new_cache = {"conv": conv_st.astype(jnp.float32), "h": h_last}
    else:
        raise ValueError(kind)
    return h, new_cache


# ---------------------------------------------------------------------------
# Quantization-state plumbing for the scan
# ---------------------------------------------------------------------------


def _prefixed(d: dict, prefix: str) -> dict:
    return {k: v for k, v in d.items() if k.startswith(prefix)}


def _scan_quant_xs(qc: QuantContext, prefix: str):
    """Per-layer-stacked quant state entering the scan as xs.

    Train mode stacks gates/betas/probes; serve mode stacks the frozen
    ``QuantSpec``s and ``QuantizedTensor``s instead (both are pytrees, so
    ``lax.scan`` slices their per-layer leaves exactly like raw arrays).
    """
    return (
        _prefixed(qc.gates, prefix),
        {k: v["beta"] for k, v in qc.ranges.items() if k.startswith(prefix)},
        _prefixed(qc.probes, prefix),
        _prefixed(qc.qweights, prefix),
        _prefixed(qc.specs, prefix),
    )


def _child_for_slice(qc: QuantContext, gates_s, betas_s, probes_s,
                     qweights_s=None, specs_s=None):
    ranges = dict(qc.ranges)
    for k, b in betas_s.items():
        ranges[k] = {"beta": b, "signed": qc.ranges[k]["signed"]}
    return qc.child(
        gates={**qc.gates, **gates_s},
        ranges=ranges,
        probes={**qc.probes, **probes_s},
        qweights={**qc.qweights, **(qweights_s or {})},
        specs={**qc.specs, **(specs_s or {})},
    )


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(qc: QuantContext, params, batch, cfg: ModelConfig):
    if cfg.embed_input:
        h = jnp.take(params["embed"], batch, axis=0).astype(COMPUTE_DTYPE)
    else:
        h = batch.astype(COMPUTE_DTYPE)  # modality stub: embeddings provided
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model**0.5, COMPUTE_DTYPE)
    return qc.input(h).astype(COMPUTE_DTYPE)


def _head(qc: QuantContext, params, h, cfg: ModelConfig):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["head"] if "head" in params else params["embed"].T
    logits = qmatmul(qc, "head", h, w, act_quantized=False)
    logits = logits.astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    return logits


# ---------------------------------------------------------------------------
# Forward (train) / prefill
# ---------------------------------------------------------------------------


def _forward_full(qc: QuantContext, params, batch, cfg: ModelConfig, *,
                  plan=None, mrope_pos=None, moe_impl="capacity",
                  want_cache=False, remat=True, scan_unroll=False,
                  init_state=None, positions=None, window=None):
    """``init_state``: optional per-layer list (pattern entries stacked along
    the scan axis) of recurrent-state entries to resume from — the SSM
    prefill-tail path (see ``prefill_slot_tail``); ``None`` per layer (or
    entirely) means a fresh sequence. ``positions``: (1, S) absolute
    positions override for continued prefills (attention layers only).
    ``window``: optional static engine ``(window, sink_tokens)`` mask tuple
    (DESIGN.md §17), applied per attention layer kind."""
    h = _embed(qc, params, batch, cfg)
    s = h.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if plan is not None:
        h = plan.shard_hidden(h)

    pat = cfg.block_pattern
    reps = cfg.pattern_repeats
    caches = []

    for pi, kind in enumerate(pat):
        prefix = f"p{pi}_{kind}/"
        gates_xs, betas_xs, probes_xs, qw_xs, sp_xs = _scan_quant_xs(
            qc, prefix)
        init_xs = None if init_state is None else init_state[pi]

        def body(carry, xs, _pi=pi, _kind=kind, _prefix=prefix):
            hh = carry
            bp, g_s, b_s, p_s, qw_s, sp_s, init_s = xs
            sub = _child_for_slice(qc, g_s, b_s, p_s, qw_s, sp_s)
            with sub.scope(_prefix[:-1]):
                hh, cache_entry = _apply_block_full(
                    sub, bp, hh, cfg, _kind, positions=positions,
                    mrope_pos=mrope_pos, plan=plan, moe_impl=moe_impl,
                    init_entry=init_s, window=window,
                )
            out = (sub.act_stats, sub.weight_stats)
            if want_cache:
                out = out + (cache_entry,)
            return hh, out

        if reps == 1:
            # single repeat: quant state is unstacked (no scan axis) — apply
            # the body directly on slice 0 of the (1, ...) param stack.
            bp = jax.tree.map(lambda x: x[0], params["blocks"][pi])
            init_s = (None if init_xs is None
                      else jax.tree.map(lambda x: x[0], init_xs))
            ys = body(h, (bp, gates_xs, betas_xs, probes_xs, qw_xs, sp_xs,
                          init_s))
            h, out = ys
            qc.absorb_stacked_stats(out[0], out[1])
            if want_cache:
                caches.append(jax.tree.map(lambda x: x[None], out[2]))
            continue

        body_fn = jax.checkpoint(body) if remat else body
        unroll = reps if scan_unroll else 1
        if qc.mode in ("collect", "export"):
            # both modes register sites; the stack multiplier must match
            with qc.layer_stack(reps):
                h, ys = jax.lax.scan(
                    body_fn, h,
                    (params["blocks"][pi], gates_xs, betas_xs, probes_xs,
                     qw_xs, sp_xs, init_xs),
                    unroll=unroll,
                )
        else:
            h, ys = jax.lax.scan(
                body_fn, h,
                (params["blocks"][pi], gates_xs, betas_xs, probes_xs, qw_xs,
                 sp_xs, init_xs),
                unroll=unroll,
            )
        qc.absorb_stacked_stats(ys[0], ys[1])
        if want_cache:
            caches.append(ys[2])

    # remainder layers (unrolled)
    for i, kind in enumerate(cfg.remainder_kinds):
        prefix = f"rem{i}_{kind}"
        init_s = None if init_state is None else init_state[len(pat) + i]
        with qc.scope(prefix):
            h, cache_entry = _apply_block_full(
                qc, params["rem"][i], h, cfg, kind, positions=positions,
                mrope_pos=mrope_pos, plan=plan, moe_impl=moe_impl,
                init_entry=init_s, window=window,
            )
        if want_cache:
            caches.append(cache_entry)

    logits = _head(qc, params, h, cfg)
    if want_cache:
        return logits, caches
    return logits


def forward_train(qc: QuantContext, params, batch, cfg: ModelConfig, *,
                  plan=None, mrope_pos=None, moe_impl="capacity", remat=True,
                  scan_unroll=False):
    return _forward_full(qc, params, batch, cfg, plan=plan, mrope_pos=mrope_pos,
                         moe_impl=moe_impl, want_cache=False, remat=remat,
                         scan_unroll=scan_unroll)


def prefill(qc: QuantContext, params, batch, cfg: ModelConfig, *, max_seq: int,
            plan=None, mrope_pos=None, moe_impl="capacity", scan_unroll=False):
    """Forward + build the decode cache. Returns (logits, cache)."""
    logits, raw = _forward_full(
        qc, params, batch, cfg, plan=plan, mrope_pos=mrope_pos,
        moe_impl=moe_impl, want_cache=True, remat=False,
        scan_unroll=scan_unroll,
    )
    b = batch.shape[0]
    cache = {"pos": jnp.full((b,), batch.shape[1], jnp.int32), "layers": []}
    pat = cfg.block_pattern
    for pi, kind in enumerate(pat):
        entry = raw[pi]
        if kind in ("global", "local"):
            # stacked (R, B, S, KV, hd) -> per-rep ring/full caches
            built = jax.vmap(
                lambda k, v: attn.fill_cache_from_prefill(cfg, kind, k, v, max_seq)
            )(entry["k"], entry["v"])
            cache["layers"].append(built)
        else:
            cache["layers"].append(entry)
    for i, kind in enumerate(cfg.remainder_kinds):
        entry = raw[len(pat) + i]
        if kind in ("global", "local"):
            cache["layers"].append(
                attn.fill_cache_from_prefill(cfg, kind, entry["k"], entry["v"],
                                             max_seq)
            )
        else:
            cache["layers"].append(entry)
    return logits, cache


def _write_state_slot(lc, entry, slot, stacked: bool):
    """Write one slot's recurrent state (ssm/rglru) into the multi-slot cache.

    ``entry`` leaves have batch dim 1 where ``lc`` has the slot count; the
    batch axis is 1 for scan-stacked layers (leading R axis), else 0.
    """
    ax = 1 if stacked else 0

    def upd(c, e):
        start = [0] * c.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(c, e.astype(c.dtype), tuple(start))

    return jax.tree.map(upd, lc, entry)


def prefill_slot(qc: QuantContext, params, tokens, plen, cache, slot,
                 cfg: ModelConfig, *, plan=None, mrope_pos=None,
                 moe_impl="dense_all", scan_unroll=False, block_table=None,
                 start_blk=0, window=None):
    """True batched prefill for one serving slot (DESIGN.md §8).

    Runs the whole (right-padded) prompt through ONE causal forward and
    writes the slot's KV range / recurrent state in one shot — replacing the
    engine's old scan-of-decode-steps prefill, which cost
    O(prompt_len x slots) decode forwards per admission.

    ``tokens``: (1, S_pad) int32 (or (1, S_pad, d) embeddings for
    stub-modality models); ``plen``/``slot`` scalar int32 (may be traced).
    Only row ``slot`` of ``cache`` is touched; its pos is set to ``plen``.
    Returns (logits (1, S_pad, V), cache) — the slot's first generated token
    is ``argmax(logits[0, plen - 1])``.

    With ``block_table`` (paged layout, DESIGN.md §10), attention K/V is
    scattered into the layer block pools as whole blocks at the physical ids
    in the slot's table row; logical blocks below ``start_blk`` (a shared
    prompt prefix already resident in the pool) are skipped. The caller must
    have allocated blocks ``start_blk .. ceil(plen/bs)-1`` for the slot.
    """
    logits, raw = _forward_full(
        qc, params, tokens, cfg, plan=plan, mrope_pos=mrope_pos,
        moe_impl=moe_impl, want_cache=True, remat=False,
        scan_unroll=scan_unroll, window=window,
    )
    plen = jnp.asarray(plen, jnp.int32)
    pat = cfg.block_pattern
    kinds = list(pat) + list(cfg.remainder_kinds)
    new_layers = []
    for li, kind in enumerate(kinds):
        entry = raw[li]
        lc = cache["layers"][li]
        stacked = li < len(pat)  # pattern entries carry the scan (R) axis
        if kind in ("global", "local"):
            if block_table is None:
                new_layers.append(
                    attn.write_prefill_slot(cfg, kind, lc, entry["k"],
                                            entry["v"], slot, plen))
            else:
                from repro.serving import kv_pool

                bs = lc["k"].shape[-3]
                nblk = (plen + bs - 1) // bs
                k = entry["k"][:, 0] if stacked else entry["k"][0]
                v = entry["v"][:, 0] if stacked else entry["v"][0]
                new_layers.append(kv_pool.write_prompt_blocks(
                    lc, k, v, block_table[slot], start_blk, nblk, bs))
        else:
            new_layers.append(_write_state_slot(lc, entry, slot, stacked))
    pos = cache["pos"].at[slot].set(plen)
    return logits, {"pos": pos, "layers": new_layers}


def _slice_state_slot(lc, slot, stacked: bool):
    """Read one slot's recurrent-state entry out of the multi-slot cache."""
    ax = 1 if stacked else 0

    def rd(c):
        start = [0] * c.ndim
        start[ax] = slot
        size = list(c.shape)
        size[ax] = 1
        return jax.lax.dynamic_slice(c, tuple(start), tuple(size))

    return jax.tree.map(rd, lc)


def prefill_slot_tail(qc: QuantContext, params, tokens, cache, slot,
                      cfg: ModelConfig, *, plan=None, moe_impl="dense_all"):
    """Absorb a prefill's sub-chunk remainder in ONE batched forward.

    ``ssd_chunked`` requires chunk-multiple lengths, so SSM prompts prefill
    their largest chunk-aligned prefix via ``prefill_slot`` and then continue
    here: the slot's carried recurrent state (conv tail + SSM state) is read
    out of the cache, threaded into a second forward over the ``tokens``
    remainder (< ssm_chunk of them), and the updated state written back —
    replacing the seed's teacher-forced single decode steps (DESIGN.md §8).
    Recurrent-state architectures only; attention blocks would need past-KV
    attention, which this path deliberately does not implement.

    ``tokens``: (1, r); slot pos advances by r. Returns (logits, cache);
    the slot's first generated token is ``argmax(logits[0, -1])``.
    """
    pat = cfg.block_pattern
    kinds = list(pat) + list(cfg.remainder_kinds)
    assert all(k in ("ssm", "recurrent") for k in kinds), \
        "tail prefill requires a recurrent-state-only architecture"
    init_state = [
        _slice_state_slot(cache["layers"][li], slot, li < len(pat))
        for li in range(len(kinds))
    ]
    r = tokens.shape[1]
    start = cache["pos"][slot]
    positions = (start + jnp.arange(r))[None, :]
    logits, raw = _forward_full(
        qc, params, tokens, cfg, plan=plan, moe_impl=moe_impl,
        want_cache=True, remat=False, init_state=init_state,
        positions=positions,
    )
    new_layers = [
        _write_state_slot(cache["layers"][li], raw[li], slot, li < len(pat))
        for li in range(len(kinds))
    ]
    pos = cache["pos"].at[slot].add(r)
    return logits, {"pos": pos, "layers": new_layers}


def _apply_block_chunk(qc, bp, h, lc, cfg: ModelConfig, kind: str, *, slot,
                       pos0, clen, fresh, positions, mrope_pos, plan,
                       block_row, window=None):
    """One block of a chunk-resumable prefill (DESIGN.md §15).

    Attention blocks write the chunk's K/V into the slot's cache at its
    offset and attend through the cache (``attn.attention_prefill_chunk``);
    recurrent blocks run the chunk-invariant full-sequence paths
    (``ssd_chunked`` inter-chunk left fold / ``rglru_forward_seq``) threading
    the slot's carried state — zeroed when ``fresh`` so the first chunk can
    never see a previous occupant's state. Returns (h, new_cache_entry).
    """
    resid = h
    hn = rms_norm(h, bp["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        with qc.scope("attn"):
            y, nc = attn.attention_prefill_chunk(
                qc, bp["attn"], hn, lc, pos0, clen, cfg, kind, slot=slot,
                block_table=block_row, positions=positions,
                mrope_pos=mrope_pos, plan=plan, window=window,
            )
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            if cfg.n_experts:
                y = moe_lib.moe_ffn(qc, bp["moe"], hn, cfg, impl="dense_all",
                                    plan=plan)
                if cfg.dense_residual:
                    with qc.scope("dense"):
                        y = y + glu_mlp(qc, bp["mlp"], hn, cfg.mlp).astype(y.dtype)
            else:
                y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
    elif kind == "ssm":
        entry = jax.tree.map(
            lambda s: jnp.where(fresh, jnp.zeros_like(s), s),
            _slice_state_slot(lc, slot, stacked=False))
        with qc.scope("ssd"):
            y, (conv_st, ssm_st) = ssd_lib.ssd_chunked(
                qc, bp["ssd"], hn, cfg, plan=plan,
                conv_state=entry["conv"], ssm_state=entry["ssm"])
        h = resid + y.astype(resid.dtype)
        nc = _write_state_slot(
            lc, {"conv": conv_st.astype(jnp.float32), "ssm": ssm_st},
            slot, stacked=False)
    elif kind == "recurrent":
        entry = jax.tree.map(
            lambda s: jnp.where(fresh, jnp.zeros_like(s), s),
            _slice_state_slot(lc, slot, stacked=False))
        with qc.scope("rglru"):
            y, (conv_st, h_last) = rglru_lib.rglru_forward_seq(
                qc, bp["rglru"], hn, cfg, plan=plan,
                conv_state=entry["conv"], h0=entry["h"])
        if cfg.post_norm:
            y = rms_norm(y, bp["ln1_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        resid = h
        hn = rms_norm(h, bp["ln2"], cfg.norm_eps)
        with qc.scope("ffn"):
            y = glu_mlp(qc, bp["mlp"], hn, cfg.mlp)
        if cfg.post_norm:
            y = rms_norm(y, bp["ln2_post"], cfg.norm_eps)
        h = resid + y.astype(resid.dtype)
        nc = _write_state_slot(
            lc, {"conv": conv_st.astype(jnp.float32), "h": h_last},
            slot, stacked=False)
    else:
        raise ValueError(kind)
    if plan is not None:
        h = plan.shard_hidden(h)
    return h, nc


def prefill_chunk(qc: QuantContext, params, tokens, clen, cache, slot,
                  cfg: ModelConfig, *, pos0=0, plan=None, mrope_pos=None,
                  scan_unroll=False, block_table=None, window=None):
    """Chunk-resumable prefill (DESIGN.md §15): run ``clen`` prompt tokens at
    absolute positions ``pos0 .. pos0+clen-1`` through the full stack for ONE
    serving slot, writing attention K/V into the slot's cache at its offset
    (ring or paged) and threading recurrent state across chunks (fresh at
    ``pos0 == 0``).

    The chunk paths are chunk-split-invariant by construction — attention
    attends through the cache over a static key axis, ssm chunks align to
    ``ssm_chunk`` (the engine's chunk planner guarantees this), rglru uses
    the sequential left fold — so any sequence of chunk sizes produces
    bit-identical caches and logits to one whole-prompt call of this same
    function. ``tokens``: (1, C) int32 (or (1, C, d) embeddings), lanes past
    ``clen`` padding; ``pos0``/``clen``/``slot`` may be traced. The slot's
    pos is set to ``pos0 + clen``. Returns (logits (1, C, V), cache) — after
    the FINAL chunk the first generated token comes from row ``clen - 1``.
    """
    pos0 = jnp.asarray(pos0, jnp.int32)
    clen = jnp.asarray(clen, jnp.int32)
    h = _embed(qc, params, tokens, cfg)
    c = h.shape[1]
    positions = (pos0 + jnp.arange(c))[None, :]
    mp = None
    if cfg.mrope_sections is not None:
        mp = (mrope_pos if mrope_pos is not None
              else jnp.broadcast_to(positions[None], (3, 1, c)))
    if plan is not None:
        h = plan.shard_hidden(h)
    fresh = pos0 == 0
    block_row = None if block_table is None else block_table[slot]

    pat = cfg.block_pattern
    new_layers = []
    for pi, kind in enumerate(pat):
        prefix = f"p{pi}_{kind}/"
        gates_xs, betas_xs, probes_xs, qw_xs, sp_xs = _scan_quant_xs(
            qc, prefix)

        def body(carry, xs, _kind=kind, _prefix=prefix):
            hh = carry
            bp, lc, g_s, b_s, p_s, qw_s, sp_s = xs
            sub = _child_for_slice(qc, g_s, b_s, p_s, qw_s, sp_s)
            with sub.scope(_prefix[:-1]):
                hh, nc = _apply_block_chunk(
                    sub, bp, hh, lc, cfg, _kind, slot=slot, pos0=pos0,
                    clen=clen, fresh=fresh, positions=positions,
                    mrope_pos=mp, plan=plan, block_row=block_row,
                    window=window,
                )
            return hh, nc

        if cfg.pattern_repeats == 1:
            bp = jax.tree.map(lambda x: x[0], params["blocks"][pi])
            lc = jax.tree.map(lambda x: x[0], cache["layers"][pi])
            h, nc = body(h, (bp, lc, gates_xs, betas_xs, probes_xs, qw_xs,
                             sp_xs))
            new_layers.append(jax.tree.map(lambda x: x[None], nc))
            continue

        unroll = cfg.pattern_repeats if scan_unroll else 1
        if qc.mode in ("collect", "export"):
            with qc.layer_stack(cfg.pattern_repeats):
                h, nc = jax.lax.scan(
                    body, h,
                    (params["blocks"][pi], cache["layers"][pi], gates_xs,
                     betas_xs, probes_xs, qw_xs, sp_xs), unroll=unroll,
                )
        else:
            h, nc = jax.lax.scan(
                body, h,
                (params["blocks"][pi], cache["layers"][pi], gates_xs,
                 betas_xs, probes_xs, qw_xs, sp_xs), unroll=unroll,
            )
        new_layers.append(nc)

    for i, kind in enumerate(cfg.remainder_kinds):
        prefix = f"rem{i}_{kind}"
        with qc.scope(prefix):
            h, nc = _apply_block_chunk(
                qc, params["rem"][i], h, cache["layers"][len(pat) + i], cfg,
                kind, slot=slot, pos0=pos0, clen=clen, fresh=fresh,
                positions=positions, mrope_pos=mp, plan=plan,
                block_row=block_row, window=window,
            )
        new_layers.append(nc)

    pos = cache["pos"].at[slot].set(pos0 + clen)
    logits = _head(qc, params, h, cfg)
    return logits, {"pos": pos, "layers": new_layers}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               kv_dtype=jnp.bfloat16, kv_spec=None):
    """Ring/contiguous decode cache. ``kv_dtype`` sets the float KV storage
    (bf16 default, fp32 oracle); ``kv_spec`` (a ``quant.KVQuantSpec``)
    switches attention entries to quantized storage instead (DESIGN.md §14).
    """
    pat = cfg.block_pattern
    reps = cfg.pattern_repeats
    layers = []
    for kind in pat:
        if kind in ("global", "local"):
            one = attn.init_attn_cache(cfg, kind, batch, max_seq,
                                       dtype=kv_dtype, spec=kv_spec)
        elif kind == "ssm":
            one = ssd_lib.init_ssd_cache(cfg, batch)
        else:
            one = rglru_lib.init_rglru_cache(cfg, batch)
        layers.append(jax.tree.map(lambda x: jnp.stack([x] * reps), one))
    for kind in cfg.remainder_kinds:
        if kind in ("global", "local"):
            layers.append(attn.init_attn_cache(cfg, kind, batch, max_seq,
                                               dtype=kv_dtype, spec=kv_spec))
        elif kind == "ssm":
            layers.append(ssd_lib.init_ssd_cache(cfg, batch))
        else:
            layers.append(rglru_lib.init_rglru_cache(cfg, batch))
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": layers}


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, kv_dtype=jnp.bfloat16, kv_spec=None):
    """Decode cache with paged attention layers (DESIGN.md §10).

    Attention entries are physical block pools ``(R?, num_blocks, bs, KV,
    hd)`` addressed through the engine's shared block table; recurrent-state
    entries stay per-slot rows exactly as in ``init_cache``. Local
    (sliding-window) layers page full history like global ones and mask to
    the window at attend time. ``kv_dtype``/``kv_spec`` select the pool
    storage exactly as in ``init_cache`` (quantized pools carry packed codes
    + fp16 group scales, DESIGN.md §14).
    """
    from repro.serving import kv_pool

    pat = cfg.block_pattern
    reps = cfg.pattern_repeats
    layers = []
    for kind in pat:
        if kind in ("global", "local"):
            one = kv_pool.init_pool(cfg, num_blocks, block_size,
                                    dtype=kv_dtype, spec=kv_spec)
        elif kind == "ssm":
            one = ssd_lib.init_ssd_cache(cfg, batch)
        else:
            one = rglru_lib.init_rglru_cache(cfg, batch)
        layers.append(jax.tree.map(lambda x: jnp.stack([x] * reps), one))
    for kind in cfg.remainder_kinds:
        if kind in ("global", "local"):
            layers.append(kv_pool.init_pool(cfg, num_blocks, block_size,
                                            dtype=kv_dtype, spec=kv_spec))
        elif kind == "ssm":
            layers.append(ssd_lib.init_ssd_cache(cfg, batch))
        else:
            layers.append(rglru_lib.init_rglru_cache(cfg, batch))
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": layers}


def decode_step(qc: QuantContext, params, cache, tokens, cfg: ModelConfig, *,
                plan=None, mrope_pos=None, scan_unroll=False, advance=None,
                block_table=None, window=None):
    """One decode step for the whole batch. tokens: (B,) int32 or (B,1,d)
    embeddings for stub-modality models. ``cache["pos"]`` is per-row (B,), so
    slots of a continuous-batching engine decode at independent positions.
    ``advance`` (optional (B,) bool/int) selects which rows bump their
    position — inactive serving slots pass 0 and stay put (their KV write
    lands at their frozen position and is re-overwritten, never attended).

    ``block_table`` ((B, max_blocks) int32) switches attention layers to the
    paged KV pools of an ``init_paged_cache`` cache (DESIGN.md §10). Paged
    pool writes are additionally gated by ``advance``: unlike the ring
    layout, pool blocks are shared hardware, so a row that isn't advancing
    must not touch them (its write is routed to the garbage block).

    Returns (logits (B, 1, V), cache). Token choice is the CALLER's seam:
    the serving tick samples (or argmaxes) from the returned logits inside
    the same jit (DESIGN.md §12), so this function stays sampling-agnostic
    in both KV layouts."""
    pos = cache["pos"]
    write_mask = None
    if block_table is not None and advance is not None:
        write_mask = advance.astype(bool)
    if cfg.embed_input:
        batch = tokens[:, None]
    else:
        batch = tokens
    h = _embed(qc, params, batch, cfg)

    pat = cfg.block_pattern
    new_layers = []
    for pi, kind in enumerate(pat):
        prefix = f"p{pi}_{kind}/"
        gates_xs, betas_xs, probes_xs, qw_xs, sp_xs = _scan_quant_xs(
            qc, prefix)

        def body(carry, xs, _kind=kind, _prefix=prefix):
            hh = carry
            bp, lc, g_s, b_s, p_s, qw_s, sp_s = xs
            sub = _child_for_slice(qc, g_s, b_s, p_s, qw_s, sp_s)
            with sub.scope(_prefix[:-1]):
                hh, nc = _apply_block_decode(
                    sub, bp, hh, lc, pos, cfg, _kind,
                    mrope_pos=mrope_pos, plan=plan,
                    block_table=block_table, write_mask=write_mask,
                    window=window,
                )
            return hh, nc

        if cfg.pattern_repeats == 1:
            bp = jax.tree.map(lambda x: x[0], params["blocks"][pi])
            lc = jax.tree.map(lambda x: x[0], cache["layers"][pi])
            h, nc = body(h, (bp, lc, gates_xs, betas_xs, probes_xs, qw_xs,
                             sp_xs))
            new_layers.append(jax.tree.map(lambda x: x[None], nc))
            continue

        unroll = cfg.pattern_repeats if scan_unroll else 1
        if qc.mode in ("collect", "export"):
            with qc.layer_stack(cfg.pattern_repeats):
                h, nc = jax.lax.scan(
                    body, h,
                    (params["blocks"][pi], cache["layers"][pi], gates_xs,
                     betas_xs, probes_xs, qw_xs, sp_xs), unroll=unroll,
                )
        else:
            h, nc = jax.lax.scan(
                body, h,
                (params["blocks"][pi], cache["layers"][pi], gates_xs,
                 betas_xs, probes_xs, qw_xs, sp_xs), unroll=unroll,
            )
        new_layers.append(nc)

    for i, kind in enumerate(cfg.remainder_kinds):
        prefix = f"rem{i}_{kind}"
        with qc.scope(prefix):
            h, nc = _apply_block_decode(
                qc, params["rem"][i], h, cache["layers"][len(pat) + i], pos,
                cfg, kind, mrope_pos=mrope_pos, plan=plan,
                block_table=block_table, write_mask=write_mask,
                window=window,
            )
        new_layers.append(nc)

    if advance is not None:
        # Non-advancing rows must be complete no-ops. Attention caches need
        # no gating: a frozen row rewrites the same (pos, K, V) and the mask
        # never admits anything new. Recurrent states are unconditional
        # scans, so an ungated row would keep integrating its stale token —
        # keep the old state for rows that didn't advance.
        adv_b = advance.astype(bool)
        kinds = list(pat) + list(cfg.remainder_kinds)
        for li, kind in enumerate(kinds):
            if kind in ("global", "local"):
                continue
            ax = 1 if li < len(pat) else 0  # batch axis (scan-stacked or not)

            def keep_old(o, n, _ax=ax):
                shp = [1] * n.ndim
                shp[_ax] = n.shape[_ax]
                return jnp.where(adv_b.reshape(shp), n, o)

            new_layers[li] = jax.tree.map(keep_old, cache["layers"][li],
                                          new_layers[li])

    logits = _head(qc, params, h, cfg)
    adv = 1 if advance is None else advance.astype(pos.dtype)
    return logits, {"pos": pos + adv, "layers": new_layers}
