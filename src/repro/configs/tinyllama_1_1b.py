"""--arch tinyllama-1.1b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import TINYLLAMA_1B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("tinyllama-1.1b")
