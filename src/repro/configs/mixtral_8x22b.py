"""--arch mixtral-8x22b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import MIXTRAL_8X22B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("mixtral-8x22b")
