"""Model + shape configuration dataclasses and the architecture registry."""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    qkv_bias: bool = False
    qk_norm: bool = False
    # layer-kind pattern cycled over depth: 'global' | 'local' | 'recurrent' | 'ssm'
    block_pattern: tuple[str, ...] = ("global",)
    window: int = 4096
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE (sums to head_dim/2)
    post_norm: bool = False        # gemma2 sandwich norms
    scale_embed: bool = False      # gemma2 multiplies embeddings by sqrt(d)
    # --- MLP ---
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False   # arctic: dense MLP parallel to MoE
    moe_group: int = 1024          # capacity-dispatch token group size
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4
    # --- RG-LRU (recurrentgemma / griffin) ---
    lru_width: int = 0
    # --- embeddings / io ---
    tie_embeddings: bool = True
    embed_input: bool = True       # False: modality stub — forward takes embeddings
    norm_eps: float = 1e-6
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def remainder_kinds(self) -> tuple[str, ...]:
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    def layer_kinds(self) -> list[str]:
        p = len(self.block_pattern)
        return [self.block_pattern[i % p] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        for kind in self.layer_kinds():
            if kind in ("global", "local"):
                qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
                o = self.n_heads * hd * d
                n += qkv + o
                if self.qkv_bias:
                    n += hd * (self.n_heads + 2 * self.n_kv_heads)
                n += 2 * d  # norms
                n += self._mlp_params()
            elif kind == "ssm":
                din, st, h = self.d_inner, self.ssm_state, self.ssm_heads
                proj_in = d * (2 * din + 2 * st + h)
                n += proj_in + din * d  # in/out proj
                n += self.conv_kernel * (din + 2 * st)  # depthwise conv
                n += 3 * h + din + d  # A_log, D, dt_bias, gated norm, ln
            elif kind == "recurrent":
                w = self.lru_width
                n += d * w * 2 + w * d  # x/y branches + out
                n += 2 * w * w + 3 * w  # gates + lambda + conv-ish
                n += self.conv_kernel * w + d
                n += self._mlp_params()  # hybrid blocks keep their MLP
        n += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        n += d  # final norm
        return n

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.n_experts:
            e = self.n_experts * 3 * d * self.moe_dff + d * self.n_experts
            if self.dense_residual:
                e += 3 * d * self.d_ff
            return e
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        return mult * d * self.d_ff

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for k in self.layer_kinds() if k in ("global", "local"))
        per_layer_moe = self.n_experts * 3 * self.d_model * self.moe_dff
        active = self.top_k * 3 * self.d_model * self.moe_dff
        return full - moe_layers * (per_layer_moe - active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs with a sub-quadratic long-context mechanism run long_500k (DESIGN.md §6).
LONG_CONTEXT_OK = {"gemma2-2b", "mamba2-1.3b", "mixtral-8x22b", "recurrentgemma-2b"}


def flops_per_token_train(cfg: ModelConfig, seq_len: int) -> float:
    """6*N_active*D-style estimate plus attention term, per token."""
    n = cfg.active_param_count()
    base = 6.0 * n
    # attention: 12 * L_attn * H * hd * seq (fwd+bwd, causal halves it)
    attn_layers = sum(1 for k in cfg.layer_kinds() if k in ("global", "local"))
    base += 12.0 * attn_layers * cfg.n_heads * cfg.head_dim * seq_len / 2
    return base


def tokens_per_batch(shape: ShapeConfig) -> int:
    if shape.kind == "decode":
        return shape.global_batch
    return shape.global_batch * shape.seq_len


def hbm_param_bytes(cfg: ModelConfig, dtype_bytes: int = 4) -> float:
    return cfg.param_count() * dtype_bytes


def fmt_count(n: float) -> str:
    for unit, div in (("T", 1e12), ("B", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(n) >= div:
            return f"{n/div:.2f}{unit}"
    return str(n)
