"""--arch qwen2-vl-72b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import QWEN2VL_72B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("qwen2-vl-72b")
