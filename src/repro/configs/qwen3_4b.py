"""--arch qwen3-4b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import QWEN3_4B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("qwen3-4b")
