"""--arch qwen1.5-110b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import QWEN15_110B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("qwen1.5-110b")
