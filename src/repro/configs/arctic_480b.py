"""--arch arctic-480b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import ARCTIC_480B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("arctic-480b")
