"""Architecture registry: the 10 assigned configs + reduced smoke variants.

Every entry is exact per the assignment table (sources noted inline).
``get_config(name)`` returns the full config; ``get_smoke_config(name)``
returns a reduced same-family variant for CPU tests. Individual
``configs/<id>.py`` modules re-export each config for --arch loading.
"""

from __future__ import annotations

import dataclasses

from .base import LONG_CONTEXT_OK, SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_REGISTRY: dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


# --- dense LM family -------------------------------------------------------

QWEN15_110B = _register(ModelConfig(
    # [hf:Qwen/Qwen1.5-110B] 80L d8192 64H GQA(kv=8) ff49152 v152064, QKV bias
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064, qkv_bias=True, mlp="swiglu",
    rope_theta=1_000_000.0, tie_embeddings=False,
))

GEMMA2_2B = _register(ModelConfig(
    # [arXiv:2408.00118] 26L d2304 8H GQA(kv=4) ff9216 v256000,
    # local+global alternating, logit softcap, sandwich norms
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000, mlp="geglu",
    block_pattern=("local", "global"), window=4096,
    logit_softcap=30.0, attn_softcap=50.0, post_norm=True, scale_embed=True,
))

TINYLLAMA_1B = _register(ModelConfig(
    # [arXiv:2401.02385] 22L d2048 32H GQA(kv=4) ff5632 v32000
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000, mlp="swiglu",
))

QWEN3_4B = _register(ModelConfig(
    # [hf:Qwen/Qwen3-4B] 36L d2560 32H GQA(kv=8) ff9728 v151936, qk-norm
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, qk_norm=True, mlp="swiglu",
    rope_theta=1_000_000.0, tie_embeddings=True,
))

# --- SSM ---------------------------------------------------------------------

MAMBA2_1B = _register(ModelConfig(
    # [arXiv:2405.21060] 48L d2048 attn-free v50280, SSD state=128
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280, block_pattern=("ssm",),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64, conv_kernel=4,
))

# --- VLM (backbone; patch frontend stubbed) ----------------------------------

QWEN2VL_72B = _register(ModelConfig(
    # [arXiv:2409.12191] 80L d8192 64H GQA(kv=8) ff29568 v152064, M-RoPE
    name="qwen2-vl-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064, qkv_bias=True, mlp="swiglu",
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    embed_input=False, tie_embeddings=False,
))

# --- MoE ----------------------------------------------------------------------

MIXTRAL_8X22B = _register(ModelConfig(
    # [arXiv:2401.04088] 56L d6144 48H GQA(kv=8) ff16384, 8 experts top-2, SWA
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32768, mlp="swiglu",
    block_pattern=("local",), window=4096,
    n_experts=8, top_k=2, moe_dff=16384, tie_embeddings=False,
))

ARCTIC_480B = _register(ModelConfig(
    # [hf:Snowflake/snowflake-arctic-base] 35L d7168 56H GQA(kv=8) ff4864,
    # MoE 128 experts top-2 + dense residual
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000, mlp="swiglu",
    n_experts=128, top_k=2, moe_dff=4864, dense_residual=True,
    tie_embeddings=False,
))

# --- audio (decoder over EnCodec tokens; frontend stubbed) --------------------

MUSICGEN_LARGE = _register(ModelConfig(
    # [arXiv:2306.05284] 48L d2048 32H (kv=32: MHA) ff8192 v2048
    name="musicgen-large", family="dense",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, mlp="gelu",
    embed_input=False, tie_embeddings=False,
))

# --- hybrid ---------------------------------------------------------------------

RECURRENTGEMMA_2B = _register(ModelConfig(
    # [arXiv:2402.19427] 26L d2560 10H (kv=1: MQA) ff7680 v256000,
    # RG-LRU + local attn at 1:2 (pattern R,R,A; 26 = 8*3 + 2 remainder)
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, mlp="geglu",
    block_pattern=("recurrent", "recurrent", "local"), window=2048,
    lru_width=2560, conv_kernel=4, scale_embed=True,
))

ALL_ARCHS = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_smoke_config(name[: -len("-smoke")])
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few layers/experts."""
    cfg = _REGISTRY[name]
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = max(1, min(cfg.n_kv_heads, heads // 2)) if cfg.n_kv_heads else 0
    pat_len = len(cfg.block_pattern)
    # two pattern repeats, plus a remainder layer if the full config has one
    n_layers = pat_len * 2 + (1 if cfg.n_layers % pat_len else 0)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=277,  # deliberately not a multiple of the pad
        window=8,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_dff=64 if cfg.moe_dff else 0,
        moe_group=32,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        lru_width=64 if cfg.lru_width else 0,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
        vocab_pad_multiple=32,
    )


def shape_cells(arch: str) -> list[str]:
    """The dry-run shape names applicable to this arch (DESIGN.md §6)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        cells.append("long_500k")
    return cells
