"""--arch gemma2-2b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import GEMMA2_2B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("gemma2-2b")
