"""--arch mamba2-1.3b — re-export of the registry entry (see configs/__init__)."""
from repro.configs import MAMBA2_1B as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("mamba2-1.3b")
