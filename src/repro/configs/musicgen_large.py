"""--arch musicgen-large — re-export of the registry entry (see configs/__init__)."""
from repro.configs import MUSICGEN_LARGE as CONFIG  # noqa: F401
from repro.configs import get_smoke_config

SMOKE = get_smoke_config("musicgen-large")
