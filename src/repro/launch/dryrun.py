import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (required deliverable (e)).

For every (architecture x input shape x mesh) cell: build the production
mesh, attach shardings to abstract inputs (ShapeDtypeStruct — nothing is
allocated), ``jax.jit(step).lower(...).compile()``, and record
``memory_analysis()`` / ``cost_analysis()`` / the collective bytes parsed
from the compiled HLO into artifacts/dryrun/<cell>.json.

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first backend init); nothing else in the package sets it.

Cost accounting: XLA's cost analysis counts a ``while``-loop body ONCE
(verified empirically), so the scanned full-depth program under-reports
flops/bytes/collectives by ~n_layers. We therefore compile three variants
per cell:

  full   — real depth, scanned: memory_analysis (peak bytes are exact:
           the backward carries scale with depth) + compile sanity;
  d1/d2  — depth = P+rem / 2P+rem pattern repeats with the scan fully
           unrolled: per-repeat costs are depth-independent, so
           ``cost_full = cost_d1 + (R-1) * (cost_d2 - cost_d1)`` is exact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_cells  # noqa: E402
from repro.configs.base import flops_per_token_train, tokens_per_batch  # noqa: E402
from repro.distributed.sharding import ShardingPlan  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import batch_axes_of, make_production_mesh  # noqa: E402
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts",
                   "dryrun")


def _compile_variant(cfg, shape, plan, *, quant_impl, scan_unroll,
                     variant_overrides=None, serve_dtype=None):
    """Lower+compile one step program; returns (compiled, cost, coll, mem)."""
    recipe = steps_lib.make_recipe(cfg, shape, quant_impl=quant_impl,
                                   scan_unroll=scan_unroll,
                                   **(variant_overrides or {}))
    if shape.kind == "train":
        state, batch = steps_lib.abstract_train_args(recipe, shape, plan)
        step = steps_lib.make_train_step(recipe, plan)
        lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
    elif shape.kind == "prefill":
        params, _, _ = steps_lib.abstract_serve_args(
            recipe, shape, plan, max_seq=shape.seq_len,
            serve_dtype=serve_dtype)
        batch = steps_lib._abstract_batch(
            cfg, shape.global_batch, shape.seq_len, targets=False)
        batch_sh = plan.batch_dict_shardings(batch)
        batch = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_sh[k])
            for k, v in batch.items()
        }
        step = steps_lib.make_prefill_step(recipe, plan, max_seq=shape.seq_len)
        lowered = jax.jit(step).lower(params, batch)
    else:  # decode
        params, cache, tokens = steps_lib.abstract_serve_args(
            recipe, shape, plan, max_seq=shape.seq_len,
            serve_dtype=serve_dtype)
        step = steps_lib.make_decode_step(recipe, plan)
        lowered = jax.jit(step, donate_argnums=(1,)).lower(
            params, cache, tokens)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    cost = {"flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0)}
    coll = collective_bytes_from_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return compiled, cost, coll, mem


def _depth_cfg(cfg, repeats: int):
    pat = len(cfg.block_pattern)
    rem = cfg.n_layers % pat
    return dataclasses.replace(cfg, n_layers=pat * repeats + rem)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             quant_impl: str = "direct", variant: str = "base",
             seq_shard_batch1: bool = True, out_dir: str = ART,
             recipe_overrides=None, plan_overrides=None,
             serve_dtype=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    def _plan(c):
        kw = dict(
            mesh=mesh, cfg=c, batch_axes=batch_axes_of(mesh),
            seq_shard_batch1=(shape.global_batch == 1 and seq_shard_batch1),
        )
        kw.update(plan_overrides or {})
        return ShardingPlan(**kw)

    t0 = time.time()
    # full-depth scanned compile: memory truth + proof the cell lowers
    _, cost_raw, coll_raw, mem = _compile_variant(
        cfg, shape, _plan(cfg), quant_impl=quant_impl, scan_unroll=False,
        variant_overrides=recipe_overrides, serve_dtype=serve_dtype)
    t_full = time.time() - t0
    print(mem)  # proves it fits (per-device bytes)

    # depth-extrapolated exact costs
    reps = cfg.pattern_repeats
    c1 = _depth_cfg(cfg, 1)
    c2 = _depth_cfg(cfg, 2)
    _, cost1, coll1, _ = _compile_variant(
        c1, shape, _plan(c1), quant_impl=quant_impl, scan_unroll=True,
        variant_overrides=recipe_overrides, serve_dtype=serve_dtype)
    _, cost2, coll2, _ = _compile_variant(
        c2, shape, _plan(c2), quant_impl=quant_impl, scan_unroll=True,
        variant_overrides=recipe_overrides, serve_dtype=serve_dtype)

    def _extrap(a, b):
        return a + (reps - 1) * (b - a)

    flops = _extrap(cost1["flops"], cost2["flops"])
    byts = _extrap(cost1["bytes_accessed"], cost2["bytes_accessed"])
    coll = {k: _extrap(coll1[k], coll2[k])
            for k in coll1 if isinstance(coll1[k], (int, float))}
    print({"flops": flops, "bytes_accessed": byts,
           "collective_total": coll.get("total")})

    n_chips = 512 if multi_pod else 256
    tokens_n = tokens_per_batch(shape)
    model_flops = (
        flops_per_token_train(cfg, shape.seq_len) * tokens_n
        if shape.kind == "train" else None
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "variant": variant,
        "quant_impl": quant_impl,
        "chips": n_chips,
        "ok": True,
        "compile_s": {"full": round(t_full, 1),
                      "total": round(time.time() - t0, 1)},
        "per_device": {
            "flops": flops,
            "bytes_accessed": byts,
            "collective_bytes": coll,
            "flops_raw_scanned": cost_raw["flops"],
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hint_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "model_flops_global": model_flops,
        "tokens": tokens_n,
    }
    rec["roofline"] = roofline_terms(rec)
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh']}__{variant}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant-impl", default="direct",
                    choices=["direct", "residual"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default=ART)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        shapes = shape_cells(arch) if args.shape is None else [args.shape]
        for sh in shapes:
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, sh, mp))

    results = []
    for arch, sh, mp in cells:
        mesh_tag = "pod2x16x16" if mp else "pod16x16"
        label = f"{arch} x {sh} x {mesh_tag}"
        path = os.path.join(args.out,
                            f"{arch}__{sh}__{mesh_tag}__{args.variant}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                old = json.load(f)
            if old.get("ok"):
                print(f"skip {label} (exists)", flush=True)
                results.append((label, "ok"))
                continue
        print(f"=== {label} ===", flush=True)
        try:
            rec = run_cell(arch, sh, mp, quant_impl=args.quant_impl,
                           variant=args.variant, out_dir=args.out)
            dom = rec["roofline"]["dominant"]
            print(f"ok  {label}: compile {rec['compile_s']['total']}s "
                  f"dominant={dom}", flush=True)
            results.append((label, "ok"))
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            os.makedirs(args.out, exist_ok=True)
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": sh, "mesh": mesh_tag,
                           "ok": False, "error": f"{type(e).__name__}: {e}"},
                          f, indent=1)
            results.append((label, f"FAIL {type(e).__name__}"))

    print("\n=== summary ===")
    for label, status in results:
        print(f"{status:28s} {label}")
    if any(s != "ok" for _, s in results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
