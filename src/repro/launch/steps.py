"""Jit-able train / prefill / decode steps with CGMQ as a first-class feature.

``make_train_step`` builds the full production step: quantized (fake-quant)
forward, vocab-parallel cross-entropy, backward, Adam (optionally 8-bit
states), learnable-range update, and the CGMQ gate/controller update — this
is the graph the multi-pod dry-run lowers and the roofline reads.

State is the unified ``repro.train.TrainState`` (DESIGN.md §9) — the same
pytree the classification pipeline's scan engine carries — so gates,
controller flags, probes, RNG and the step counter all checkpoint/restore
together, and the LeNet and LLM stacks share one resumable state layout.

Distribution is GSPMD: parameters/batch carry NamedShardings (from
``ShardingPlan``), activations are constrained at block boundaries inside the
models, and two vocab-sharded primitives are written with ``shard_map``
(mask-psum embedding lookup; Megatron-style vocab-parallel cross-entropy)
because gather/take along a sharded axis is exactly where GSPMD falls back to
all-gathering a multi-GB table.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import bop as bop_lib
from repro.core import controller as ctrl
from repro.core.sites import (
    QuantConfig,
    QuantContext,
    collect_sites,
    init_gates,
    init_probes,
    init_ranges_from_weights,
    merge_ranges,
    split_learnable_ranges,
)
from repro.distributed.sharding import ShardingPlan
from repro.models import transformer as tfm
from repro.models.layers import COMPUTE_DTYPE
from repro.optim.adam import AdamConfig, AdamState, adam, apply_updates
from repro.train.state import TrainState


# ---------------------------------------------------------------------------
# Vocab-sharded primitives (shard_map)
# ---------------------------------------------------------------------------


def sharded_embed_lookup(plan: ShardingPlan, table, tokens):
    """Mask-psum lookup from a vocab-sharded table (V:model, d:replicated).

    Each model shard gathers its local rows (out-of-range -> 0) and the
    partial results psum over 'model' — one (B, S, d) all-reduce instead of
    all-gathering the table.
    """
    mesh = plan.mesh
    m = plan.model_axis
    bspec = plan.batch_spec(tokens.shape)

    def _local(tab, tok):
        rows = tab.shape[0]
        idx = jax.lax.axis_index(m)
        local = tok - idx * rows
        ok = (local >= 0) & (local < rows)
        safe = jnp.clip(local, 0, rows - 1)
        out = jnp.take(tab, safe, axis=0)
        out = jnp.where(ok[..., None], out, 0)
        return jax.lax.psum(out, m)

    return shard_map(
        _local, mesh=mesh,
        in_specs=(P(m, None), bspec),
        out_specs=P(*bspec, None),
        check_rep=False,
    )(table, tokens)


def vocab_parallel_xent(plan: ShardingPlan | None, logits, targets, vocab: int):
    """Cross-entropy over a (possibly model-sharded) vocab axis.

    logits: (B, S, Vp) fp32 (padded ids already masked to -inf);
    targets: (B, S) int32 in [0, vocab).
    """
    if plan is None:
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return -jnp.mean(ll)

    mesh = plan.mesh
    m = plan.model_axis
    bspec = plan.batch_spec(targets.shape)

    def _local(lg, tg):
        shard_v = lg.shape[-1]
        idx = jax.lax.axis_index(m)
        # max is a stability shift only (gradient cancels); pmax has no VJP
        # rule, so gather the per-shard maxes (all_gather differentiates).
        local_max = jax.lax.stop_gradient(jnp.max(lg, axis=-1))
        gmax = jnp.max(jax.lax.all_gather(local_max, m, axis=0), axis=0)
        ex = jnp.exp(lg - gmax[..., None])
        denom = jax.lax.psum(jnp.sum(ex, axis=-1), m)             # (B, S)
        local_t = tg - idx * shard_v
        ok = (local_t >= 0) & (local_t < shard_v)
        safe = jnp.clip(local_t, 0, shard_v - 1)
        picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
        picked = jnp.where(ok, picked, 0.0)
        correct = jax.lax.psum(picked, m)                         # (B, S)
        nll = jnp.log(denom) + gmax - correct
        # nll is m-replicated (all terms psum'd over m); mean over batch axes
        total = jax.lax.psum(jnp.sum(nll), tuple(plan.batch_axes))
        cnt = jax.lax.psum(jnp.asarray(nll.size, jnp.float32),
                           tuple(plan.batch_axes))
        return total / cnt

    loss = shard_map(
        _local, mesh=mesh,
        in_specs=(P(*bspec, m), bspec),
        out_specs=P(),
        check_rep=False,
    )(logits, targets)
    return loss


# ---------------------------------------------------------------------------
# State: TrainState is the unified pytree from repro.train.state, imported
# above so both training stacks share one resumable layout.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Recipe:
    """Everything needed to build/lower the steps for one arch."""

    cfg: ModelConfig
    qcfg: QuantConfig
    ccfg: ctrl.CGMQConfig
    adam: AdamConfig
    sites: dict
    signed: dict
    budget_bop: float
    moe_impl: str = "capacity"
    quant_enabled: bool = True
    scan_unroll: bool = False
    microbatches: int = 1   # gradient accumulation (activation memory / mb)
    accum_dtype: str = "float32"  # bf16 halves the accumulator for 100B+ models
    gather_dtype: str | None = None  # 'bfloat16': cast params before use so
                                     # FSDP all-gathers move half the bytes


def make_recipe(cfg: ModelConfig, shape: ShapeConfig, *,
                direction="dir2", budget_rbop=0.0625, check_every=100,
                state_bits: int | None = None, quant_impl="direct",
                quant_enabled=True, moe_impl="capacity",
                scan_unroll=False, microbatches: int | None = None,
                gather_dtype: str | None = None) -> Recipe:
    """Collect sites (abstract; no allocation) and freeze the recipe.

    budget_rbop default 6.25% == uniform W8A8 deployment target.
    """
    qcfg = QuantConfig(granularity="per_tensor", impl=quant_impl,
                       enabled=quant_enabled)
    b = min(shape.global_batch, 2)  # site collection is shape-independent
    s = min(shape.seq_len, 512) if shape.kind != "decode" else 512
    s = max(s, cfg.ssm_chunk)
    batch_sds = _abstract_batch(cfg, b, s)

    def fwd(qc, p, x, mp):
        return tfm.forward_train(qc, p, x, cfg, mrope_pos=mp, moe_impl=moe_impl)

    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    sites = collect_sites(
        fwd, params_sds, batch_sds["tokens"], batch_sds.get("mrope"), cfg=qcfg
    )
    gates = init_gates(sites, qcfg)
    ranges = init_ranges_from_weights(sites, qcfg, lambda n: None)
    _, signed = split_learnable_ranges(ranges)
    if state_bits is None:
        # 8-bit Adam states where fp32 m/v would not fit 16 GiB/chip
        state_bits = 8 if cfg.param_count() > 2e11 else 32
    if microbatches is None:
        # gradient accumulation for the widest models: activation temp
        # scales down by the microbatch count
        microbatches = 4 if (cfg.d_model >= 7168 and shape.kind == "train"
                             and shape.global_batch % 64 == 0) else 1
    accum_dtype = "bfloat16" if cfg.param_count() > 2e11 else "float32"
    return Recipe(
        cfg=cfg, qcfg=qcfg,
        # dir_clip 10 * lr 0.01 = at most 0.1 gate-units per step: a gate
        # needs >= 10 steps to cross one bit-width level (stability at scale)
        ccfg=ctrl.CGMQConfig(budget_rbop=budget_rbop, direction=direction,
                             gate_lr=0.01, check_every=check_every,
                             dir_clip=10.0),
        adam=AdamConfig(lr=1e-4, state_bits=state_bits, grad_clip_norm=1.0),
        sites=sites, signed=signed,
        budget_bop=bop_lib.budget_from_rbop(sites, budget_rbop),
        moe_impl=moe_impl, quant_enabled=quant_enabled,
        scan_unroll=scan_unroll, microbatches=microbatches,
        accum_dtype=accum_dtype, gather_dtype=gather_dtype,
    )


def _abstract_batch(cfg: ModelConfig, b: int, s: int, *, targets=True):
    out = {}
    if cfg.embed_input:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), COMPUTE_DTYPE)
    if cfg.mrope_sections is not None:
        out["mrope"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    if targets:
        out["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def init_probe_taps(recipe: Recipe, gates) -> dict:
    """Activation probes + weight gradient taps, sized from the gates."""
    probes = init_probes(recipe.sites, recipe.qcfg)
    for s in recipe.sites.values():
        probes[s.name + ".w"] = jnp.zeros_like(
            jnp.asarray(gates[s.name + ".w"], jnp.float32))
    return probes


def init_train_state(recipe: Recipe, key) -> TrainState:
    """Concrete (or eval_shape-able) state initializer."""
    cfg = recipe.cfg
    params = tfm.init_params(cfg, key)
    gates = init_gates(recipe.sites, recipe.qcfg)
    ranges = init_ranges_from_weights(recipe.sites, recipe.qcfg, lambda n: None)
    betas, _ = split_learnable_ranges(ranges)
    opt_init, _ = adam(recipe.adam)
    opt = opt_init((params, betas))
    cgmq = ctrl.init_state(gates, recipe.sites)
    return TrainState(params=params, betas=betas, opt=opt, cgmq=cgmq,
                      probes=init_probe_taps(recipe, gates),
                      rng=jax.random.fold_in(key, 1),
                      step=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def _embed_override(plan):
    if plan is None:
        return None
    return functools.partial(sharded_embed_lookup, plan)


def _split_microbatches(batch: dict, mb: int, plan: ShardingPlan | None):
    """Reshape batch leaves (B, ...) -> (mb, B/mb, ...); mrope at dim 1."""
    out = {}
    for k, v in batch.items():
        if k == "mrope":
            b = v.shape[1]
            r = v.reshape(v.shape[0], mb, b // mb, *v.shape[2:])
            r = jnp.moveaxis(r, 1, 0)
            if plan is not None and (b // mb) % plan.dp_size == 0:
                r = jax.lax.with_sharding_constraint(
                    r, plan.named(P(None, None, plan.batch_axes, None)))
        else:
            b = v.shape[0]
            r = v.reshape(mb, b // mb, *v.shape[1:])
            if plan is not None and (b // mb) % plan.dp_size == 0:
                spec = P(None, plan.batch_axes,
                         *((None,) * (v.ndim - 1)))
                r = jax.lax.with_sharding_constraint(r, plan.named(spec))
        out[k] = r
    return out


def make_train_step(recipe: Recipe, plan: ShardingPlan | None):
    cfg = recipe.cfg
    _, opt_update = adam(recipe.adam)
    mb = recipe.microbatches

    def train_step(state: TrainState, batch: dict):
        # probe taps travel in the state (always zero; only their gradients
        # are read); ad-hoc states from before the unified layout still work
        probes = state.probes if state.probes is not None else init_probe_taps(
            recipe, state.cgmq.gates)

        def loss_fn(params, betas, probes, mb_batch):
            if recipe.gather_dtype is not None:
                # cast BEFORE use: GSPMD's per-layer FSDP all-gathers then
                # move half-precision bytes; fp32 masters still get exact
                # gradients (cast transpose), and the quantizer computes in
                # fp32 internally so fake-quant codes are unchanged.
                gd = jnp.dtype(recipe.gather_dtype)
                params = jax.tree.map(
                    lambda p: p.astype(gd)
                    if (p.dtype == jnp.float32 and p.ndim >= 2) else p,
                    params)
            qc = QuantContext(
                mode="train" if recipe.quant_enabled else "off",
                cfg=recipe.qcfg, gates=state.cgmq.gates,
                ranges=merge_ranges(betas, recipe.signed), probes=probes,
            )
            if plan is not None and cfg.embed_input:
                # swap the lookup for the vocab-sharded mask-psum version
                logits = _forward_with_sharded_embed(
                    qc, params, mb_batch, cfg, plan, recipe.moe_impl,
                    recipe.scan_unroll)
            else:
                logits = tfm.forward_train(
                    qc, params, mb_batch["tokens"], cfg,
                    mrope_pos=mb_batch.get("mrope"), plan=plan,
                    moe_impl=recipe.moe_impl,
                    scan_unroll=recipe.scan_unroll)
            loss = vocab_parallel_xent(plan, logits, mb_batch["targets"],
                                       cfg.vocab_size)
            return loss, (qc.act_stats, qc.weight_stats)

        grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1, 2), has_aux=True)

        if mb == 1:
            (loss, (astats, wstats)), grads = grad_fn(
                state.params, state.betas, probes, batch)
        else:
            # gradient accumulation: scan over microbatches, mean-reduce
            split = _split_microbatches(batch, mb, plan)
            adt = jnp.dtype(recipe.accum_dtype)
            zero_like = jax.eval_shape(
                lambda: grad_fn(state.params, state.betas, probes,
                                jax.tree.map(lambda x: x[0], split)))
            acc0 = jax.tree.map(
                lambda s: jnp.zeros(
                    s.shape, adt if s.dtype == jnp.float32 else s.dtype),
                zero_like)

            def mb_body(acc, mb_batch):
                out = grad_fn(state.params, state.betas, probes, mb_batch)
                return jax.tree.map(
                    lambda a, o: a + o.astype(a.dtype) / mb, acc, out), None

            accum, _ = jax.lax.scan(mb_body, acc0, split)
            (loss, (astats, wstats)), grads = accum
        gp, gb, gprobe = grads
        upd, opt = opt_update((gp, gb), state.opt, (state.params, state.betas))
        params, betas = apply_updates((state.params, state.betas), upd)
        cgmq = ctrl.controller_update(
            state.cgmq, recipe.ccfg, recipe.sites, gprobe, wstats, astats,
            recipe.budget_bop,
        )
        metrics = {
            "loss": loss,
            "bop": cgmq.bop,
            "rbop": cgmq.bop / bop_lib.fp32_bop(recipe.sites),
            "sat": cgmq.sat,
        }
        new = TrainState(
            params=params, betas=betas, opt=opt, cgmq=cgmq, probes=probes,
            rng=state.rng,
            step=None if state.step is None else state.step + 1)
        return new, metrics

    return train_step


def _forward_with_sharded_embed(qc, params, batch, cfg, plan, moe_impl,
                                scan_unroll=False):
    """forward_train with the embedding lookup done via shard_map."""
    tokens = batch["tokens"]
    h = sharded_embed_lookup(plan, params["embed"], tokens)
    if cfg.scale_embed:
        h = h * (cfg.d_model**0.5)
    # re-enter the standard forward from the embedded representation by
    # treating it as a stub-modality input
    cfg_stub = dataclasses.replace(cfg, embed_input=False)
    params_stub = dict(params)
    if "head" not in params_stub:
        params_stub["head"] = params["embed"].T
    return tfm.forward_train(qc, params_stub, h.astype(COMPUTE_DTYPE), cfg_stub,
                             mrope_pos=batch.get("mrope"), plan=plan,
                             moe_impl=moe_impl, scan_unroll=scan_unroll)


def make_prefill_step(recipe: Recipe, plan: ShardingPlan | None, max_seq: int):
    cfg = recipe.cfg

    def prefill_step(params, batch):
        qc = QuantContext(mode="off")
        if plan is not None and cfg.embed_input:
            tokens = batch["tokens"]
            h = sharded_embed_lookup(plan, params["embed"], tokens)
            if cfg.scale_embed:
                h = h * (cfg.d_model**0.5)
            cfg_stub = dataclasses.replace(cfg, embed_input=False)
            params_stub = dict(params)
            if "head" not in params_stub:
                params_stub["head"] = params["embed"].T
            logits, cache = tfm.prefill(
                qc, params_stub, h.astype(COMPUTE_DTYPE), cfg_stub,
                max_seq=max_seq, mrope_pos=batch.get("mrope"), plan=plan,
                moe_impl=recipe.moe_impl, scan_unroll=recipe.scan_unroll)
        else:
            logits, cache = tfm.prefill(
                qc, params, batch["tokens"], cfg, max_seq=max_seq,
                mrope_pos=batch.get("mrope"), plan=plan,
                moe_impl=recipe.moe_impl, scan_unroll=recipe.scan_unroll)
        return logits[:, -1:], cache

    return prefill_step


def make_decode_step(recipe: Recipe, plan: ShardingPlan | None):
    cfg = recipe.cfg

    def decode_step(params, cache, tokens):
        qc = QuantContext(mode="off")
        mp = None
        if cfg.mrope_sections is not None:
            b = tokens.shape[0]
            mp = jnp.broadcast_to(cache["pos"][None, :, None], (3, b, 1))
        if plan is not None and cfg.embed_input:
            h = sharded_embed_lookup(plan, params["embed"], tokens[:, None])
            if cfg.scale_embed:
                h = h * (cfg.d_model**0.5)
            cfg_stub = dataclasses.replace(cfg, embed_input=False)
            params_stub = dict(params)
            if "head" not in params_stub:
                params_stub["head"] = params["embed"].T
            logits, cache = tfm.decode_step(
                qc, params_stub, cache, h.astype(COMPUTE_DTYPE), cfg_stub,
                plan=plan, mrope_pos=mp, scan_unroll=recipe.scan_unroll)
        else:
            logits, cache = tfm.decode_step(
                qc, params, cache, tokens, cfg, plan=plan, mrope_pos=mp,
                scan_unroll=recipe.scan_unroll)
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# Abstract state/batch builders for the dry run (no allocation)
# ---------------------------------------------------------------------------


def abstract_train_args(recipe: Recipe, shape: ShapeConfig,
                        plan: ShardingPlan | None):
    """(state_sds, batch_sds) with shardings attached; nothing allocated."""
    state = jax.eval_shape(
        lambda: init_train_state(recipe, jax.random.PRNGKey(0)))
    batch = _abstract_batch(recipe.cfg, shape.global_batch, shape.seq_len)
    if plan is None:
        return state, batch
    state_sh = train_state_shardings(recipe, state, plan)
    batch_sh = plan.batch_dict_shardings(batch)
    state = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        state, state_sh)
    batch = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_sh[k])
        for k, v in batch.items()
    }
    return state, batch


def train_state_shardings(recipe: Recipe, state_sds: TrainState,
                          plan: ShardingPlan):
    params_sh = plan.params_shardings(state_sds.params)
    betas_sh = plan.replicated(state_sds.betas)
    cgmq_sh = plan.replicated(state_sds.cgmq)

    if recipe.adam.state_bits == 8:
        # row-wise int8 moments: codes share the owner param's sharding;
        # the per-row scale drops the (size-1) last-dim axis from the spec.
        owners_sh = (params_sh, betas_sh)

        def _q_sh(q_sds, owner_sharding):
            spec = owner_sharding.spec
            scale_spec = P(*(tuple(spec[:-1]) + (None,))) if len(spec) else P()
            return {
                "codes": owner_sharding,
                "scale": plan.named(scale_spec),
            }

        m_sh = jax.tree.map(
            _q_sh, state_sds.opt.m, owners_sh,
            is_leaf=lambda x: isinstance(x, dict) and "codes" in x)
        v_sh = jax.tree.map(
            _q_sh, state_sds.opt.v, owners_sh,
            is_leaf=lambda x: isinstance(x, dict) and "codes" in x)
    else:
        m_sh = params_shardings_like(plan, state_sds.opt.m, params_sh, betas_sh)
        v_sh = params_shardings_like(plan, state_sds.opt.v, params_sh, betas_sh)
    opt_sh = AdamState(step=plan.named(P()), m=m_sh, v=v_sh)
    return TrainState(params=params_sh, betas=betas_sh, opt=opt_sh,
                      cgmq=cgmq_sh,
                      probes=plan.replicated(state_sds.probes),
                      rng=plan.named(P()), step=plan.named(P()))


def params_shardings_like(plan, opt_tree, params_sh, betas_sh):
    """Adam moments over (params, betas) reuse their owners' shardings."""
    return (params_sh, betas_sh)


def abstract_serve_args(recipe: Recipe, shape: ShapeConfig,
                        plan: ShardingPlan | None, *, max_seq: int,
                        serve_dtype=None):
    """(params_sds, cache_sds, tokens_sds) for decode lowering.

    ``serve_dtype``: cast >=2D fp32 weights for serving (bf16 halves the
    per-token FSDP gather traffic AND the resident weight bytes).
    """
    cfg = recipe.cfg
    params = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    if serve_dtype is not None:
        sd = jnp.dtype(serve_dtype)
        params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, sd if (x.dtype == jnp.float32 and len(x.shape) >= 2)
                else x.dtype),
            params)
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, shape.global_batch, max_seq))
    if cfg.embed_input:
        tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.d_model),
                                      COMPUTE_DTYPE)
    if plan is None:
        return params, cache, tokens
    params_sh = plan.params_shardings(params)
    cache_sh = plan.cache_shardings(cache)
    params = jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        params, params_sh)

    def _attach(sds, sh):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    cache = jax.tree.map(_attach, cache, cache_sh)
    tokens = jax.ShapeDtypeStruct(
        tokens.shape, tokens.dtype,
        sharding=plan.named(plan.batch_spec(tokens.shape)))
    return params, cache, tokens
