"""Production mesh construction (required deliverable (e), step 1).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are Auto-typed implicitly
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: x2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (subprocess sets device count)."""
    return _mesh(shape, axes)


def batch_axes_of(mesh) -> tuple[str, ...]:
    names = tuple(mesh.axis_names)
    return tuple(a for a in names if a != "model")
