"""Production training launcher.

On a real fleet each host runs this with its own process index and the
coordinator address (see scripts/launch_pod.sh); ``jax.distributed`` then
assembles the global device mesh. On this single-process container it runs
the same code path on the local devices.

State is the unified ``repro.train.TrainState`` (DESIGN.md §9): the
supervisor checkpoints the whole pytree — params, betas, Adam moments,
gates, controller flags, probes, RNG, step — so a restarted run resumes the
exact trajectory, including the §3 last-certified-snapshot guarantee.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b-smoke \
        --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--budget-rbop", type=float, default=0.0625)
    ap.add_argument("--direction", default="dir2")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 to build a (data,model) mesh; default: no mesh")
    ap.add_argument("--coordinator", default=None,
                    help="host:port for multi-host jax.distributed")
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--num-processes", type=int, default=None)
    args = ap.parse_args()

    if args.coordinator:
        import jax

        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.core import bop as bop_lib
    from repro.data.synthetic import lm_tokens
    from repro.distributed.fault_tolerance import (
        SupervisorConfig,
        TrainSupervisor,
    )
    from repro.distributed.sharding import ShardingPlan
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import batch_axes_of, make_test_mesh

    cfg = get_config(args.arch)
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    plan = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_test_mesh(dims, ("data", "model")[: len(dims)])
        plan = ShardingPlan(mesh=mesh, cfg=cfg, batch_axes=batch_axes_of(mesh))

    recipe = steps_lib.make_recipe(cfg, shape, direction=args.direction,
                                   budget_rbop=args.budget_rbop,
                                   check_every=max(10, args.steps // 10))
    state = steps_lib.init_train_state(recipe, jax.random.PRNGKey(0))
    shardings = None
    if plan is not None:
        shardings = steps_lib.train_state_shardings(
            recipe, jax.eval_shape(lambda: state), plan)
        state = jax.tree.map(jax.device_put, state, shardings)
    step_fn = jax.jit(steps_lib.make_train_step(recipe, plan),
                      donate_argnums=(0,))

    data = lm_tokens(2048, args.seq, cfg.vocab_size, seed=0, noise=0.05)

    def batches(step):
        if step >= args.steps:
            return None
        rng = np.random.default_rng(step)
        idx = rng.integers(0, data.shape[0], args.batch)
        chunk = data[idx]
        b = {"tokens": jnp.asarray(chunk[:, :-1]),
             "targets": jnp.asarray(chunk[:, 1:])}
        if cfg.mrope_sections is not None:
            b["mrope"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None], (3, args.batch, args.seq)
            ).astype(jnp.int32)
        if not cfg.embed_input:
            rngx = np.random.default_rng(1000 + step)
            b["tokens"] = jnp.asarray(
                rngx.normal(size=(args.batch, args.seq, cfg.d_model)),
                jnp.bfloat16)
        if plan is not None:
            sh = plan.batch_dict_shardings(b)
            b = {k: jax.device_put(v, sh[k]) for k, v in b.items()}
        return b

    fp_bop = bop_lib.fp32_bop(recipe.sites)
    sup = TrainSupervisor(
        SupervisorConfig(args.ckpt, checkpoint_every=args.checkpoint_every),
        log=print)

    def metrics_cb(step, metrics):
        if step % 10 == 0:
            m = jax.device_get(metrics)
            print(f"step {step} loss {float(m['loss']):.4f} "
                  f"rbop {float(m['bop'])/fp_bop*100:.2f}% "
                  f"sat={bool(m['sat'])}")

    state, step, status = sup.run(state, step_fn, batches,
                                  shardings=shardings, metrics_cb=metrics_cb)
    print(f"{status} at step {step}")


if __name__ == "__main__":
    main()
