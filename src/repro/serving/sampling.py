"""Request-lifecycle sampling: ``SamplingParams`` + device-resident sampling.

The serving API's sampling surface (DESIGN.md §12). A ``SamplingParams``
rides on every request and is lowered at admission into per-slot rows of the
engine's device-resident generation state (temperature / top-k / top-p /
stop tokens / a per-request ``jax.random`` key), so the stochastic pick of
the next token runs INSIDE the jitted decode tick — the §8 contract of one
small host sync per tick survives sampling unchanged.

Two layers:

  * ``SamplingParams`` — the user-facing request knobs, a frozen host-side
    dataclass validated at construction. ``temperature=0`` (the default) is
    the greedy path and is BIT-IDENTICAL to pre-sampling argmax decoding:
    ``sample_tokens`` selects ``argmax`` for zero-temperature rows and
    ``lax.cond``-skips the masking/categorical work entirely when no row in
    the batch samples, so the argmax oracle gates (packed-vs-int8, ring-vs-
    paged) keep holding and all-greedy batches pay zero sampling compute.
  * ``mask_logits`` / ``sample_tokens`` — the device-side math. Every op is
    row-independent (per-slot vmap / axis=-1 reductions), which is what
    makes a request's token stream a pure function of its
    ``(seed, prompt, params)`` and NOT of slot placement, admission order,
    or KV layout: the seed-determinism contract tested in
    ``tests/test_serving.py``.

Key discipline: a request's key is created from its seed at admission and
split once per emitted token (the first, prefill-sampled token included).
Keys advance only for rows that actually emit, so the stream position in
the key chain equals the number of tokens emitted — identical across every
admission path (batched prefill, SSM tail, teacher-forced prefix replay).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Lowest fp32 the masking writes into rejected lanes; -inf would make
# categorical's gumbel-add produce NaN for fully-masked rows (which cannot
# happen — the top-ranked token is always kept — but finfo.min keeps the
# math total anyway).
_MASKED = float(jnp.finfo(jnp.float32).min)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs (DESIGN.md §12).

    ``temperature=0`` is greedy argmax — the bit-exact oracle path.
    ``top_k=0`` / ``top_p=1.0`` disable the respective truncation.
    ``seed=None`` lets the engine draw a per-request seed from its own
    deterministic stream (reproducible per engine instance, not across
    processes — pass an explicit seed for that).
    ``stop``: token ids that end the request early; the stop token itself is
    emitted (like an EOS) and the request retires in the SAME tick, blocks
    and all. ``max_new`` counts every emitted token, stop included.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    stop: tuple = ()
    max_new: int = 16

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off): {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1: {self.max_new}")
        object.__setattr__(self, "stop", tuple(int(t) for t in self.stop))
        if any(t < 0 for t in self.stop):
            raise ValueError(f"stop token ids must be >= 0: {self.stop}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def finite_rows(logits):
    """Per-row non-finite guard (DESIGN.md §13): True where every logit in
    the row is finite. ``jax.random.categorical`` (and argmax) on a NaN/Inf
    row silently emits an arbitrary token, so the engine folds this mask
    into the decode tick — a False row is not emitted and fails alone with
    ``FINISHED_ERROR``, no extra host sync, rest of the batch unaffected."""
    return jnp.isfinite(logits).all(axis=-1)


def mask_logits(logits, top_k, top_p):
    """Top-k / top-p (nucleus) truncation, per row.

    ``logits``: (B, V) fp32 (already temperature-scaled); ``top_k``: (B,)
    int32 (0 = off); ``top_p``: (B,) fp32 in (0, 1]. Returns (B, V) with
    rejected lanes at ``finfo.min``. Nucleus keeps the smallest
    probability-sorted set whose cumulative mass reaches ``top_p`` (the
    first token is always kept), computed on the post-top-k renormalized
    distribution; ranking ties resolve by stable sort, so the result is
    deterministic and row-independent.
    """
    v = logits.shape[-1]
    order = jnp.argsort(-logits, axis=-1, stable=True)
    ranked = jnp.take_along_axis(logits, order, axis=-1)
    rank = jnp.arange(v)[None, :]
    k = jnp.where(top_k > 0, top_k, v)[:, None]
    in_k = rank < k
    probs = jax.nn.softmax(jnp.where(in_k, ranked, _MASKED), axis=-1)
    mass_before = jnp.cumsum(probs, axis=-1) - probs
    keep = in_k & (mass_before < top_p[:, None])
    keep = keep | (rank == 0)
    ranked = jnp.where(keep, ranked, _MASKED)
    inverse = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(ranked, inverse, axis=-1)


def sample_tokens(logits, keys, temperature, top_k, top_p):
    """Next-token choice for a batch of slots, on device.

    ``logits``: (B, V) fp32; ``keys``: (B, 2) uint32 per-slot subkeys;
    ``temperature`` / ``top_k`` / ``top_p``: (B,) per-slot rows. Rows with
    ``temperature <= 0`` take the argmax — bit-identical to the pre-sampling
    greedy path — and when NO row samples, ``lax.cond`` skips the sort/
    categorical work at runtime, so all-greedy ticks cost what they always
    did. Returns (B,) int32.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _drawn(_):
        scaled = logits / jnp.maximum(temperature, 1e-3)[:, None]
        masked = mask_logits(scaled, top_k, top_p)
        drawn = jax.vmap(jax.random.categorical)(keys, masked)
        return jnp.where(temperature > 0.0, drawn.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temperature > 0.0), _drawn,
                        lambda _: greedy, operand=None)
