"""Serving: the request-lifecycle API over the CGMQ-quantized model.

Public surface (DESIGN.md §8/§10/§11/§12):

    from repro.serving import ServingEngine, SamplingParams

    eng = ServingEngine(cfg, params, quant_state=qs)
    results = eng.generate(prompts, SamplingParams(temperature=0.8,
                                                   top_p=0.9, seed=7))
    for ev in eng.generate_stream(prompts, params):
        ...  # TokenEvent per emitted token

``Request``/``submit``/``step`` remain public as the scheduler level the
facade drives; ``kv_pool`` and ``sampling`` are the paged-KV and sampling
substrates.
"""

from repro.serving.engine import (GenerationResult, Request, ServingEngine,
                                  TokenEvent, export_int_codes,
                                  export_int_model, make_mixed_quant_state,
                                  make_uniform_quant_state)
from repro.serving.sampling import SamplingParams, mask_logits, sample_tokens

__all__ = [
    "GenerationResult", "Request", "SamplingParams", "ServingEngine",
    "TokenEvent", "export_int_codes", "export_int_model",
    "make_mixed_quant_state", "make_uniform_quant_state", "mask_logits",
    "sample_tokens",
]
