"""Serving: the request-lifecycle API over the CGMQ-quantized model.

Public surface (DESIGN.md §8/§10/§11/§12/§13):

    from repro.serving import ServingEngine, SamplingParams

    eng = ServingEngine(cfg, params, quant_state=qs)
    results = eng.generate(prompts, SamplingParams(temperature=0.8,
                                                   top_p=0.9, seed=7))
    for ev in eng.generate_stream(prompts, params):
        ...  # TokenEvent per emitted token

``Request``/``submit``/``step`` remain public as the scheduler level the
facade drives; ``kv_pool`` and ``sampling`` are the paged-KV and sampling
substrates. The §13 failure model rides on top: ``AdmissionConfig`` bounds
the queue / pool occupancy / deadlines, every ``GenerationResult`` ends in
one of the ``FINISHED_*`` reasons, and ``ServingSupervisor`` +
``FaultInjector`` give the serving loop the training supervisor's
crash-restart-replay semantics. §15's continuous batching is a
construction knob, not a new surface: ``ServingEngine(...,
prefill_chunk_tokens=N)`` interleaves chunked prefill with decode ticks,
``slo_stats()`` reports arrival-anchored TTFT/TPOT percentiles
(``latency_percentiles`` is the shared summary helper), and
``benchmarks/loadgen.py`` replays seeded traces against the same API.
§17's long-context serving is the same shape of knob:
``ServingEngine(..., attention_window=W)`` — or a ``WindowSpec`` carrying
pinned sink blocks — applies a sliding-window mask at every attention site
and, on the paged layout, evicts out-of-window KV blocks in-tick so
residency stays bounded by the window, not the prompt length.
"""

from repro.serving.admission import (FINISHED_DEADLINE, FINISHED_ERROR,
                                     FINISHED_LENGTH, FINISHED_REJECTED,
                                     FINISHED_STOP, TERMINAL_REASONS,
                                     AdmissionConfig, WaitingQueue,
                                     latency_percentiles)
from repro.serving.engine import (GenerationResult, Request, ServingEngine,
                                  TokenEvent, export_int_codes,
                                  export_int_model, make_act_specs,
                                  make_mixed_quant_state,
                                  make_uniform_quant_state)
from repro.serving.faults import (FaultInjector, InjectedFault,
                                  ServingSupervisor)
from repro.serving.sampling import (SamplingParams, finite_rows, mask_logits,
                                    sample_tokens)
from repro.serving.window import (WindowSpec, as_window_spec,
                                  window_demand_blocks, window_report)

__all__ = [
    "AdmissionConfig", "FINISHED_DEADLINE", "FINISHED_ERROR",
    "FINISHED_LENGTH", "FINISHED_REJECTED", "FINISHED_STOP", "FaultInjector",
    "GenerationResult", "InjectedFault", "Request", "SamplingParams",
    "ServingEngine", "ServingSupervisor", "TERMINAL_REASONS", "TokenEvent",
    "WaitingQueue", "WindowSpec", "as_window_spec", "export_int_codes",
    "export_int_model", "finite_rows", "latency_percentiles",
    "make_act_specs", "make_mixed_quant_state", "make_uniform_quant_state",
    "mask_logits", "sample_tokens", "window_demand_blocks", "window_report",
]
