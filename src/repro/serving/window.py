"""Attention windows for long-context serving (DESIGN.md §17).

A ``WindowSpec`` bounds how much KV history a request's attention may read:
a sliding window of the last ``window`` token positions, plus an optional
block-aligned "sink" prefix (the first ``sink_blocks`` paged blocks) that is
*always* attended and never evicted. Together they induce a block-sparse
pattern over the paged block table — the live set of a slot at position
``p`` is exactly

    blocks [0, sink_blocks)  ∪  blocks [first_live_block(p), p // bs]

and every other block is dead: no current or future query can attend any
position inside it, so the engine's in-tick eviction
(``kv_pool.evict_out_of_window``) releases it back to the pool. That is
what makes KV residency O(window) instead of O(prompt length) — the
CGMQ resource-budget story (PAPER.md) extended to cache memory.

The mask rule, shared bit-exactly by every attend path (dense prefill,
ring decode, paged oracle + Pallas kernel, chunked prefill):

    key position kp is valid for query position qp  iff
        kp <= qp  AND  (qp - kp < window  OR  kp < sink_blocks * bs)

Per-layer composition: a ``kind == "local"`` layer already carries its own
architectural window (``cfg.window``); the engine window tightens it to
``min(cfg.window, spec.window)`` and sinks do NOT apply (the ring layout
physically overwrites positions older than ``cfg.window``, so a sink there
would be unservable — the sink contract covers full-history layers only).
``kind == "global"`` layers get ``(spec.window, sink_tokens)`` verbatim.

``WindowSpec`` is a frozen (hashable) dataclass so it can ride through
``jax.jit`` static arguments unchanged; attention forwards receive the
resolved ``(window, sink_tokens)`` tuple instead of the spec to keep
``repro.models`` free of serving imports.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Sliding-window + sink-block attention pattern for one engine.

    ``window``: how many trailing token positions stay attendable (>= 1).
    ``sink_blocks``: leading paged blocks pinned forever — attended by every
    query of a full-history layer and exempt from eviction (the
    "attention sink" prefix). ``block_size`` is bound by the engine at
    construction (``bind``); it converts ``sink_blocks`` to token units and
    is required before ``sink_tokens``/``live_blocks`` are meaningful.
    """

    window: int
    sink_blocks: int = 0
    block_size: int | None = None

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1: {self.window}")
        if self.sink_blocks < 0:
            raise ValueError(
                f"sink_blocks must be >= 0: {self.sink_blocks}")
        if self.block_size is not None and self.block_size < 1:
            raise ValueError(f"block_size must be >= 1: {self.block_size}")

    def bind(self, block_size: int) -> "WindowSpec":
        """The engine-resolved spec: sink units fixed to its block size."""
        return dataclasses.replace(self, block_size=int(block_size))

    @property
    def sink_tokens(self) -> int:
        if self.block_size is None:
            raise ValueError("WindowSpec is unbound; call bind(block_size)")
        return self.sink_blocks * self.block_size

    @property
    def mask(self) -> tuple[int, int]:
        """The static ``(window, sink_tokens)`` tuple attention forwards
        take (hashable, so it rides jit static args)."""
        return (self.window, self.sink_tokens)

    def live_blocks(self, max_blocks: int) -> int:
        """Worst-case resident blocks per slot under eviction: the sinks
        plus the window span, which straddles one extra partially-live
        block whenever the window boundary is block-interior."""
        if self.block_size is None:
            raise ValueError("WindowSpec is unbound; call bind(block_size)")
        span = -(-self.window // self.block_size) + 1
        return min(max_blocks, self.sink_blocks + span)


def as_window_spec(window, block_size: int | None = None):
    """Coerce the engine's ``attention_window`` knob: ``None`` (off), a bare
    int (sliding window, no sinks), or a ``WindowSpec``."""
    if window is None:
        return None
    spec = window if isinstance(window, WindowSpec) \
        else WindowSpec(window=int(window))
    return spec.bind(block_size) if block_size is not None else spec


def first_live_block(pos, window: int, sink_blocks: int, block_size: int):
    """First logical block the sliding window still reaches at query
    position ``pos`` (jnp or python ints). Block ``j`` is dead iff its last
    key position ``(j+1)*bs - 1 <= pos - window``; the floor below is that
    bound solved for ``j``, clamped so the pinned sink prefix is never
    counted dead."""
    fl = (pos - window + 1) // block_size  # jnp // floors negatives too
    return jnp.clip(fl, sink_blocks, None) if hasattr(fl, "dtype") \
        else max(int(fl), sink_blocks)


def window_demand_blocks(spec: WindowSpec | None, max_blocks: int,
                         chunk_tokens: int | None,
                         block_size: int) -> int:
    """Worst-case pool blocks one slot can hold at any instant.

    Without a window (or without chunked prefill, which allocates the whole
    prompt before eviction can run) the bound is the full table width. With
    both, residency peaks between chunk evictions: the live set plus one
    chunk's worth of freshly written blocks."""
    if spec is None or chunk_tokens is None:
        return max_blocks
    chunk_blk = -(-chunk_tokens // block_size) + 1
    return min(max_blocks, spec.live_blocks(max_blocks) + chunk_blk)


def layer_mask(window: tuple[int, int] | None, kind: str,
               cfg_window: int | None):
    """Resolve the engine mask tuple for one attention layer: local layers
    tighten their architectural window (no sinks — see module docstring),
    global layers take the spec verbatim. Returns ``(window, sink_tokens)``
    with ``window=None`` meaning unmasked."""
    if window is None:
        return (cfg_window if kind == "local" else None, 0)
    w, sink = window
    if kind == "local":
        return (min(cfg_window, w), 0)
    return (w, sink)


def sink_block_count(sink_tokens: int, block_size: int) -> int:
    return -(-sink_tokens // block_size)


def window_report(spec: WindowSpec | None, max_blocks: int,
                  block_size: int) -> dict:
    """JSON-able summary for benchmarks/examples."""
    if spec is None:
        return {"enabled": False}
    return {
        "enabled": True,
        "window": spec.window,
        "sink_blocks": spec.sink_blocks,
        "block_size": block_size,
        "live_blocks_per_slot": spec.live_blocks(max_blocks),
        "table_blocks_per_slot": max_blocks,
        "residency_ratio":
            spec.live_blocks(max_blocks) / max(max_blocks, 1),
    }


def max_live_blocks(window: int, sink_blocks: int, block_size: int) -> int:
    """Ceiling for the bench/CI assert: sinks + the window span including
    the one partially-live boundary block."""
    return sink_blocks + math.ceil(window / block_size) + 1
