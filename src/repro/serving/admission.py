"""Bounded admission: queue policy, watermarks, deadlines, finish reasons.

The admission half of the serving failure model (DESIGN.md §13). PRs 1-5
built an engine that assumes an infinitely patient client and a pool that
never runs dry: ``submit`` always enqueues, the queue is unbounded, and a
request runs until it stops or exhausts ``max_new``. Under real load every
one of those assumptions breaks, and this module is where the breakage is
turned into *policy* instead of undefined behavior:

  * **Finish-reason taxonomy** — every ``GenerationResult`` ends in exactly
    one of the ``FINISHED_*`` reasons below. Overload is never an exception
    escaping a tick; it is a typed terminal state (``rejected`` /
    ``deadline`` / ``error``) or backpressure at ``submit()``.
  * **``AdmissionConfig``** — the knobs: queue capacity + on-full policy
    (``reject`` / ``block`` / ``evict_lru_prefix``), a pool-occupancy
    watermark that refuses to *start* a prefill when projected occupancy
    crosses the reserve threshold, and default TTFT / wall deadlines.
  * **``WaitingQueue``** — FIFO with deadline priority: the pop order is
    (earliest deadline, submission order). Requests without deadlines are
    served strictly FIFO among themselves, so a stream of long prompts can
    never starve an earlier arrival (the pre-§13 engine relied on implicit
    wave ordering); a preempted request keeps its original submission
    sequence number, so re-admission naturally jumps ahead of newer work.

The watermark math is host-side arithmetic over per-request worst cases
(``ceil((plen + max_new - 1) / block_size)`` blocks), so admission control
costs zero device syncs; the §8/§12 one-host-sync-per-tick ledger is
untouched by any policy in this module.
"""

from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# Finish-reason taxonomy (DESIGN.md §13)
# ---------------------------------------------------------------------------

#: Emitted a stop token (the stop token itself is the final token).
FINISHED_STOP = "stop"
#: Exhausted the request's ``max_new`` budget.
FINISHED_LENGTH = "length"
#: Refused at ``submit()`` by the queue-capacity policy; zero tokens ran.
FINISHED_REJECTED = "rejected"
#: TTFT budget expired while waiting, or the wall deadline expired while
#: running; partial output (possibly empty) is kept.
FINISHED_DEADLINE = "deadline"
#: The request's logits went non-finite (NaN/Inf) — the request fails alone,
#: the rest of the batch keeps serving.
FINISHED_ERROR = "error"

#: Every reason a ``GenerationResult`` can terminate with. Preemption is NOT
#: here on purpose: a preempted request is re-queued and resumed, it never
#: finishes with a "preempted" state.
TERMINAL_REASONS = frozenset({FINISHED_STOP, FINISHED_LENGTH,
                              FINISHED_REJECTED, FINISHED_DEADLINE,
                              FINISHED_ERROR})

ON_FULL_POLICIES = ("reject", "block", "evict_lru_prefix")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Backpressure policy for one ``ServingEngine`` (DESIGN.md §13).

    ``queue_capacity``: max requests allowed to wait (``None`` = unbounded,
    the pre-§13 behavior). ``on_full`` picks what ``submit()`` does at
    capacity:

      * ``"reject"`` — finish the request immediately with
        ``FINISHED_REJECTED`` (zero device work);
      * ``"block"`` — drive engine ticks inline until a queue slot frees
        (bounded by ``block_max_ticks``, then reject): synchronous
        backpressure for single-threaded callers;
      * ``"evict_lru_prefix"`` — first release every retained prefix-cache
        block (freeing pool headroom so the queue can drain faster), then
        behave like ``"block"``.

    ``watermark``: fraction of the usable pool (blocks minus the garbage
    block, retained LRU blocks and ``reserve_blocks``) that projected
    occupancy may reach before admission pauses; ``None`` disables the
    check. Projection is the worst case — every running request grown to
    ``plen + max_new - 1`` tokens — so ``watermark=1.0`` guarantees the
    in-tick allocator can never run dry (prefix-shared blocks are counted
    once per sharer, i.e. conservatively). Admission is head-of-line: a
    refused request blocks later (possibly smaller) ones, which is exactly
    what makes starvation impossible.

    ``ttft_deadline_s`` / ``deadline_s``: default per-request budgets
    (submit → first token, and submit → completion); a request's own
    ``Request.ttft_deadline_s`` / ``Request.deadline_s`` override them.
    ``None`` disables the respective check.

    ``tick_token_budget``: prompt tokens the continuous-batching scheduler
    may START prefilling per tick (DESIGN.md §15). Only consulted when the
    engine runs with ``prefill_chunk_tokens`` set; ``None`` defers to the
    engine's own default (one chunk's worth per tick). The budget bounds
    prefill work interleaved between decode ticks, so a long prompt can
    never stall running decoders for more than one chunk forward.
    """

    queue_capacity: int | None = None
    on_full: str = "reject"
    watermark: float | None = None
    reserve_blocks: int = 0
    ttft_deadline_s: float | None = None
    deadline_s: float | None = None
    block_max_ticks: int = 10_000
    tick_token_budget: int | None = None

    def __post_init__(self):
        if self.queue_capacity is not None and self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1 or None: {self.queue_capacity}")
        if self.on_full not in ON_FULL_POLICIES:
            raise ValueError(f"on_full must be one of {ON_FULL_POLICIES}: "
                             f"{self.on_full!r}")
        if self.watermark is not None and not 0.0 < self.watermark <= 1.0:
            raise ValueError(f"watermark must be in (0, 1]: {self.watermark}")
        if self.reserve_blocks < 0:
            raise ValueError(
                f"reserve_blocks must be >= 0: {self.reserve_blocks}")
        for name in ("ttft_deadline_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0 or None: {v}")
        if self.block_max_ticks < 1:
            raise ValueError(
                f"block_max_ticks must be >= 1: {self.block_max_ticks}")
        if self.tick_token_budget is not None and self.tick_token_budget < 1:
            raise ValueError(f"tick_token_budget must be >= 1 or None: "
                             f"{self.tick_token_budget}")


def latency_percentiles(samples) -> dict:
    """p50/p95/p99/mean summary of a latency sample list, as reported for
    TTFT and TPOT in ``ServingEngine.slo_stats()`` (DESIGN.md §15). Pure
    host arithmetic; an empty sample set yields ``count: 0`` with ``None``
    percentiles so JSON consumers need no special-casing."""
    xs = [float(x) for x in samples]
    if not xs:
        return {"count": 0, "p50": None, "p95": None, "p99": None,
                "mean": None}
    xs.sort()

    def pct(q: float) -> float:
        # nearest-rank on the sorted samples: exact, no numpy dependency
        i = max(math.ceil(q / 100.0 * len(xs)) - 1, 0)
        return xs[i]

    return {"count": len(xs), "p50": pct(50), "p95": pct(95),
            "p99": pct(99), "mean": sum(xs) / len(xs)}


def projected_blocks(plen: int, max_new: int, block_size: int,
                     max_blocks: int,
                     window_blocks: int | None = None) -> int:
    """Worst-case pool blocks one request can ever hold: KV is written for
    the prompt plus every generated token except the last emitted one (the
    final token is never decoded), capped at the table width.

    ``window_blocks`` (DESIGN.md §17) caps the projection for windowed
    engines: with in-tick out-of-window eviction a slot's residency never
    exceeds its window demand (sink + live-window + one-chunk blocks), so
    projecting the full sequence length would make the watermark reject
    long-context requests the pool can in fact serve."""
    blk = math.ceil(max(plen + max_new - 1, 1) / block_size)
    if window_blocks is not None:
        blk = min(blk, window_blocks)
    return min(blk, max_blocks)


class WaitingQueue:
    """FIFO with deadline priority (DESIGN.md §13).

    Pop order is ``(effective deadline, submission sequence)`` — requests
    carrying a TTFT or wall deadline sort by whichever expires first, and
    ties (including the no-deadline common case, where the key is ``inf``)
    fall back to strict submission order. That makes the no-deadline queue
    exactly FIFO, so admission order is a total order over arrivals and a
    stream of long prompts cannot starve an earlier request.

    Iteration yields requests in pop order (tests and callers see the queue
    the way the scheduler will drain it); ``len``/truthiness match the old
    plain-list surface the engine exposed.
    """

    def __init__(self):
        self._items: list = []

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self):
        return iter(sorted(self._items, key=self._key))

    @staticmethod
    def _key(req):
        return (getattr(req, "deadline_key", math.inf),
                getattr(req, "seq", 0))

    def push(self, req) -> None:
        self._items.append(req)

    def peek(self):
        """The request the scheduler would admit next (None when empty)."""
        if not self._items:
            return None
        return min(self._items, key=self._key)

    def pop(self):
        """Remove and return the highest-priority request."""
        req = self.peek()
        if req is not None:
            self._items.remove(req)
        return req

    def remove(self, req) -> None:
        self._items.remove(req)

    def expired(self, now: float):
        """Waiting requests whose TTFT or wall budget has passed at ``now``
        (they have produced no first token yet, so either budget expiring
        ends them)."""
        return [r for r in self._items
                if getattr(r, "deadline_key", math.inf) <= now]
