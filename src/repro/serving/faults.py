"""Serving-side failure model: fault injector + supervised serving loop.

``distributed/fault_tolerance.py`` gives TRAINING a supervisor (restore /
replay on crash, EWMA+MAD straggler detection, preemption); this module is
the same failure model on the SERVING side (DESIGN.md §13):

  * ``FaultInjector`` — a tick-indexed schedule of injectable faults driving
    the engine's chaos seams: pool exhaustion (``drain_free_blocks``),
    NaN/Inf logits (``inject_logit_fault``), forced slot preemption, slow
    ticks (straggler food), and hard crashes (``InjectedFault``).
  * ``ServingSupervisor`` — wraps an engine *factory* with a request log and
    a tick loop: every submitted request is recorded as (rid, prompt,
    params-with-pinned-seed) BEFORE it reaches the engine, so when a tick
    raises, the supervisor rebuilds the engine from the factory and
    resubmits every unfinished request from the log. Because a stream is a
    pure function of (prompt, params, seed) — the §12 placement-invariance
    contract — the replayed results are identical to an uninterrupted run.
    Slow ticks feed the SAME ``StragglerDetector`` the training supervisor
    uses; serving does not grow a second anomaly detector.

The supervisor never reaches into device state to recover: recovery is
resubmission, and determinism does the rest. That is the serving analogue of
``TrainSupervisor``'s restore-and-replay-the-batch-stream.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import replace

import numpy as np

from repro.distributed.fault_tolerance import StragglerDetector
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingParams


class InjectedFault(RuntimeError):
    """A scheduled hard failure (the chaos analogue of a node crash)."""


class FaultInjector:
    """Tick-indexed fault schedule: ``{tick: [fault, ...]}`` where each
    fault is a tuple —

      * ``("nan_logits", slot)`` — poison one slot's logits from its next
        tick (cleared when the slot re-arms); exercises ``FINISHED_ERROR``.
      * ``("exhaust_pool", leave)`` — steal all but ``leave`` free blocks,
        forcing the next allocating tick into victim preemption.
      * ``("restore_pool",)`` — give stolen blocks back.
      * ``("preempt", slot)`` — host-side forced preemption of one slot.
      * ``("slow_tick", seconds)`` — sleep inside the measured tick
        (straggler-detector food).
      * ``("crash", msg?)`` — raise ``InjectedFault`` (supervisor restart).

    Each scheduled entry fires exactly once; ``fired`` records what ran.
    """

    def __init__(self, schedule: dict | None = None):
        self.schedule = {int(t): list(fs)
                         for t, fs in (schedule or {}).items()}
        self.fired: list[tuple[int, tuple]] = []

    def at(self, tick: int, *fault) -> "FaultInjector":
        """Builder form: ``FaultInjector().at(3, "crash")``."""
        self.schedule.setdefault(int(tick), []).append(tuple(fault))
        return self

    def fire(self, tick: int, engine: ServingEngine):
        for fault in self.schedule.pop(tick, []):
            kind = fault[0]
            if kind == "nan_logits":
                engine.inject_logit_fault(int(fault[1]))
            elif kind == "exhaust_pool":
                engine.drain_free_blocks(int(fault[1]) if len(fault) > 1
                                         else 0)
            elif kind == "restore_pool":
                engine.restore_free_blocks()
            elif kind == "preempt":
                engine.preempt(int(fault[1]))
            elif kind == "slow_tick":
                time.sleep(float(fault[1]))
            elif kind == "crash":
                self.fired.append((tick, fault))
                raise InjectedFault(fault[1] if len(fault) > 1
                                    else f"injected crash at tick {tick}")
            else:
                raise ValueError(f"unknown fault kind {kind!r}")
            self.fired.append((tick, fault))


class ServingSupervisor:
    """Crash-recovering serving loop: request log + engine factory (§13).

    ``engine_factory`` must build an identically-configured engine each
    call (the supervisor owns the instance and discards it on restart).
    ``submit`` pins a seed on every seedless request BEFORE logging it —
    the log entry must determine the stream, or a replay after restart
    would diverge. ``run`` drives ticks until every logged request has a
    terminal result, surviving up to ``max_restarts`` in-tick exceptions
    by rebuilding the engine and resubmitting unfinished requests.
    """

    def __init__(self, engine_factory, *, injector: FaultInjector | None
                 = None, max_restarts: int = 3, straggler_window: int = 32,
                 straggler_z: float = 4.0, seed: int = 0xFA57,
                 log=print):
        self._factory = engine_factory
        self.engine: ServingEngine = engine_factory()
        self.injector = injector
        self.detector = StragglerDetector(straggler_window, straggler_z)
        self.max_restarts = max_restarts
        self.restarts = 0
        self.log = log
        self._rid = itertools.count(1)
        self._rng = np.random.default_rng(seed)
        # the request log: rid -> (prompt, params). Everything needed to
        # replay the request bit-identically after an engine restart.
        self.request_log: dict[int, tuple[np.ndarray, SamplingParams]] = {}

    def submit(self, prompt, params: SamplingParams | None = None, *,
               rid: int | None = None, **req_kwargs) -> int:
        params = params or SamplingParams()
        if params.seed is None:
            params = replace(params,
                             seed=int(self._rng.integers(2 ** 31 - 1)))
        rid = next(self._rid) if rid is None else rid
        prompt = np.asarray(prompt, np.int32)
        self.request_log[rid] = (prompt, params)
        self.engine.submit(Request(rid=rid, prompt=prompt, params=params,
                                   **req_kwargs))
        return rid

    def _harvest(self, results: dict):
        for req in self.engine.finished:
            if req.rid in self.request_log and req.rid not in results:
                results[req.rid] = self.engine._result(req)

    def _restart(self, results: dict, err: Exception):
        self.restarts += 1
        self.log(f"[serving-supervisor] tick failed ({err}); restart "
                 f"{self.restarts}/{self.max_restarts}")
        if self.restarts > self.max_restarts:
            raise err
        # the old engine's host lists are still trustworthy (the device is
        # what failed): keep anything that already finished
        try:
            self._harvest(results)
        except Exception:  # noqa: BLE001 — chaos path, engine may be gone
            pass
        self.engine = self._factory()
        for rid, (prompt, params) in self.request_log.items():
            if rid not in results:
                # fresh Request: replay restarts the stream from scratch;
                # the pinned seed makes it land on the same tokens
                self.engine.submit(Request(rid=rid, prompt=prompt,
                                           params=params))

    def run(self, max_ticks: int = 100_000) -> dict:
        """Drive the engine until every logged request has a terminal
        result (finish reason included). Returns {rid: GenerationResult}.
        """
        results: dict = {}
        for tick in range(max_ticks):
            self._harvest(results)
            if len(results) == len(self.request_log):
                break
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.fire(tick, self.engine)
                self.engine.step()
            except Exception as e:  # noqa: BLE001 — fleet failure model
                self._restart(results, e)
                continue
            dt = time.perf_counter() - t0
            if self.detector.observe(tick, dt):
                self.log(f"[serving-supervisor] straggler tick {tick}: "
                         f"{dt:.3f}s")
        self._harvest(results)
        if len(results) < len(self.request_log):
            raise RuntimeError(
                f"supervisor still running after {max_ticks} ticks "
                f"({len(results)}/{len(self.request_log)} done)")
        return results
