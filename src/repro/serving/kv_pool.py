"""Paged KV cache: block pool, block tables, device-resident allocator.

The paged serving substrate (DESIGN.md §10). Instead of one contiguous
``(slots, max_seq, KV, hd)`` row per serving slot, each attention layer keeps
a **pool** of ``num_blocks`` fixed-size token blocks

    {"k": (num_blocks, block_size, KV, hd), "v": ...}

(with a leading scan axis for pattern-stacked layers), and every slot owns a
row of the shared **block table** ``(slots, max_blocks)`` mapping its logical
block index to a physical block id (``-1`` = unallocated). One table serves
every layer: an allocation reserves the same physical id across all pools.

**Allocator.** The allocator state is four device arrays — a free *stack*
(``free`` int32 vector + ``n_free`` scalar), per-block ``ref`` counts, and the
block table — and every transition is a jitted gather/scatter:

  * ``alloc_range`` / ``share_prefix``  — admission-time fills of a table row
    (fresh pops, or mapping leading entries to another request's physical
    blocks with a refcount bump: prefix sharing). ``alloc_range`` is
    incremental — ``(slot, start, n)`` extends an existing row — which is
    how §15's chunked prefill allocates blocks chunk-by-chunk instead of
    reserving a whole prompt's worth up front;
  * ``tick_alloc``       — the in-decode-tick pop: rows whose position enters
    an unallocated block each take one block off the stack *inside* the
    jitted tick, so the §8 one-host-sync-per-tick contract survives paging;
  * ``free_slot``        — retirement: decref the row, push blocks that hit
    refcount 0 back on the stack;
  * ``cow_block``        — copy-on-write: give a slot a private copy of one
    shared block across every layer pool before it writes into it.

Physical block 0 is reserved as the **garbage block**: writes by rows that
must not touch the pool (inactive slots, masked prefill padding) are routed
to it, and it is never referenced by a valid table entry, so it is never
attended.

**Pool dtype contract (DESIGN.md §10/§14).** A float pool stores K/V in
exactly ``bfloat16`` or ``float32`` — asserted at construction, no silent
widening. A *quantized* pool (built with a ``KVQuantSpec``) is the flat dict

    {"k": codes, "v": codes, "k_scale": scales, "v_scale": scales}

with codes ``(num_blocks, bs, KV, packed_head)`` in the spec's storage dtype
(int8, or uint8 nibble-packed for int4) and fp16 per-group scales
``(num_blocks, bs, KV, num_groups)``. All four arrays share the leading
block/slot axes, so every allocator primitive above — and crucially
``cow_block``'s verbatim per-entry copy — treats codes and affine aux
identically: CoW never pays a dequant->requant round trip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.quant import kv as kv_codec

# The §10 float-pool contract: KV blocks are bf16 by default, fp32 for the
# equivalence oracle. Anything else must go through a KVQuantSpec.
FLOAT_POOL_DTYPES = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32))


def init_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
              dtype=jnp.bfloat16, spec: kv_codec.KVQuantSpec | None = None):
    """One attention layer's K/V block pool (unstacked).

    With ``spec`` set, the pool is quantized: packed codes + fp16 group
    scales (zero-filled — the garbage block dequantizes to exact zeros).
    """
    if spec is not None:
        assert spec.head_dim == cfg.head_dim, (spec, cfg.head_dim)
        cshape = (num_blocks, block_size, cfg.n_kv_heads, spec.packed_head)
        sshape = (num_blocks, block_size, cfg.n_kv_heads, spec.num_groups)
        return {"k": jnp.zeros(cshape, spec.code_dtype),
                "v": jnp.zeros(cshape, spec.code_dtype),
                "k_scale": jnp.zeros(sshape, spec.scale_dtype),
                "v_scale": jnp.zeros(sshape, spec.scale_dtype)}
    assert jnp.dtype(dtype) in FLOAT_POOL_DTYPES, (
        f"float KV pools are bf16 or fp32 (got {jnp.dtype(dtype)}); "
        "sub-float storage goes through a KVQuantSpec")
    shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_alloc(num_blocks: int, slots: int, max_blocks: int):
    """Allocator state. Block 0 is the reserved garbage block, so the free
    stack starts holding blocks ``1 .. num_blocks-1`` (``n_free`` of them);
    entries past ``n_free`` are don't-care."""
    free = jnp.concatenate([jnp.arange(1, num_blocks, dtype=jnp.int32),
                            jnp.zeros((1,), jnp.int32)])
    return {
        "free": free,
        "n_free": jnp.asarray(num_blocks - 1, jnp.int32),
        "ref": jnp.zeros((num_blocks,), jnp.int32).at[0].set(1),
        "table": jnp.full((slots, max_blocks), -1, jnp.int32),
    }


def alloc_range(alloc, slot, start, n):
    """Pop ``n`` fresh blocks into ``table[slot, start:start+n]`` (ref=1).

    ``slot`` / ``start`` / ``n`` may be traced scalars. The caller must
    guarantee ``n <= n_free`` (the engine sizes the pool so a full slot
    complement always fits; see DESIGN.md §10).
    """
    nb = alloc["free"].shape[0]
    mb = alloc["table"].shape[1]
    j = jnp.arange(mb)
    take = (j >= start) & (j < start + n)
    si = alloc["n_free"] - 1 - (j - start)
    ids = alloc["free"][jnp.clip(si, 0, nb - 1)]
    row = alloc["table"][slot]
    return {
        "free": alloc["free"],
        "n_free": alloc["n_free"] - jnp.asarray(n, jnp.int32),
        "ref": alloc["ref"].at[jnp.where(take, ids, 0)].add(
            take.astype(jnp.int32)),
        "table": alloc["table"].at[slot].set(jnp.where(take, ids, row)),
    }


def share_prefix(alloc, slot, phys, n):
    """Map ``table[slot, :n]`` onto existing physical blocks ``phys[:n]``
    (another request's prompt prefix), bumping their refcounts. ``phys`` is a
    ``(max_blocks,)`` vector padded past ``n`` with anything."""
    mb = alloc["table"].shape[1]
    take = jnp.arange(mb) < n
    row = alloc["table"][slot]
    return {
        "free": alloc["free"],
        "n_free": alloc["n_free"],
        "ref": alloc["ref"].at[jnp.where(take, phys, 0)].add(
            take.astype(jnp.int32)),
        "table": alloc["table"].at[slot].set(jnp.where(take, phys, row)),
    }


def free_slot(alloc, slot):
    """Retire a slot: decref every valid table entry, push blocks whose
    refcount hits 0 back on the stack (in row order), clear the row.

    This is also the stop-token early-exit path (DESIGN.md §12): a request
    that stops before ``max_new`` retires in the tick that emitted the stop,
    so its blocks rejoin the free stack immediately — the engine guarantees
    the slot's device row is inactive by then (a still-active row would
    keep popping blocks via ``tick_alloc``)."""
    nb = alloc["free"].shape[0]
    row = alloc["table"][slot]
    valid = row >= 0
    safe = jnp.where(valid, row, 0)
    ref = alloc["ref"].at[safe].add(-valid.astype(jnp.int32))
    freed = valid & (ref[safe] == 0)
    rank = jnp.cumsum(freed.astype(jnp.int32)) - 1
    # Junk (non-freed) scatter lanes write free[nb-1] back to itself: the
    # stack holds at most nb-1 entries, so index nb-1 is never live.
    idx = jnp.where(freed, alloc["n_free"] + rank, nb - 1)
    vals = jnp.where(freed, safe, alloc["free"][nb - 1])
    return {
        "free": alloc["free"].at[idx].set(vals),
        "n_free": alloc["n_free"] + jnp.sum(freed.astype(jnp.int32)),
        "ref": ref,
        "table": alloc["table"].at[slot].set(jnp.full_like(row, -1)),
    }


def release_range(alloc, slot, start, n):
    """Release ``table[slot, start : start+n]`` back toward the pool: decref
    every valid entry in the span, clear it to ``-1``, and push blocks whose
    refcount hits 0 onto the free stack (DESIGN.md §17).

    This is ``free_slot`` restricted to a logical span — the primitive under
    out-of-window eviction. The refcount rules make it safe by construction
    against every sharing mechanism: a block mapped by another slot
    (``share_prefix``), retained by the prefix-LRU cache
    (``retain_block``), or held by the fault injector (``steal_blocks``)
    keeps a positive refcount and therefore never reaches the stack; only
    the *reference* is dropped. Cleared entries are skipped by a later
    ``free_slot`` (it only decrefs entries ``>= 0``), so eviction followed
    by retirement never double-frees. ``slot``/``start``/``n`` may be
    traced scalars."""
    nb = alloc["free"].shape[0]
    mb = alloc["table"].shape[1]
    row = alloc["table"][slot]
    j = jnp.arange(mb)
    take = (j >= start) & (j < start + n) & (row >= 0)
    safe = jnp.where(take, row, 0)
    ref = alloc["ref"].at[safe].add(-take.astype(jnp.int32))
    freed = take & (ref[safe] == 0)
    rank = jnp.cumsum(freed.astype(jnp.int32)) - 1
    idx = jnp.where(freed, alloc["n_free"] + rank, nb - 1)
    vals = jnp.where(freed, safe, alloc["free"][nb - 1])
    return {
        "free": alloc["free"].at[idx].set(vals),
        "n_free": alloc["n_free"] + jnp.sum(freed.astype(jnp.int32)),
        "ref": ref,
        "table": alloc["table"].at[slot].set(jnp.where(take, -1, row)),
    }


def evict_out_of_window(alloc, first_live, live, sink_blocks: int):
    """In-tick out-of-window eviction (DESIGN.md §17): for every row in
    ``live``, release logical blocks ``sink_blocks <= j < first_live[row]``
    — the blocks the sliding window can no longer reach (the per-row
    ``first_live`` comes from ``serving.window.first_live_block``). Runs
    INSIDE the jitted decode tick: all gather/scatter, no host round-trip,
    so the §8 one-sync-per-tick ledger is untouched.

    Unlike ``release_range`` this is vectorized over rows, and two rows may
    drop the *same* physical block in one call (a shared out-of-window
    prefix), so decrements are accumulated per physical block first and
    each block is pushed at most once — exactly when its refcount reaches
    0. Sink blocks (``j < sink_blocks``) and any block with a surviving
    reference (another slot, the prefix-LRU cache) are never freed.
    """
    nb = alloc["free"].shape[0]
    tbl = alloc["table"]
    mb = tbl.shape[1]
    cols = jnp.arange(mb)[None, :]
    ev = (live.astype(bool)[:, None]
          & (cols >= sink_blocks) & (cols < first_live[:, None])
          & (tbl >= 0))
    ids = jnp.where(ev, tbl, 0)
    dec = jnp.zeros((nb,), jnp.int32).at[ids.reshape(-1)].add(
        ev.reshape(-1).astype(jnp.int32))
    dec = dec.at[0].set(0)  # junk lanes accumulate on the pinned garbage id
    ref = alloc["ref"] - dec
    freed = (dec > 0) & (ref == 0)
    rank = jnp.cumsum(freed.astype(jnp.int32)) - 1
    # same junk-lane trick as free_slot: index nb-1 is never a live stack
    # slot (the stack tops out at nb-1 entries occupying [0, nb-2])
    idx = jnp.where(freed, alloc["n_free"] + rank, nb - 1)
    vals = jnp.where(freed, jnp.arange(nb, dtype=jnp.int32),
                     alloc["free"][nb - 1])
    return {
        "free": alloc["free"].at[idx].set(vals),
        "n_free": alloc["n_free"] + jnp.sum(freed.astype(jnp.int32)),
        "ref": ref,
        "table": jnp.where(ev, -1, tbl),
    }


def retain_block(alloc, blk):
    """Take a cache-side reference on one physical block (prefix-cache LRU
    retention, DESIGN.md §10): the block survives every live user retiring
    until ``release_block`` drops the reference."""
    return {**alloc, "ref": alloc["ref"].at[blk].add(1)}


def release_block(alloc, blk):
    """Drop a cache-side reference; push the block back on the free stack if
    that was the last one. Same junk-lane trick as ``free_slot``: a block
    being released holds a ref, so the stack has at most ``nb - 2`` entries
    and index ``nb - 1`` is never live."""
    nb = alloc["free"].shape[0]
    ref = alloc["ref"].at[blk].add(-1)
    freed = ref[blk] == 0
    idx = jnp.where(freed, alloc["n_free"], nb - 1)
    val = jnp.where(freed, blk, alloc["free"][nb - 1])
    return {
        "free": alloc["free"].at[idx].set(val),
        "n_free": alloc["n_free"] + freed.astype(jnp.int32),
        "ref": ref,
        "table": alloc["table"],
    }


def tick_alloc(alloc, pos, mask, block_size: int):
    """In-tick allocation: every row in ``mask`` whose current position lies
    in an unallocated logical block pops one block off the free stack. Runs
    INSIDE the jitted decode tick — no host round-trip."""
    nb = alloc["free"].shape[0]
    mb = alloc["table"].shape[1]
    b = pos.shape[0]
    lp = jnp.clip(pos, 0, mb * block_size - 1)
    blk = lp // block_size
    rows = jnp.arange(b)
    cur = alloc["table"][rows, blk]
    need = mask.astype(bool) & (cur < 0)
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    ids = alloc["free"][jnp.clip(alloc["n_free"] - 1 - rank, 0, nb - 1)]
    chosen = jnp.where(need, ids, cur)
    return {
        "free": alloc["free"],
        "n_free": alloc["n_free"] - jnp.sum(need.astype(jnp.int32)),
        "ref": alloc["ref"].at[jnp.where(need, ids, 0)].add(
            need.astype(jnp.int32)),
        "table": alloc["table"].at[rows, blk].set(chosen),
    }


def preempt_for_free(alloc, pos, active, gen, stamp, block_size: int):
    """In-tick victim preemption (DESIGN.md §13): while the rows about to
    enter an unallocated block demand more blocks than the free stack holds,
    free whole victim slots until the demand fits.

    Victim policy: fewest generated tokens first (``gen``, the cheapest
    progress to throw away — its replay bill on re-admission is smallest),
    oldest admission stamp (``stamp``) on ties, i.e. LRU among equals.
    Runs INSIDE the jitted decode tick, before ``tick_alloc``, so exhaustion
    never surfaces as a host-side error; the preempted mask rides back to the
    host in the same single per-tick sync the stats ledger already pays for.

    Returns ``(alloc, preempted)`` where ``preempted`` is a bool row mask.
    Termination: each iteration removes one live row, and demand over zero
    live rows is zero.
    """
    mb = alloc["table"].shape[1]
    b = pos.shape[0]
    rows = jnp.arange(b)
    blk = jnp.clip(pos, 0, mb * block_size - 1) // block_size
    big = jnp.iinfo(jnp.int32).max

    def demand(a, live):
        cur = a["table"][rows, blk]
        return jnp.sum((live & (cur < 0)).astype(jnp.int32))

    def cond(carry):
        a, pre = carry
        return demand(a, active & ~pre) > a["n_free"]

    def body(carry):
        a, pre = carry
        live = active & ~pre
        least = jnp.min(jnp.where(live, gen, big))
        tied = live & (gen == least)
        victim = jnp.argmin(jnp.where(tied, stamp, big))
        return free_slot(a, victim), pre.at[victim].set(True)

    alloc, pre = jax.lax.while_loop(
        cond, body, (alloc, jnp.zeros_like(active)))
    return alloc, pre


def steal_blocks(alloc, n):
    """Pop ``n`` blocks off the free stack under an external (non-table)
    reference — the fault injector's pool-exhaustion lever, and the generic
    "reserve blocks outside any slot" primitive. ``n`` may be traced; the
    caller must guarantee ``n <= n_free``. Returns ``(alloc, ids)`` with
    ``ids`` a ``(num_blocks,)`` vector of the stolen physical ids, padded
    with ``-1`` — hand it back verbatim to ``unsteal_blocks``."""
    nb = alloc["free"].shape[0]
    j = jnp.arange(nb)
    take = j < n
    ids = alloc["free"][jnp.clip(alloc["n_free"] - 1 - j, 0, nb - 1)]
    return {
        "free": alloc["free"],
        "n_free": alloc["n_free"] - jnp.asarray(n, jnp.int32),
        "ref": alloc["ref"].at[jnp.where(take, ids, 0)].add(
            take.astype(jnp.int32)),
        "table": alloc["table"],
    }, jnp.where(take, ids, -1)


def unsteal_blocks(alloc, ids):
    """Return blocks taken by ``steal_blocks``: drop the external reference
    and push every block whose refcount hits 0 back on the stack. Same
    junk-lane trick as ``free_slot`` (stolen blocks hold refs, so the stack
    can't be full while any are outstanding)."""
    nb = alloc["free"].shape[0]
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    ref = alloc["ref"].at[safe].add(-valid.astype(jnp.int32))
    freed = valid & (ref[safe] == 0)
    rank = jnp.cumsum(freed.astype(jnp.int32)) - 1
    idx = jnp.where(freed, alloc["n_free"] + rank, nb - 1)
    vals = jnp.where(freed, safe, alloc["free"][nb - 1])
    return {
        "free": alloc["free"].at[idx].set(vals),
        "n_free": alloc["n_free"] + jnp.sum(freed.astype(jnp.int32)),
        "ref": ref,
        "table": alloc["table"],
    }


def _is_pool(entry) -> bool:
    return isinstance(entry, dict) and "k" in entry and "v" in entry


def cow_block(alloc, layers, slot, blk):
    """Copy-on-write: replace the shared block at ``table[slot, blk]`` with a
    fresh private copy across every attention layer pool. The caller must
    know the block is shared (ref > 1) — CoW of an unshared block would leak
    it. Returns ``(alloc, layers)``."""
    nb = alloc["free"].shape[0]
    old = alloc["table"][slot, blk]
    old_safe = jnp.clip(old, 0, nb - 1)
    new = alloc["free"][jnp.clip(alloc["n_free"] - 1, 0, nb - 1)]

    def copy_entry(entry):
        if not _is_pool(entry):
            return entry  # recurrent state rows: nothing to page
        out = {}
        for name, pool in entry.items():
            if pool.ndim == 5:  # (R, nb, bs, KV, hd) scan-stacked
                out[name] = pool.at[:, new].set(pool[:, old_safe])
            else:
                out[name] = pool.at[new].set(pool[old_safe])
        return out

    new_layers = [copy_entry(e) for e in layers]
    alloc = {
        "free": alloc["free"],
        "n_free": alloc["n_free"] - 1,
        "ref": alloc["ref"].at[old_safe].add(-1).at[new].set(1),
        "table": alloc["table"].at[slot, blk].set(new),
    }
    return alloc, new_layers


def write_prompt_blocks(pool, k, v, row, start_blk, nblk, block_size: int):
    """Scatter a prompt's K/V into the pool as whole blocks.

    ``pool``: {"k","v"} (+ ``"*_scale"`` when quantized) of
    (R?, num_blocks, bs, KV, last); ``k``/``v``: the *float* prefill K/V for
    one slot, (R?, S, KV, hd) — S is padded here to a block multiple. A
    quantized pool quantizes at this write site (the §14 write-site rule:
    floats never land in a quantized pool), then codes and scales ride the
    identical pad/reshape/scatter — the token axis is -3 for all four
    entries. Blocks ``start_blk <= j < nblk`` land at ``row[j]``; the rest
    (shared prefix the slot must not overwrite, and the pad tail) are routed
    to the garbage block 0. ``start_blk`` / ``nblk`` may be traced.
    """
    bs = block_size
    stacked = k.ndim == 4
    s = k.shape[-3]
    spec = kv_codec.spec_from_cache(pool, k.shape[-1])
    if spec is not None:
        kk, ks = kv_codec.quantize_kv(k, spec)
        vv, vs = kv_codec.quantize_kv(v, spec)
        entries = {"k": kk, "v": vv, "k_scale": ks, "v_scale": vs}
    else:
        entries = {"k": k, "v": v}
    pad = (-s) % bs
    nblocks = (s + pad) // bs
    j = jnp.arange(nblocks)
    write = (j >= start_blk) & (j < nblk)
    phys = jnp.where(write, jnp.clip(row[:nblocks], 0, None), 0)
    out = {}
    for name, x in entries.items():
        if pad:
            width = [(0, 0)] * x.ndim
            width[-3] = (0, pad)
            x = jnp.pad(x, width)
        if stacked:
            xb = x.reshape(x.shape[0], nblocks, bs, *x.shape[-2:])
        else:
            xb = x.reshape(nblocks, bs, *x.shape[-2:])
        tgt = pool[name]
        out[name] = (tgt.at[:, phys].set(xb.astype(tgt.dtype)) if stacked
                     else tgt.at[phys].set(xb.astype(tgt.dtype)))
    return out
