"""Batched serving engine over the CGMQ-quantized model.

The deployment half of the CGMQ story (DESIGN.md §8). ``export_int_model``
freezes a trained (params, gates, ranges) triple into int8 codes + affine
terms per site — the ``quant_matmul`` kernel's format — and ``ServingEngine``
runs a slot-based continuous-batching scheduler whose hot path actually
serves that artifact:

  * **batched prefill** — each admitted request runs its whole prompt through
    ONE causal forward (``tfm.prefill_slot``), which writes the slot's KV
    range / recurrent state in one shot. The seed engine scanned
    ``decode_step`` token-by-token with the token broadcast across all
    slots: O(prompt_len x slots) slot-forwards per admission, now 1.
  * **int8 decode** — with a ``quant_state``, decode runs in serve mode:
    every exported matmul site dispatches the fused-dequant GEMM
    (``quant_matmul``: Pallas on TPU, jnp reference elsewhere) straight off
    int8 codes instead of fake-quant-then-fp32-matmul, so decode streams a
    quarter of the weight bytes.
  * **device-resident generation loop** — greedy sampling, the per-slot
    position bump and done-flag computation all live inside the jitted tick;
    the Python loop does ONE small host sync per batch tick (next tokens +
    emitted/done masks), not one per slot.

Requests join a waiting queue; free slots prefill and join the running
batch; finished slots free immediately. Per-slot KV state lives in the cache
pytree indexed by slot, at per-slot positions (``cache["pos"]`` is a
vector), so slots at unrelated sequence positions share one decode step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gates import gate_to_bits
from repro.core.quantizer import quantize_to_int
from repro.core.sites import QuantContext, merge_ranges
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# Int-code export
# ---------------------------------------------------------------------------


def export_int_codes(w, gate, beta, signed: bool):
    """Int-code export for one tensor at its learned bit-width."""
    bits = int(np.asarray(gate_to_bits(jnp.asarray(gate))).max())
    bits = max(2, min(bits, 8))  # serving GEMM packs <= 8 bits
    codes, scale, bias = quantize_to_int(w, bits, beta, signed)
    return {"codes": codes, "scale": scale, "bias": bias, "bits": bits}


def _expand_group(a, w, stacked: bool):
    """Broadcast a gate-group array against weight ``w``.

    Group shapes are () (per-tensor) or (N,) (per-channel), with a leading
    stack axis when ``stacked``; channels align with w's LAST axis.
    """
    a = jnp.asarray(a, jnp.float32)
    if stacked:
        core = a.shape[1:]
        return a.reshape((a.shape[0],) + (1,) * (w.ndim - 1 - len(core)) + core)
    if a.ndim == 0:
        return a
    return a.reshape((1,) * (w.ndim - a.ndim) + a.shape)


def _site_int_export(w, gate, beta, signed: bool, stacked: bool):
    """One dense site -> ({codes, scale, bias}, max_bits) or None.

    Eligible layouts: per-tensor / per-channel gates over a (K, N) weight,
    optionally scan-stacked to (R, K, N). The int grid reproduces the
    fake-quant grid EXACTLY (per-layer mixed bit-widths ride in scale/bias),
    so serve-mode logits match the fake-quant reference. Sites trained above
    8 bits are rejected — int8 can't carry their grid — and fall back to
    fake-quant in serve mode.
    """
    g = jnp.asarray(gate)
    w = jnp.asarray(w)
    core = g.shape[1:] if stacked else g.shape
    if core not in ((), (w.shape[-1],)):
        return None  # per-weight granularity: kernel has no per-element scale
    if stacked and (g.ndim == 0 or g.shape[0] != w.shape[0]):
        return None
    bits = gate_to_bits(g)
    max_bits = int(np.asarray(jax.device_get(bits)).max())
    if max_bits > 8:
        return None
    codes, scale, bias = quantize_to_int(
        w, _expand_group(bits, w, stacked), _expand_group(beta, w, stacked),
        signed)
    return {"codes": codes, "scale": scale, "bias": bias}, max_bits


def export_int_model(params, cfg: ModelConfig, quant_state: dict, *,
                     plan=None):
    """Full-model int-code export for the serving GEMM.

    Captures every matmul site's weight tensor via an export-mode forward —
    the same code path serving runs, so site names line up by construction
    (scan-stacked sites come back stacked along the scan axis, exactly the
    layout the decode scan re-slices). Each eligible dense site is then
    quantized at its learned per-site (per-layer, per-channel) bit-widths.

    ``quant_state``: {"qcfg", "gates", "betas", "signed"} as used for
    train-mode forwards. Returns ``(qweights, report)``: ``qweights`` maps
    "<site>.w" -> {codes, scale, bias} arrays (the pytree ``decode_step``
    threads through its scan alongside gates); ``report`` maps the same keys
    to the exported max bit-width. Ineligible sites (per-weight granularity,
    >8-bit, MoE/conv weight shapes) are absent and served via fake-quant.
    """
    qc = QuantContext(mode="export")
    s = 8  # long enough for chunked-SSD block sizes at smoke scale
    if cfg.embed_input:
        dummy = jnp.zeros((1, s), jnp.int32)
    else:
        dummy = jnp.zeros((1, s, cfg.d_model), jnp.float32)
    mrope = None
    if cfg.mrope_sections is not None:
        mrope = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))
    tfm.forward_train(qc, params, dummy, cfg, plan=plan, mrope_pos=mrope,
                      moe_impl="dense_all", remat=False)
    gates = quant_state["gates"]
    ranges = merge_ranges(quant_state["betas"], quant_state["signed"])
    qweights: dict[str, Any] = {}
    report: dict[str, int] = {}
    for key, w in qc.weight_stats.items():
        site = qc.sites.get(key[:-len(".w")])
        if key not in gates or site is None or len(site.weight_shape) != 2:
            continue
        stacked = w.ndim == len(site.weight_shape) + 1
        out = _site_int_export(w, gates[key], ranges[key]["beta"],
                               ranges[key]["signed"], stacked)
        if out is None:
            continue
        qweights[key], report[key] = out
    return qweights, report


def make_uniform_quant_state(cfg: ModelConfig, params, *, gate_init=2.2,
                             granularity="per_channel"):
    """A stand-in trained CGMQ state with one uniform gate everywhere
    (default T(2.2) = 8 bits): the shape real training produces, without
    running the controller. Shared by the serving example, the throughput
    benchmark and the serving tests so they can't drift apart; NOT a
    substitute for a trained state in real deployments.
    """
    from repro.core.sites import (QuantConfig, collect_sites, init_gates,
                                  init_ranges_from_weights,
                                  split_learnable_ranges)

    qcfg = QuantConfig(granularity=granularity)
    sites = collect_sites(
        lambda qc, p, x: tfm.forward_train(qc, p, x, cfg, remat=False),
        params, jnp.zeros((1, 8), jnp.int32), cfg=qcfg)
    gates = init_gates(sites, qcfg, init=gate_init)
    betas, signed = split_learnable_ranges(
        init_ranges_from_weights(sites, qcfg, lambda n: None))
    return {"qcfg": qcfg, "gates": gates, "betas": betas, "signed": signed}


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    done: bool = False
    output: list = dataclasses.field(default_factory=list)


class ServingEngine:
    """Slot-based continuous batching around prefill_slot / decode_step.

    ``quant_state=None`` serves fp32; with a quant_state the engine serves
    the int-code export (``use_int8=True``, the default) or pure fake-quant.
    ``matmul_impl`` picks the fused-dequant GEMM backend: "pallas" on TPU,
    "pallas_interpret" for kernel validation, "ref" (jnp) elsewhere; the
    default auto-detects.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, quant_state: dict | None = None,
                 plan=None, use_int8: bool = True,
                 matmul_impl: str | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.plan = plan
        self.quant_state = quant_state
        if matmul_impl is None:
            matmul_impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.qweights: dict[str, Any] = {}
        self.int8_report: dict[str, int] = {}
        if quant_state is not None and use_int8:
            self.qweights, self.int8_report = export_int_model(
                params, cfg, quant_state, plan=plan)

        self.cache = tfm.init_cache(cfg, slots, max_seq)
        # Device-resident generation state: one row per slot.
        self.state = {
            "last_tok": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "remaining": jnp.zeros((slots,), jnp.int32),
        }
        self.slot_req: list[Request | None] = [None] * slots
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        # Perf accounting (consumed by benchmarks/run.py --json):
        #   prefill_forwards       batched prompt forwards actually run
        #   seed_equiv_forwards    decode_step forwards the seed's
        #                          scan-of-decode-steps prefill would have run
        #                          (one per prompt token, each slots wide)
        self.stats = {"prefill_forwards": 0, "tail_decode_steps": 0,
                      "prompt_tokens": 0, "seed_equiv_forwards": 0,
                      "decode_ticks": 0, "generated_tokens": 0,
                      "prefill_time_s": 0.0, "decode_time_s": 0.0}

        # Small quant state (gates/ranges) rides as jit closure constants;
        # the int8 codes are passed as a jit ARGUMENT so the (potentially
        # large) artifact isn't baked into every compiled executable — _tick
        # plus each per-bucket _prefill specialization would otherwise embed
        # its own copy.
        def _qc(qweights):
            if quant_state is None:
                return QuantContext(mode="off")
            return QuantContext(
                mode="serve", cfg=quant_state["qcfg"],
                gates=quant_state["gates"],
                ranges=merge_ranges(quant_state["betas"],
                                    quant_state["signed"]),
                qweights=qweights, matmul_impl=matmul_impl,
            )

        @jax.jit
        def _tick(params, qweights, cache, state):
            """One device-resident generation step for the whole batch.

            Greedy sampling, the per-slot position bump (via ``advance``) and
            the done-flag updates all happen on device; the caller fetches
            (next_tokens, emitted, done) in a single host transfer.
            """
            logits, cache = tfm.decode_step(
                _qc(qweights), params, cache, state["last_tok"], cfg,
                plan=plan, advance=state["active"])
            nxt = jnp.argmax(logits[:, 0, : cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            emitted = state["active"]
            nxt = jnp.where(emitted, nxt, state["last_tok"])
            remaining = state["remaining"] - emitted.astype(jnp.int32)
            done_now = emitted & (remaining <= 0)
            state = {"last_tok": nxt, "active": emitted & ~done_now,
                     "remaining": remaining}
            return cache, state, nxt, emitted, done_now

        self._tick = _tick

        @jax.jit
        def _prefill(params, qweights, cache, state, toks, plen, slot,
                     max_new):
            """Admit one request: batched prefill into the slot + state init.

            Specializes per padded prompt-bucket shape; ``plen``/``slot``/
            ``max_new`` are traced, so admissions don't recompile.
            """
            logits, cache = tfm.prefill_slot(
                _qc(qweights), params, toks, plen, cache, slot, cfg,
                plan=plan)
            first = jnp.argmax(
                logits[0, plen - 1, : cfg.vocab_size]).astype(jnp.int32)
            remaining = jnp.asarray(max_new, jnp.int32) - 1
            state = {
                "last_tok": state["last_tok"].at[slot].set(first),
                "active": state["active"].at[slot].set(remaining > 0),
                "remaining": state["remaining"].at[slot].set(remaining),
            }
            return cache, state, first

        self._prefill = _prefill

        @jax.jit
        def _teacher_step(params, qweights, cache, state, tok, slot):
            """Teacher-forced decode of one PROMPT token into one slot.

            Used for the sub-chunk tail of SSM prefills. Only ``slot``
            advances; decode_step keeps every non-advancing row's recurrent
            state untouched, so concurrent slots are unaffected.
            """
            toks = state["last_tok"].at[slot].set(tok)
            adv = jnp.zeros((slots,), jnp.int32).at[slot].set(1)
            logits, cache = tfm.decode_step(
                _qc(qweights), params, cache, toks, cfg, plan=plan,
                advance=adv)
            nxt = jnp.argmax(
                logits[slot, 0, : cfg.vocab_size]).astype(jnp.int32)
            return cache, nxt

        self._teacher_step = _teacher_step

    # ------------------------------------------------------------------
    def _prefill_shape(self, plen: int) -> tuple[int, int]:
        """(batched-forward length, teacher-forced tail length) per prompt.

        Attention-only archs right-pad to a power-of-two bucket (padding is
        masked, see tfm.prefill_slot). Recurrent state (ssm / rglru) is an
        unconditional scan over every input position with no masking
        analogue, so those archs prefill at the exact prompt length —
        ssd_chunked additionally requires chunk-multiple lengths, so SSM
        prompts run the largest chunk-aligned prefix in the batched forward
        and teacher-force the < chunk remaining tokens through decode steps.
        """
        kinds = list(self.cfg.block_pattern) + list(self.cfg.remainder_kinds)
        if "ssm" in kinds:
            cs = self.cfg.ssm_chunk
            if plen <= cs:
                return plen, 0
            l0 = (plen // cs) * cs
            return l0, plen - l0
        if "recurrent" in kinds:
            return plen, 0
        b = 8
        while b < plen:
            b *= 2
        return min(b, self.max_seq), 0

    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        t0 = time.perf_counter()
        admitted = []
        for s in range(self.slots):
            if self.slot_req[s] is None and self.waiting:
                req = self.waiting.pop(0)
                plen = len(req.prompt)
                assert 1 <= plen <= self.max_seq, (plen, self.max_seq)
                self.slot_req[s] = req
                prompt = np.asarray(req.prompt, np.int32)
                l0, tail = self._prefill_shape(plen)
                toks = np.zeros((1, max(l0, plen - tail)), np.int32)
                toks[0, : plen - tail] = prompt[: plen - tail]
                self.cache, self.state, first = self._prefill(
                    self.params, self.qweights, self.cache, self.state,
                    jnp.asarray(toks), plen - tail, s, req.max_new)
                for t in prompt[plen - tail:]:
                    self.cache, first = self._teacher_step(
                        self.params, self.qweights, self.cache, self.state,
                        jnp.asarray(int(t), jnp.int32), s)
                if tail:
                    self.state["last_tok"] = \
                        self.state["last_tok"].at[s].set(first)
                self.stats["prefill_forwards"] += 1
                self.stats["tail_decode_steps"] += tail
                self.stats["prompt_tokens"] += plen
                self.stats["seed_equiv_forwards"] += plen
                admitted.append((s, req, first))
        for s, req, first in admitted:
            req.output.append(int(first))
            self.stats["generated_tokens"] += 1
            if req.max_new <= 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        if admitted:
            self.stats["prefill_time_s"] += time.perf_counter() - t0

    def step(self):
        """One engine tick: admit, decode the running batch, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        t0 = time.perf_counter()
        self.cache, self.state, nxt, emitted, done = self._tick(
            self.params, self.qweights, self.cache, self.state)
        # The one host sync of the tick: three (slots,)-sized vectors.
        nxt, emitted, done = map(np.asarray,
                                 jax.device_get((nxt, emitted, done)))
        self.stats["decode_time_s"] += time.perf_counter() - t0
        self.stats["decode_ticks"] += 1
        for s, req in enumerate(self.slot_req):
            if req is None or not emitted[s]:
                continue
            req.output.append(int(nxt[s]))
            self.stats["generated_tokens"] += 1
            if done[s]:
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return True

    def run_to_completion(self, max_ticks: int = 1000):
        ticks = 0
        while (self.waiting or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
