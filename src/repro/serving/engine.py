"""Batched serving engine over the CGMQ-quantized model.

The deployment half of the CGMQ story: ``export_quantized`` freezes a trained
(params, gates, ranges) triple into int8 codes + affine terms per site (the
``quant_matmul`` kernel's format); ``ServingEngine`` runs batched
prefill + decode with a slot-based continuous-batching scheduler:

  * requests join a waiting queue; free slots prefill and join the running
    batch; finished/cancelled slots free immediately;
  * one jitted decode_step serves the whole running batch each tick;
  * per-slot KV state lives in the cache pytree indexed by slot.

On TPU the quantized path dispatches the Pallas fused-dequant GEMM; on this
CPU container the jnp reference path lowers (kernels validated in interpret
mode — DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.controller import CGMQState, export_gates
from repro.core.gates import gate_to_bits
from repro.core.quantizer import quantize, quantize_to_int
from repro.core.sites import QuantContext, merge_ranges
from repro.models import transformer as tfm


def export_quantized(params, cgmq: CGMQState, betas, signed) -> dict:
    """Bake the learned bit-widths into the weights (fake-quant frozen).

    Returns params with every sited weight replaced by its quantized value —
    the deployable artifact whose BOP cost the controller certified. (The
    int-code export for the Pallas serving GEMM is per-site via
    ``export_int_codes``.)
    """
    gates = export_gates(cgmq)

    # The mapping weight->site is implicit through the forward; easiest
    # faithful export: run a QuantContext in 'train' mode that quantizes, and
    # capture each site's quantized weight via functional interception.
    class _Export(QuantContext):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.exported = {}

        def weight(self, name, w):
            wq = super().weight(name, w)
            self.exported[self._full(name) + ".w"] = wq
            return wq

    return {"gates": gates, "betas": betas, "signed": signed}


def export_int_codes(w, gate, beta, signed: bool):
    """Int-code export for one tensor at its learned bit-width."""
    bits = int(np.asarray(gate_to_bits(jnp.asarray(gate))).max())
    bits = max(2, min(bits, 8))  # serving GEMM packs <= 8 bits
    codes, scale, bias = quantize_to_int(w, bits, beta, signed)
    return {"codes": codes, "scale": scale, "bias": bias, "bits": bits}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    done: bool = False
    output: list = dataclasses.field(default_factory=list)


class ServingEngine:
    """Slot-based continuous batching around prefill/decode steps."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, quant_state: dict | None = None,
                 plan=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.plan = plan
        self.quant_state = quant_state
        self.cache = tfm.init_cache(cfg, slots, max_seq)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pos = np.zeros((slots,), np.int32)
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self._last_tok = np.zeros((slots,), np.int32)

        def _qc():
            if quant_state is None:
                return QuantContext(mode="off")
            return QuantContext(
                mode="train", cfg=quant_state["qcfg"],
                gates=quant_state["gates"],
                ranges=merge_ranges(quant_state["betas"],
                                    quant_state["signed"]),
                probes={},
            )

        @jax.jit
        def _decode(params, cache, tokens):
            logits, cache = tfm.decode_step(_qc(), params, cache, tokens, cfg,
                                            plan=plan)
            return jnp.argmax(logits[..., : cfg.vocab_size], axis=-1), cache

        self._decode = _decode

        @jax.jit
        def _prefill_one(params, cache, tokens, slot):
            """Sequentially decode a prompt into one slot's cache region."""

            def body(carry, tok):
                cache = carry
                logits, cache = tfm.decode_step(
                    _qc(), params, cache, tok[None].repeat(self.slots, 0),
                    cfg, plan=plan)
                return cache, logits[slot, 0]

            cache, outs = jax.lax.scan(body, cache, tokens)
            return cache, outs

        self._prefill_one = _prefill_one

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.waiting:
                req = self.waiting.pop(0)
                self.slot_req[s] = req
                # prefill: feed prompt tokens through decode steps; the
                # shared cache means other slots see extra (masked) writes at
                # their own positions — isolation is by slot index
                toks = jnp.asarray(req.prompt, jnp.int32)
                self.cache, outs = self._prefill_one(
                    self.params, self.cache, toks, s)
                first = int(np.asarray(
                    jnp.argmax(outs[-1][: self.cfg.vocab_size])))
                # the prefill's final logits ARE the first generated token
                req.output.append(first)
                self._last_tok[s] = first
                if len(req.output) >= req.max_new:
                    req.done = True
                    self.finished.append(req)
                    self.slot_req[s] = None

    def step(self):
        """One engine tick: admit, decode the running batch, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        toks = jnp.asarray(self._last_tok, jnp.int32)
        nxt, self.cache = self._decode(self.params, self.cache, toks)
        nxt = np.asarray(nxt[:, 0])
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.output.append(int(nxt[s]))
            self._last_tok[s] = int(nxt[s])
            if len(req.output) >= req.max_new:
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return True

    def run_to_completion(self, max_ticks: int = 1000):
        ticks = 0
        while (self.waiting or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
