"""Batched serving engine over the CGMQ-quantized model.

The deployment half of the CGMQ story (DESIGN.md §8/§11).
``export_int_model`` freezes a trained (params, gates, ranges) triple into
``quant.QuantizedTensor``s — packed sub-byte codes + affine terms per site,
the ``quant_matmul`` kernel family's format — and ``ServingEngine`` runs a
slot-based continuous-batching scheduler whose hot path actually serves
that artifact:

  * **batched prefill** — each admitted request runs its whole prompt through
    ONE causal forward (``tfm.prefill_slot``), which writes the slot's KV
    range / recurrent state in one shot. The seed engine scanned
    ``decode_step`` token-by-token with the token broadcast across all
    slots: O(prompt_len x slots) slot-forwards per admission, now 1.
  * **mixed-precision integer decode** — with a ``quant_state``, decode runs
    in serve mode: every exported matmul site dispatches the bit-width-
    matched fused-dequant GEMM (``quant_matmul_qt``: Pallas on TPU, jnp
    reference elsewhere) straight off packed 2/4/8-bit codes instead of
    fake-quant-then-fp32-matmul, so decode streams the weight bytes the
    controller certified — ``bits/8`` of a byte per weight, not a uniform
    int8 (let alone fp32) footprint.
  * **device-resident generation loop** — sampling (greedy argmax OR the
    stochastic temperature / top-k / top-p pick, per slot), the per-slot
    position bump, stop-token detection and done-flag computation all live
    inside the jitted tick; the Python loop does ONE small host sync per
    batch tick (next tokens + emitted/done masks), not one per slot. The
    ``stats`` host-sync ledger (``tick_syncs`` / ``admit_syncs``) records
    every transfer, and the tick stays at exactly one with sampling enabled.

The request lifecycle (DESIGN.md §12): each ``Request`` carries a
``SamplingParams`` (temperature, top-k, top-p, per-request seed, stop
tokens, max_new) that admission lowers into per-slot rows of the device
state; ``engine.generate(prompts, params)`` is the user-facing facade
(submit → drive → collect ``GenerationResult``s) and
``engine.generate_stream(...)`` yields per-tick ``TokenEvent`` deltas.
Requests join a waiting queue; free slots prefill and join the running
batch; finished slots — stop-token hits included — free immediately, in the
same tick. Per-slot KV state lives in the cache pytree indexed by slot, at
per-slot positions (``cache["pos"]`` is a vector), so slots at unrelated
sequence positions share one decode step.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import math
import time
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sites import QuantContext
from repro.models import transformer as tfm
from repro.core.calibration import calibrate_activations
from repro.quant import (ActQuantSpec, KVQuantSpec, QuantizedTensor,
                         QuantSpec, export_act_sites, export_sites,
                         quant_report, specs_from_state)
from repro.quant.kv import kv_cache_report
from repro.serving import kv_pool
from repro.serving.admission import (FINISHED_DEADLINE, FINISHED_ERROR,
                                     FINISHED_LENGTH, FINISHED_REJECTED,
                                     FINISHED_STOP, AdmissionConfig,
                                     WaitingQueue, latency_percentiles,
                                     projected_blocks)
from repro.serving.sampling import (SamplingParams, finite_rows,
                                    sample_tokens)
from repro.serving.window import (WindowSpec, as_window_spec,
                                  window_demand_blocks, window_report)


# ---------------------------------------------------------------------------
# Int-code export
# ---------------------------------------------------------------------------


def export_int_codes(w, gate, beta, signed: bool) -> QuantizedTensor:
    """Single-tensor export at its learned bit-width (packed sub-byte).

    The gate→bits→storage-class decision is ``QuantSpec.from_gate`` /
    ``storage_bits`` — the same constructor the full-model exporter uses.
    Gates above 8 bits clamp to the 8-bit storage ceiling here (this helper
    has no fake-quant fallback to reject into).
    """
    spec = QuantSpec.from_gate(gate, beta, signed)
    storage = spec.storage_bits() or 8
    bits = jnp.minimum(spec.bits, float(storage))
    return QuantizedTensor.from_float(w, bits, spec.beta, spec.signed,
                                      storage_bits=storage)


def export_int_model(params, cfg: ModelConfig, quant_state: dict, *,
                     plan=None, pack: bool = True, warn: bool = True):
    """Full-model quantized export for the serving GEMMs.

    Captures every matmul site's weight tensor via an export-mode forward —
    the same code path serving runs, so site names line up by construction
    (scan-stacked sites come back stacked along the scan axis, exactly the
    layout the decode scan re-slices) — then freezes each eligible dense
    site through ``quant.export.export_sites`` at its learned per-site
    (per-layer, per-channel) bit-widths, packed into its 2/4/8-bit storage
    class (``pack=False`` keeps the unpacked int8 oracle layout).

    ``quant_state``: {"qcfg", "gates", "betas", "signed"} as used for
    train-mode forwards. Returns ``(qweights, ledger)``: ``qweights`` maps
    "<site>.w" -> ``QuantizedTensor`` (the pytree ``decode_step`` threads
    through its scan alongside the specs); ``ledger`` is the
    ``quant.ExportLedger`` recording EVERY site — including the ones
    rejected to fake-quant fallback (per-weight granularity, >8-bit,
    MoE/conv weight shapes), which used to be silently invisible.
    """
    qc = QuantContext(mode="export")
    s = 8  # long enough for chunked-SSD block sizes at smoke scale
    if cfg.embed_input:
        dummy = jnp.zeros((1, s), jnp.int32)
    else:
        dummy = jnp.zeros((1, s, cfg.d_model), jnp.float32)
    mrope = None
    if cfg.mrope_sections is not None:
        mrope = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))
    tfm.forward_train(qc, params, dummy, cfg, plan=plan, mrope_pos=mrope,
                      moe_impl="dense_all", remat=False)
    return export_sites(qc, quant_state["gates"], quant_state["betas"],
                        quant_state["signed"], pack=pack, warn=warn)


def make_uniform_quant_state(cfg: ModelConfig, params, *, gate_init=2.2,
                             granularity="per_channel"):
    """A stand-in trained CGMQ state with one uniform gate everywhere
    (default T(2.2) = 8 bits): the shape real training produces, without
    running the controller. Shared by the serving example, the throughput
    benchmark and the serving tests so they can't drift apart; NOT a
    substitute for a trained state in real deployments.
    """
    from repro.core.sites import (QuantConfig, collect_sites, init_gates,
                                  init_ranges_from_weights,
                                  split_learnable_ranges)

    qcfg = QuantConfig(granularity=granularity)
    s = 8
    if cfg.embed_input:
        dummy = jnp.zeros((1, s), jnp.int32)
    else:  # modality stub: embeddings come in directly
        dummy = jnp.zeros((1, s, cfg.d_model), jnp.float32)
    mrope = None
    if cfg.mrope_sections is not None:
        mrope = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))
    sites = collect_sites(
        lambda qc, p, x: tfm.forward_train(qc, p, x, cfg, mrope_pos=mrope,
                                           moe_impl="dense_all", remat=False),
        params, dummy, cfg=qcfg)
    gates = init_gates(sites, qcfg, init=gate_init)
    betas, signed = split_learnable_ranges(
        init_ranges_from_weights(sites, qcfg, lambda n: None))
    return {"qcfg": qcfg, "gates": gates, "betas": betas, "signed": signed}


# Gate values landing exactly on T(g) = 2 / 4 / 8 bits (core.gates Eq. 4).
MIXED_GATE_LEVELS = (0.8, 1.5, 2.5)

# bits -> the gate value whose T(g) is exactly that width; used to fold
# served activation widths back into the BOP certificate (DESIGN.md §16).
ACT_GATE_LEVELS = {2: 0.8, 4: 1.5, 8: 2.5}


def make_act_specs(cfg: ModelConfig, params, act_bits: int, *, plan=None,
                   batches: int = 2, seq: int = 16, seed: int = 0) -> dict:
    """Calibrate per-tensor ``.in`` activation specs for serving (§16).

    Runs a few seeded random batches through the SAME calibrate-mode
    forward training uses (``QuantConfig(quantize_inputs=True)`` turns the
    ``.in`` recording on), EMA-aggregates the per-batch ranges via
    ``core.calibration.calibrate_activations``, and freezes each GEMM-input
    site into an ``ActQuantSpec`` at ``act_bits``. Scan-stacked sites come
    back with a leading layer axis on ``beta`` — the layout the decode scan
    re-slices. Returns {"<site>.in": ActQuantSpec}; merge into a serve
    context's ``specs`` (the engine's ``act_bits=`` knob does this) to run
    the int8×int8 integer GEMM path end to end.
    """
    from repro.core.sites import QuantConfig

    qcfg = QuantConfig(quantize_inputs=True)
    rng = np.random.default_rng(seed)
    if cfg.embed_input:
        data = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)),
                            jnp.int32) for _ in range(batches)]
    else:
        data = [jnp.asarray(rng.normal(size=(1, seq, cfg.d_model)),
                            jnp.float32) for _ in range(batches)]
    mrope = None
    if cfg.mrope_sections is not None:
        mrope = jnp.broadcast_to(jnp.arange(seq)[None, None, :], (3, 1, seq))

    def _fwd(qc, batch):
        tfm.forward_train(qc, params, batch, cfg, plan=plan, mrope_pos=mrope,
                          moe_impl="dense_all", remat=False)

    act_ranges = calibrate_activations(_fwd, data, qcfg)
    return {
        key: ActQuantSpec(bits=int(act_bits),
                          beta=jnp.asarray(v["beta"], jnp.float32),
                          signed=bool(v["signed"]))
        for key, v in act_ranges.items() if key.endswith(".in")
    }


def make_mixed_quant_state(cfg: ModelConfig, params, *,
                           levels=MIXED_GATE_LEVELS,
                           granularity="per_channel"):
    """A stand-in trained CGMQ state with MIXED 2/4/8-bit weight sites.

    Weight gates cycle through ``levels`` site-by-site (deterministic: sorted
    site order), activations stay 8-bit — the shape of a real
    budget-constrained CGMQ outcome, without running the controller. This is
    the workload for the packed sub-byte serving path: exported storage is
    2/4/8-bit packed, so device bytes land strictly below the uniform-int8
    baseline (asserted in CI via ``quant_report``).
    """
    qs = make_uniform_quant_state(cfg, params, gate_init=2.5,
                                  granularity=granularity)
    gates = {}
    wi = 0
    for key in sorted(qs["gates"]):
        g = qs["gates"][key]
        if key.endswith(".w"):
            gates[key] = jnp.full_like(g, levels[wi % len(levels)])
            wi += 1
        else:
            gates[key] = g
    qs["gates"] = gates
    return qs


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One unit of the serving lifecycle: waiting → slot → finished.

    ``params`` carries the request's ``SamplingParams``; ``max_new`` is kept
    as a construction convenience (the pre-§12 call signature) and is folded
    into a default-greedy ``params`` when none is given — after
    construction ``req.max_new`` always mirrors ``req.params.max_new``.

    The §13 failure-model fields: ``ttft_deadline_s`` / ``deadline_s`` are
    per-request budgets (seconds from submit to first token / to
    completion) overriding the engine ``AdmissionConfig`` defaults;
    ``seed_used`` pins the sampling seed actually drawn at first admission,
    so a preempted request resumes its exact key chain (a seedless request
    must NOT redraw on re-admission); ``preemptions`` counts evictions;
    ``seq`` is the submission sequence number (preemption keeps it, so
    re-admission sorts ahead of newer arrivals).
    """

    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    done: bool = False
    output: list = dataclasses.field(default_factory=list)
    # paged layout: the chain-hash keys of this request's full prompt blocks
    # in the engine's prefix map (for eviction at retirement)
    prefix_keys: list = dataclasses.field(default_factory=list)
    params: SamplingParams | None = None
    finish_reason: str | None = None    # a FINISHED_* reason once done
    ttft_deadline_s: float | None = None
    deadline_s: float | None = None
    seed_used: int | None = None
    preemptions: int = 0
    seq: int | None = None
    submit_s: float = 0.0
    ttft_by: float = math.inf       # absolute expiry times, resolved at
    deadline_by: float = math.inf   # submit() against the engine clock
    # SLO stamps (DESIGN.md §15), taken against the engine's injectable
    # clock: first_token_s when the first output token reaches the host,
    # finish_s at the terminal transition. TTFT = first_token_s - submit_s;
    # TPOT = (finish_s - first_token_s) / (len(output) - 1).
    first_token_s: float | None = None
    finish_s: float | None = None

    def __post_init__(self):
        if self.params is None:
            self.params = SamplingParams(max_new=self.max_new)
        self.max_new = self.params.max_new

    @property
    def deadline_key(self):
        """The expiry that matters while this request WAITS: a fresh request
        dies when either budget passes (no first token yet); a preempted
        one already met its TTFT, so only the wall deadline applies. Also
        the queue's priority key (earliest-expiring first)."""
        if self.output:
            return self.deadline_by
        return min(self.ttft_by, self.deadline_by)


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One emitted token, as yielded by ``generate_stream`` (one event per
    request per tick; the admission tick yields the prefill-sampled first
    token). ``done``/``finish_reason`` ride on the request's final event."""

    rid: int
    token: int
    index: int                  # position in the request's output
    done: bool = False
    finish_reason: str | None = None


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """Terminal state of one request, as returned by ``generate``."""

    rid: int
    prompt: np.ndarray
    tokens: list
    finish_reason: str
    params: SamplingParams


class ServingEngine:
    """Slot-based continuous batching around prefill_slot / decode_step.

    The user-facing surface is the request lifecycle (DESIGN.md §12):
    ``generate(prompts, params)`` / ``generate_stream(...)`` with a
    ``SamplingParams`` per request — temperature / top-k / top-p sampling
    runs inside the jitted tick off per-slot key chains, ``temperature=0``
    (default) being bit-identical to greedy argmax. ``submit``/``step`` stay
    public as the scheduler-level API the facade drives.

    ``quant_state=None`` serves fp32; with a quant_state the engine serves
    the packed mixed-precision export (``use_int8=True``, the default) or
    pure fake-quant. ``matmul_impl`` picks the fused-dequant GEMM backend:
    "pallas" on TPU, "pallas_interpret" for kernel validation, "ref" (jnp)
    elsewhere; the default auto-detects.

    ``kv_layout`` picks the attention cache substrate (DESIGN.md §10):

      * ``"paged"`` (the "auto" default whenever the arch has attention
        layers) — K/V lives in a block pool addressed through a per-slot
        block table with a device-resident free-list allocator, and the
        scheduler shares physical blocks between requests with a common
        prompt prefix (copy-on-write at the first divergent write). A fully
        cached prompt admits with NO prefill forward: its table row maps the
        existing blocks and only the sub-block remainder is teacher-forced.
      * ``"ring"`` — the §8 contiguous per-slot rows (local layers as ring
        buffers). Kept as the equivalence oracle for the paged path and used
        automatically for attention-free (pure recurrent-state) archs.

    Prefix sharing applies only to pure-attention archs (recurrent state is
    per-slot and can't be block-shared); ``prefix_sharing=False`` disables
    it. ``block_size``/``num_blocks`` size the pool — the default pool
    (``slots * ceil(max_seq/bs) + 1 + prefix_lru_blocks`` blocks) can always
    hold every slot at ``max_seq``, so the in-tick allocator can never run
    dry.

    ``prefix_lru_blocks`` (default 0 = retire-time eviction, the old
    behavior) keeps up to that many fully-unreferenced prefix blocks alive
    in an LRU pool: the prefix cache itself holds a device refcount, so a
    popular prompt's blocks survive all its requests retiring and the next
    same-prefix admission still skips the prefill. Retained blocks live in
    pool surplus beyond the worst-case slot reservation (the pool is sized
    up by exactly ``prefix_lru_blocks``), so generation can never be starved
    by the cache; past capacity the least-recently-used key is evicted and
    its block released.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, quant_state: dict | None = None,
                 plan=None, use_int8: bool = True, act_bits: int | None = None,
                 matmul_impl: str | None = None, kv_layout: str = "auto",
                 kv_dtype: str = "bf16",
                 block_size: int = 8, num_blocks: int | None = None,
                 prefix_sharing: bool = True, prefix_lru_blocks: int = 0,
                 max_stop: int = 4,
                 admission: AdmissionConfig | None = None,
                 preemption: bool | str = "auto",
                 prefill_chunk_tokens: int | None = None,
                 tick_token_budget: int | None = None,
                 attention_window: "int | WindowSpec | None" = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.plan = plan
        self.quant_state = quant_state
        # KV storage class (DESIGN.md §14): bf16 (default) / fp32 float
        # pools, or int8/int4 group-wise quantized codes + fp16 scales.
        assert kv_dtype in ("bf16", "fp32", "int8", "int4"), kv_dtype
        self.kv_dtype = kv_dtype
        self._kv_store = jnp.float32 if kv_dtype == "fp32" else jnp.bfloat16
        if kv_dtype in ("int8", "int4"):
            # largest power-of-two group <= 32 that divides head_dim, so the
            # fused kernel path never sees a ragged group (§14 alignment rule)
            gs = math.gcd(cfg.head_dim, 32)
            assert cfg.head_dim % gs == 0, (cfg.head_dim, gs)
            self.kv_spec = KVQuantSpec(bits=8 if kv_dtype == "int8" else 4,
                                       group_size=gs, head_dim=cfg.head_dim)
        else:
            self.kv_spec = None
        if matmul_impl is None:
            matmul_impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.qweights: dict[str, QuantizedTensor] = {}
        self.export_ledger = None
        self.specs: dict[str, QuantSpec] = {}
        if quant_state is not None:
            self.specs = specs_from_state(quant_state["gates"],
                                          quant_state["betas"],
                                          quant_state["signed"])
        if quant_state is not None and use_int8:
            self.qweights, self.export_ledger = export_int_model(
                params, cfg, quant_state, plan=plan)
        # Fully-integer GEMMs (DESIGN.md §16): calibrate per-tensor ``.in``
        # activation specs and merge them into the serve specs — every site
        # with an int-code export then dispatches the int8×int8 kernel.
        self.act_bits = act_bits
        self.act_specs: dict[str, ActQuantSpec] = {}
        if act_bits is not None:
            if quant_state is None:
                raise ValueError("act_bits requires a quant_state")
            self.act_specs = make_act_specs(cfg, params, act_bits, plan=plan)
            self.specs = {**self.specs, **self.act_specs}
            if self.export_ledger is not None:
                self.export_ledger.act_entries = export_act_sites(
                    self.act_specs, self.export_ledger.sites)

        kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
        has_attn = any(k in ("global", "local") for k in kinds)
        self._state_only = not has_attn
        assert kv_layout in ("auto", "paged", "ring"), kv_layout
        if kv_layout == "auto":
            kv_layout = "paged" if has_attn else "ring"
        if not has_attn:
            kv_layout = "ring"  # nothing to page: pure state rows
        self.kv_layout = kv_layout
        self.paged = kv_layout == "paged"
        self.prefix_sharing = (
            self.paged and prefix_sharing
            and all(k in ("global", "local") for k in kinds))
        self.lru_capacity = prefix_lru_blocks if self.prefix_sharing else 0
        assert preemption in ("auto", True, False), preemption
        # Long-context window (DESIGN.md §17): None keeps dense attention
        # bit-identical to an unwindowed engine; an int or WindowSpec caps
        # every global layer's reach (local layers clip to min(cfg.window,
        # W)) and, on the paged layout, bounds KV residency via in-tick
        # out-of-window eviction. The spec binds the engine block size so
        # sink_tokens is block-aligned.
        self.window_spec = as_window_spec(attention_window, block_size)
        self._window = (self.window_spec.mask
                       if self.window_spec is not None else None)
        if self.paged:
            self.block_size = block_size
            self.max_blocks = -(-max_seq // block_size)
            # Per-slot worst-case residency: the full table without a
            # window; with a window AND chunked prefill (between-chunk
            # eviction, §17) only live-window + sink + one-chunk blocks.
            self._slot_demand = window_demand_blocks(
                self.window_spec, self.max_blocks, prefill_chunk_tokens,
                block_size)
            # Retained (LRU) prefix blocks live in pool surplus BEYOND the
            # worst-case slot reservation, so the in-tick allocator can
            # never be starved by the cache (DESIGN.md §10).
            min_blocks = slots * self._slot_demand + 1 + self.lru_capacity
            # An undersized pool is legal WITH preemption (§13): the pool
            # only has to back one slot's worst-case residency, so a
            # preempted request can always be replayed once the others
            # drain. Below that floor not even a lone request fits and no
            # policy can help. (Windowed + chunked engines shrink the floor
            # to window + chunk blocks: §17 long-context sizing.)
            floor_blocks = self._slot_demand + 1 + self.lru_capacity
            if num_blocks is not None and num_blocks < floor_blocks:
                raise ValueError(
                    f"num_blocks={num_blocks} can't back even one slot at "
                    f"max_seq={max_seq} with {self.lru_capacity} retained "
                    f"prefix blocks (need >= {floor_blocks})")
            undersized = num_blocks is not None and num_blocks < min_blocks
            self.preemption = undersized if preemption == "auto" \
                else bool(preemption)
            if undersized and not self.preemption:
                # without the in-tick preemption branch an exhausted free
                # stack would silently alias a live block into two slots
                raise ValueError(
                    f"num_blocks={num_blocks} can't back {slots} slots at "
                    f"max_seq={max_seq} with {self.lru_capacity} retained "
                    f"prefix blocks (need >= {min_blocks}); pass "
                    f"preemption=True (or leave it 'auto') to oversubscribe "
                    f"the pool with victim preemption")
            self.num_blocks = num_blocks or min_blocks
            self.cache = tfm.init_paged_cache(cfg, slots, self.num_blocks,
                                              block_size,
                                              kv_dtype=self._kv_store,
                                              kv_spec=self.kv_spec)
            self.alloc = kv_pool.init_alloc(self.num_blocks, slots,
                                            self.max_blocks)
        else:
            # nothing to page: every slot owns its contiguous rows, so the
            # in-tick exhaustion path can't exist; host-side ``preempt()``
            # still works (deadlines / fault injection).
            self.preemption = False
            self.cache = tfm.init_cache(cfg, slots, max_seq,
                                        kv_dtype=self._kv_store,
                                        kv_spec=self.kv_spec)
            self.alloc = None
        self._assert_kv_contract()
        self.admission = admission
        self._clock = clock
        # Continuous batching (DESIGN.md §15): with ``prefill_chunk_tokens``
        # set, admission binds a request to a free slot immediately and its
        # prompt prefills in fixed-size chunks interleaved with decode
        # ticks, at most ``tick_token_budget`` prompt tokens started per
        # tick (default: one chunk's worth, falling back to the
        # AdmissionConfig's budget if it carries one). ``None`` keeps the
        # wave scheduler: whole-prompt prefill at admission.
        self.prefill_chunk_tokens = prefill_chunk_tokens
        if prefill_chunk_tokens is not None and prefill_chunk_tokens < 1:
            raise ValueError(f"prefill_chunk_tokens must be >= 1 or None: "
                             f"{prefill_chunk_tokens}")
        if tick_token_budget is None and admission is not None:
            tick_token_budget = admission.tick_token_budget
        if tick_token_budget is None:
            tick_token_budget = prefill_chunk_tokens
        if tick_token_budget is not None and tick_token_budget < 1:
            raise ValueError(f"tick_token_budget must be >= 1 or None: "
                             f"{tick_token_budget}")
        self.tick_token_budget = tick_token_budget
        self._has_state = any(k in ("ssm", "recurrent") for k in kinds)
        self._ssm_arch = "ssm" in kinds
        # slot -> in-flight chunked-prefill record (PREFILLING slots); the
        # device row stays inactive until the final chunk arms it
        self._pending: dict[int, dict] = {}
        # host side of the prefix cache: chain-hash of full-block prompt
        # content -> physical block id, plus live-request counts per key
        self._prefix_map: dict[Any, int] = {}
        self._key_refs: dict[Any, int] = {}
        # LRU retention (ROADMAP item): keys whose last live user retired
        # but whose physical block the cache still holds (device ref +1),
        # in eviction order. Only keys in ``_cache_held`` carry that ref.
        self._lru: "collections.OrderedDict[Any, int]" = \
            collections.OrderedDict()
        self._cache_held: set = set()
        # Device-resident generation state: one row per slot. The sampling
        # rows (key / temperature / top-k / top-p / stop) are the lowered
        # form of each slot's SamplingParams (DESIGN.md §12), written once
        # at admission so the tick samples without any host traffic.
        self.max_stop = max_stop
        # gen / stamp feed the §13 preemption victim policy (fewest
        # generated tokens, oldest admission stamp on ties); bomb is the
        # fault-injection seam — a per-slot additive logit perturbation,
        # cleared whenever the slot is (re-)armed.
        self.state = {
            "last_tok": jnp.zeros((slots,), jnp.int32),
            "active": jnp.zeros((slots,), bool),
            "remaining": jnp.zeros((slots,), jnp.int32),
            "key": jnp.zeros((slots, 2), jnp.uint32),
            "temp": jnp.zeros((slots,), jnp.float32),
            "top_k": jnp.zeros((slots,), jnp.int32),
            "top_p": jnp.ones((slots,), jnp.float32),
            "stop": jnp.full((slots, max_stop), -1, jnp.int32),
            "gen": jnp.zeros((slots,), jnp.int32),
            "stamp": jnp.zeros((slots,), jnp.int32),
            "bomb": jnp.zeros((slots,), jnp.float32),
        }
        self.slot_req: list[Request | None] = [None] * slots
        self.waiting = WaitingQueue()
        self.finished: list[Request] = []
        # seed stream for requests that don't pin one (deterministic per
        # engine instance, not across processes) + facade request ids
        self._seed_rng = np.random.default_rng(0x5EED)
        # facade rids start high so they can't collide with hand-numbered
        # Requests submitted alongside a generate() batch
        self._auto_rid = itertools.count(1 << 20)
        self._seq_counter = itertools.count()       # submission order
        self._stamp_counter = itertools.count(1)    # admission order
        self._stolen: list = []                     # fault-injected steals
        # Perf accounting (consumed by benchmarks/run.py --json):
        #   prefill_forwards       batched prompt forwards actually run
        #   seed_equiv_forwards    decode_step forwards the seed's
        #                          scan-of-decode-steps prefill would have run
        #                          (one per prompt token, each slots wide)
        #   prefix_hit_blocks /    paged: prompt blocks served from the
        #     prompt_blocks        prefix cache vs total full prompt blocks
        #   shared_admissions      admissions that skipped the prefill
        #                          forward entirely (fully cached prompt)
        #   tick_syncs / admit_syncs   the host-sync ledger (DESIGN.md §12):
        #                          every device_get on the serving path is
        #                          counted at its call site, so the §8
        #                          one-sync-per-tick contract is a tested
        #                          number, not a comment (pool_stats() is
        #                          benchmarking-only and ledgered separately)
        #   preemptions / resumed_admissions / rejected_requests /
        #     deadline_expired / nan_failures   the §13 failure-model
        #                          counters: victim evictions, replays after
        #                          eviction, submit-time rejections, deadline
        #                          expiries, non-finite-logit failures
        self.stats = {"prefill_forwards": 0, "tail_forwards": 0,
                      "teacher_steps": 0, "prefill_chunks": 0,
                      "prompt_tokens": 0, "seed_equiv_forwards": 0,
                      "decode_ticks": 0, "generated_tokens": 0,
                      "prefix_hit_blocks": 0, "prompt_blocks": 0,
                      "shared_admissions": 0, "cow_copies": 0,
                      "preemptions": 0, "resumed_admissions": 0,
                      "rejected_requests": 0, "deadline_expired": 0,
                      "nan_failures": 0,
                      "tick_syncs": 0, "admit_syncs": 0, "stat_syncs": 0,
                      "prefill_time_s": 0.0, "decode_time_s": 0.0}

        # The small frozen specs (bits/ranges) ride as jit closure
        # constants; the packed codes are passed as a jit ARGUMENT so the
        # (potentially large) artifact isn't baked into every compiled
        # executable — _tick plus each per-bucket _prefill specialization
        # would otherwise embed its own copy.
        specs = self.specs

        def _qc(qweights):
            if quant_state is None:
                return QuantContext(mode="off")
            return QuantContext(
                mode="serve", cfg=quant_state["qcfg"], specs=specs,
                qweights=qweights, matmul_impl=matmul_impl,
            )

        paged = self.paged
        preemption = self.preemption
        # §17 closure constants: the (window, sink_tokens) tuple threads
        # into every model entry point; the block-granular split drives the
        # in-tick eviction pass.
        wmask = self._window
        if wmask is not None:
            win_w, win_sinks = wmask
            win_sink_blocks = win_sinks // block_size
        else:
            win_w = win_sink_blocks = 0

        @jax.jit
        def _tick(params, qweights, cache, state, alloc):
            """One device-resident generation step for the whole batch.

            Sampling (per-slot temperature / top-k / top-p off the slot's
            key chain; zero-temperature rows take the bit-exact argmax), the
            per-slot position bump (via ``advance``), stop-token detection,
            the done-flag updates — and, in the paged layout, the free-list
            pop for rows entering an unallocated block, preceded on an
            oversubscribed pool by §13 victim preemption — all happen on
            device. The non-finite-logit guard runs here too: rows whose
            logits went NaN/Inf (model blow-up or an injected ``bomb``) are
            not emitted and deactivate in place. The caller fetches
            (next_tokens, emitted, done, preempted, bad) in a single host
            transfer — the failure masks ride the same sync the stats
            ledger already pays for, so the §8 contract holds under faults.
            """
            table = None
            live = state["active"]
            pre = jnp.zeros_like(live)
            if paged:
                if wmask is not None:
                    # §17 out-of-window eviction: release every block wholly
                    # behind the sliding window (sink blocks pinned) BEFORE
                    # preemption/allocation, so freed blocks relieve pool
                    # pressure within the same tick. ``fl`` matches the
                    # kernel's first-live-block walk exactly, so no evicted
                    # block is ever read.
                    fl = jnp.maximum(
                        (cache["pos"] - win_w + 1) // block_size,
                        win_sink_blocks)
                    alloc = kv_pool.evict_out_of_window(
                        alloc, fl, live, win_sink_blocks)
                if preemption:
                    alloc, pre = kv_pool.preempt_for_free(
                        alloc, cache["pos"], live, state["gen"],
                        state["stamp"], block_size)
                    live = live & ~pre
                alloc = kv_pool.tick_alloc(alloc, cache["pos"], live,
                                           block_size)
                table = alloc["table"]
            logits, cache = tfm.decode_step(
                _qc(qweights), params, cache, state["last_tok"], cfg,
                plan=plan, advance=live, block_table=table, window=wmask)
            pair = jax.vmap(jax.random.split)(state["key"])
            rows = logits[:, 0, : cfg.vocab_size] + state["bomb"][:, None]
            ok = finite_rows(rows)
            emitted = live & ok
            bad = live & ~ok
            # gate idle rows' (stale) temperature to 0 so a retired sampled
            # request can't defeat the all-greedy lax.cond fast path
            temp = jnp.where(emitted, state["temp"], 0.0)
            nxt = sample_tokens(rows, pair[:, 1], temp, state["top_k"],
                                state["top_p"])
            nxt = jnp.where(emitted, nxt, state["last_tok"])
            # keys advance only on emission, so a request's position in its
            # key chain equals its emitted-token count — slot placement,
            # admission order, KV layout and preemption can't perturb the
            # stream
            key = jnp.where(emitted[:, None], pair[:, 0], state["key"])
            hit_stop = (nxt[:, None] == state["stop"]).any(axis=-1)
            remaining = state["remaining"] - emitted.astype(jnp.int32)
            done_now = emitted & ((remaining <= 0) | hit_stop)
            state = {**state, "last_tok": nxt, "active": emitted & ~done_now,
                     "remaining": remaining, "key": key,
                     "gen": state["gen"] + emitted.astype(jnp.int32)}
            return cache, state, alloc, nxt, emitted, done_now, pre, bad

        self._tick = _tick

        @jax.jit
        def _prefill(params, qweights, cache, table, toks, plen, slot,
                     start_blk):
            """Admit one request: batched prefill into the slot.

            Specializes per padded prompt-bucket shape; ``plen``/``slot``/
            ``start_blk`` are traced, so admissions don't recompile. In the
            paged layout ``table`` is the block table and ``start_blk``
            skips writing a shared prompt prefix. Returns the final prompt
            position's logits row — ``_arm`` samples the first token from
            it, so every admission path shares ONE sampling seam.
            """
            logits, cache = tfm.prefill_slot(
                _qc(qweights), params, toks, plen, cache, slot, cfg,
                plan=plan, block_table=table if paged else None,
                start_blk=start_blk, window=wmask)
            return cache, logits[0, plen - 1, : cfg.vocab_size]

        self._prefill = _prefill

        @jax.jit
        def _prefill_tail(params, qweights, cache, toks, slot):
            """Continue an SSM prefill: absorb the < ssm_chunk remainder in
            one batched forward threading the slot's carried recurrent state
            into the chunked scan (DESIGN.md §8)."""
            logits, cache = tfm.prefill_slot_tail(
                _qc(qweights), params, toks, cache, slot, cfg, plan=plan)
            return cache, logits[0, -1, : cfg.vocab_size]

        self._prefill_tail = _prefill_tail

        @jax.jit
        def _prefill_chunk(params, qweights, cache, table, toks, clen, slot,
                           pos0):
            """One chunk of a chunk-resumable prefill (DESIGN.md §15): run
            ``clen`` prompt tokens (``toks`` may be right-padded to a bucket
            shape) into the slot's KV/state at absolute offset ``pos0``.
            ``clen``/``slot``/``pos0`` are traced, so every chunk of every
            admission shares one compilation per padded shape. Returns the
            chunk's final position's logits row — only the LAST chunk's row
            is consumed (by ``_arm``), keeping the one-sampling-seam
            contract."""
            logits, cache = tfm.prefill_chunk(
                _qc(qweights), params, toks, clen, cache, slot, cfg,
                pos0=pos0, plan=plan,
                block_table=table if paged else None, window=wmask)
            return cache, logits[0, clen - 1, : cfg.vocab_size]

        self._prefill_chunk = _prefill_chunk

        @jax.jit
        def _teacher_step(params, qweights, cache, state, table, tok, slot):
            """Teacher-forced decode of one PROMPT token into one slot.

            Used to replay the sub-block remainder of a prefix-shared
            admission. Only ``slot`` advances (and, paged, only it writes);
            every other row's cache state is untouched, so concurrent slots
            are unaffected. Returns the slot's logits row (consumed only by
            the final replay step, via ``_arm``).
            """
            toks = state["last_tok"].at[slot].set(tok)
            adv = jnp.zeros((slots,), jnp.int32).at[slot].set(1)
            logits, cache = tfm.decode_step(
                _qc(qweights), params, cache, toks, cfg, plan=plan,
                advance=adv, block_table=table if paged else None,
                window=wmask)
            return cache, logits[slot, 0, : cfg.vocab_size]

        self._teacher_step = _teacher_step

        @jax.jit
        def _arm(state, slot, logits_row, temp, top_k, top_p, key, stop_row,
                 max_new, stamp):
            """Arm a slot for generation: lower the request's SamplingParams
            into the slot's state rows and sample its FIRST token from the
            admission logits — the one sampling seam shared by every
            admission path (batched prefill, SSM tail, teacher-forced
            prefix replay). All operands are traced, so admissions with
            different params never recompile. ``ok`` (returned alongside the
            first token, fetched in the same batched admission sync) is the
            §13 non-finite guard on the admission logits: a False row arms
            INACTIVE so retirement can free it without a device round-trip.
            """
            pair = jax.random.split(key)
            ok = jnp.isfinite(logits_row).all()
            first = sample_tokens(logits_row[None], pair[1][None],
                                  temp[None], top_k[None], top_p[None])[0]
            remaining = jnp.asarray(max_new, jnp.int32) - 1
            return {
                "last_tok": state["last_tok"].at[slot].set(first),
                "active": state["active"].at[slot].set(ok & (remaining > 0)),
                "remaining": state["remaining"].at[slot].set(remaining),
                "key": state["key"].at[slot].set(pair[0]),
                "temp": state["temp"].at[slot].set(temp),
                "top_k": state["top_k"].at[slot].set(top_k),
                "top_p": state["top_p"].at[slot].set(top_p),
                "stop": state["stop"].at[slot].set(stop_row),
                "gen": state["gen"].at[slot].set(1),
                "stamp": state["stamp"].at[slot].set(stamp),
                "bomb": state["bomb"].at[slot].set(0.0),
            }, first, ok

        self._arm = _arm

        @jax.jit
        def _rearm(state, slot, last_tok, temp, top_k, top_p, key, stop_row,
                   remaining, gen, stamp):
            """Re-arm a preempted request's slot after its replay (§13): no
            sampling — the resumed stream continues the original key chain
            from ``key`` (recomputed by ``_replay_key``) with ``last_tok``
            = the last token emitted before eviction, so the next tick
            produces exactly the token the unpreempted run would have."""
            return {
                "last_tok": state["last_tok"].at[slot].set(last_tok),
                "active": state["active"].at[slot].set(remaining > 0),
                "remaining": state["remaining"].at[slot].set(remaining),
                "key": state["key"].at[slot].set(key),
                "temp": state["temp"].at[slot].set(temp),
                "top_k": state["top_k"].at[slot].set(top_k),
                "top_p": state["top_p"].at[slot].set(top_p),
                "stop": state["stop"].at[slot].set(stop_row),
                "gen": state["gen"].at[slot].set(gen),
                "stamp": state["stamp"].at[slot].set(stamp),
                "bomb": state["bomb"].at[slot].set(0.0),
            }

        self._rearm = _rearm

        @jax.jit
        def _replay_key(seed, k):
            """The slot key after ``k`` emitted tokens of a request seeded
            with ``seed``: arming splits once and each emission advances
            ``key -> split(key)[0]`` — ``k`` is traced, so resumes at any
            depth share one compilation."""
            key = jax.random.PRNGKey(seed)
            return jax.lax.fori_loop(
                0, k, lambda _, kk: jax.random.split(kk)[0], key)

        self._replay_key = _replay_key

        self._set_bomb = jax.jit(
            lambda state, slot, v:
            {**state, "bomb": state["bomb"].at[slot].set(v)})

        @jax.jit
        def _deactivate(state, slot):
            """Host-side retirement of a slot the device still thinks is
            live (first token hit a stop token): without this the row would
            keep generating — and, paged, keep popping free blocks — after
            its request retired."""
            return {**state,
                    "active": state["active"].at[slot].set(False),
                    "remaining": state["remaining"].at[slot].set(0)}

        self._deactivate = _deactivate

        if self.paged:
            self._alloc_range = jax.jit(kv_pool.alloc_range)
            self._evict_window = jax.jit(kv_pool.evict_out_of_window,
                                         static_argnums=(3,))
            self._share_prefix = jax.jit(kv_pool.share_prefix)
            self._free_slot_op = jax.jit(kv_pool.free_slot)
            self._retain_block = jax.jit(kv_pool.retain_block)
            self._release_block = jax.jit(kv_pool.release_block)
            self._steal = jax.jit(kv_pool.steal_blocks)
            self._unsteal = jax.jit(kv_pool.unsteal_blocks)
            self._set_pos = jax.jit(
                lambda cache, slot, p:
                {**cache, "pos": cache["pos"].at[slot].set(p)})

            @jax.jit
            def _cow(alloc, cache, slot, blk):
                alloc, layers = kv_pool.cow_block(alloc, cache["layers"],
                                                  slot, blk)
                return alloc, {**cache, "layers": layers}

            self._cow = _cow

    # ------------------------------------------------------------------
    def _prefill_shape(self, plen: int) -> tuple[int, int]:
        """(batched-forward length, teacher-forced tail length) per prompt.

        Attention-only archs right-pad to a power-of-two bucket (padding is
        masked, see tfm.prefill_slot). Recurrent state (ssm / rglru) is an
        unconditional scan over every input position with no masking
        analogue, so those archs prefill at the exact prompt length —
        ssd_chunked additionally requires chunk-multiple lengths, so SSM
        prompts run the largest chunk-aligned prefix in the batched forward
        and teacher-force the < chunk remaining tokens through decode steps.
        """
        kinds = list(self.cfg.block_pattern) + list(self.cfg.remainder_kinds)
        if "ssm" in kinds:
            cs = self.cfg.ssm_chunk
            if plen <= cs:
                return plen, 0
            l0 = (plen // cs) * cs
            return l0, plen - l0
        if "recurrent" in kinds:
            return plen, 0
        b = 8
        while b < plen:
            b *= 2
        return min(b, self.max_seq), 0

    def _chunk_len(self, remaining: int) -> int:
        """Length of the next prefill chunk given ``remaining`` prompt
        tokens (DESIGN.md §15). Chunk boundaries are canonical — a function
        of position only, never of budget or pool pressure — so the chunked
        forward's internal groupings (ssd_chunked's chunk scan, the
        recurrent left fold) are identical no matter how ticks interleave.
        SSM archs additionally align every boundary to ``ssm_chunk`` (the
        chunked-scan grouping is length-dependent below that), with the
        < ssm_chunk remainder as the exact-length final chunk."""
        c = min(self.prefill_chunk_tokens, remaining)
        if self._ssm_arch:
            cs = self.cfg.ssm_chunk
            if remaining >= cs:
                c = min(max((c // cs) * cs, cs), (remaining // cs) * cs)
            else:
                c = remaining
        return c

    def _chunk_shape(self, clen: int) -> int:
        """Padded device shape for a ``clen``-token chunk. Attention-only
        archs bucket to a power of two (padding is masked and never
        written); recurrent-state archs scan every input position
        unconditionally, so they run at the exact chunk length."""
        if self._has_state:
            return clen
        b = 8
        while b < clen:
            b *= 2
        return min(b, self.max_seq)

    def _validate_request(self, req: Request):
        """Uniform ValueError at the API boundary (§13): malformed requests
        used to surface as shape errors or silent garbage deep in prefill.
        ``max_new <= 0`` is already rejected by ``SamplingParams`` at
        construction — the remaining holes are all prompt-shaped."""
        if len(req.params.stop) > self.max_stop:
            raise ValueError(
                f"request {req.rid} has {len(req.params.stop)} stop tokens; "
                f"engine holds {self.max_stop} per slot (max_stop=...)")
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"request {req.rid}: prompt must be a non-empty 1-D token "
                f"sequence (got shape {prompt.shape})")
        if prompt.size > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {prompt.size} exceeds "
                f"max_seq={self.max_seq}")
        if not np.issubdtype(prompt.dtype, np.integer):
            ids = prompt.astype(np.int64, casting="unsafe")
            if not np.array_equal(ids, prompt):
                raise ValueError(
                    f"request {req.rid}: prompt must hold integer token ids "
                    f"(got dtype {prompt.dtype})")
        vocab = self.cfg.vocab_size
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= vocab:
            raise ValueError(
                f"request {req.rid}: prompt token ids outside [0, {vocab}) "
                f"(min {lo}, max {hi})")

    def _reject(self, req: Request) -> Request:
        req.finish_reason = FINISHED_REJECTED
        req.done = True
        req.finish_s = self._clock()
        self.finished.append(req)
        self.stats["rejected_requests"] += 1
        return req

    def submit(self, req: Request) -> Request:
        """Enqueue one validated request. Under an ``AdmissionConfig`` with
        a full queue this is where backpressure lives (§13): ``reject``
        finishes the request immediately with ``FINISHED_REJECTED``,
        ``block`` drives engine ticks inline until a queue slot frees
        (``evict_lru_prefix`` first drops retained prefix blocks to help
        the pool drain). Returns the request (possibly already done)."""
        self._validate_request(req)
        req.prompt = np.asarray(req.prompt, np.int32)
        ad = self.admission
        if ad is not None and ad.queue_capacity is not None \
                and len(self.waiting) >= ad.queue_capacity:
            if ad.on_full == "evict_lru_prefix":
                self._drop_retained()
            if ad.on_full in ("block", "evict_lru_prefix"):
                for _ in range(ad.block_max_ticks):
                    if len(self.waiting) < ad.queue_capacity:
                        break
                    self.step()
            if len(self.waiting) >= ad.queue_capacity:
                return self._reject(req)
        if req.seq is None:
            req.seq = next(self._seq_counter)
        now = self._clock()
        req.submit_s = now
        ttft = req.ttft_deadline_s if req.ttft_deadline_s is not None \
            else (ad.ttft_deadline_s if ad else None)
        wall = req.deadline_s if req.deadline_s is not None \
            else (ad.deadline_s if ad else None)
        req.ttft_by = now + ttft if ttft is not None else math.inf
        req.deadline_by = now + wall if wall is not None else math.inf
        self.waiting.push(req)
        return req

    def _sync(self, tree, kind: str):
        """Host transfer + ledger entry: every ``device_get`` on the serving
        path goes through here, so ``stats["tick_syncs"]`` /
        ``stats["admit_syncs"]`` are an audited count, and the §8/§12
        one-sync-per-tick contract is testable."""
        self.stats[kind + "_syncs"] += 1
        return jax.device_get(tree)

    def _param_rows(self, req: Request):
        """Lower a request's SamplingParams to the traced operands ``_arm``
        writes into the slot's device state rows. The effective seed is
        PINNED on the request at first admission (``seed_used``): a
        seedless request that gets preempted must resume the same key
        chain, not redraw (§13)."""
        p = req.params
        if req.seed_used is None:
            req.seed_used = p.seed if p.seed is not None \
                else int(self._seed_rng.integers(2**31 - 1))
        stop = np.full((self.max_stop,), -1, np.int32)
        stop[: len(p.stop)] = p.stop
        return (jnp.asarray(p.temperature, jnp.float32),
                jnp.asarray(p.top_k, jnp.int32),
                jnp.asarray(p.top_p, jnp.float32),
                jax.random.PRNGKey(req.seed_used),
                jnp.asarray(stop),
                p.max_new)

    # ------------------------------------------------------------------
    # Prefix cache (host side; DESIGN.md §10)
    # ------------------------------------------------------------------

    def _block_keys(self, prompt: np.ndarray):
        """Chain-digest keys for the prompt's FULL blocks: key_j hashes
        key_{j-1} with block j's tokens, so it commits to the entire content
        of blocks 0..j and equal keys imply equal prefixes — at O(1) key
        size and O(plen) total work per admission (a nested-tuple chain
        would re-hash the whole prefix on every map probe).

        §17 sink-block contract: under a windowed engine, sharing and
        registration are restricted to the pinned sink region — sink blocks
        are the only blocks the out-of-window eviction pass can never free,
        so a ``_prefix_map`` entry can't go stale pointing at a recycled
        physical block. (A windowed engine with ``sink_blocks=0`` therefore
        does no prefix sharing at all.)"""
        bs = self.block_size
        nmax = len(prompt) // bs
        if self.window_spec is not None:
            nmax = min(nmax, self.window_spec.sink_blocks)
        keys, h = [], b""
        for j in range(nmax):
            h = hashlib.blake2b(
                h + np.ascontiguousarray(prompt[j * bs:(j + 1) * bs],
                                         np.int32).tobytes(),
                digest_size=16).digest()
            keys.append(h)
        return keys

    def _admit_paged(self, s: int, req: Request, prompt: np.ndarray):
        """Paged admission: map any cached prompt prefix onto its existing
        physical blocks, allocate the rest, and prefill only what the cache
        can't supply. Returns the final prompt position's logits row (the
        caller samples the first token from it via ``_arm``)."""
        plen = len(prompt)
        bs = self.block_size
        nblk = -(-plen // bs)
        fb = plen // bs
        keys = self._block_keys(prompt) if self.prefix_sharing else []
        shared: list[int] = []
        for key in keys:
            if key not in self._prefix_map:
                break
            shared.append(self._prefix_map[key])
        ns = len(shared)
        if ns:
            phys = np.zeros((self.max_blocks,), np.int32)
            phys[:ns] = shared
            self.alloc = self._share_prefix(self.alloc, s,
                                            jnp.asarray(phys), ns)
        if nblk > ns:
            self.alloc = self._alloc_range(self.alloc, s, ns, nblk - ns)

        if ns and ns == fb:
            # Fully cached prompt: NO prefill forward. Teacher-force the sub-
            # block remainder (and at least the final prompt token, which
            # must run to produce the first-token logits). A block-aligned
            # prompt replays its last token INTO the shared final block, so
            # that block is copy-on-write'd to a private one first.
            r = plen - ns * bs
            t0 = ns * bs if r else plen - 1
            kept_keys = keys[:ns]
            if r == 0:
                self.alloc, self.cache = self._cow(self.alloc, self.cache,
                                                   s, fb - 1)
                self.stats["cow_copies"] += 1
                # after CoW this slot no longer maps the registered physical
                # block for the final key — holding it would keep the map
                # entry alive past the block's device refcount reaching 0
                # (a later sharer would then map a freed/recycled block)
                kept_keys = keys[:ns - 1]
            self.cache = self._set_pos(self.cache, s, t0)
            row = None
            for t in prompt[t0:]:
                self.cache, row = self._teacher_step(
                    self.params, self.qweights, self.cache, self.state,
                    self.alloc["table"], jnp.asarray(int(t), jnp.int32), s)
                self.stats["teacher_steps"] += 1
            self.stats["shared_admissions"] += 1
            req.prefix_keys = kept_keys
        else:
            l0, tail = self._prefill_shape(plen)
            # tail > 0 only for hybrid ssm+attention archs (pure-SSM archs
            # take the ring/state layout): the attention layers rule out the
            # state-threaded tail forward, so teacher-force the remainder.
            toks = np.zeros((1, max(l0, plen - tail)), np.int32)
            toks[0, : plen - tail] = prompt[: plen - tail]
            self.cache, row = self._prefill(
                self.params, self.qweights, self.cache,
                self.alloc["table"], jnp.asarray(toks), plen - tail, s, ns)
            self.stats["prefill_forwards"] += 1
            for t in prompt[plen - tail:]:
                self.cache, row = self._teacher_step(
                    self.params, self.qweights, self.cache, self.state,
                    self.alloc["table"], jnp.asarray(int(t), jnp.int32), s)
                self.stats["teacher_steps"] += 1
            if keys:
                # register this prompt's full blocks for later sharers; the
                # table row read is an admission-time sync, not a tick sync
                trow = np.asarray(self._sync(self.alloc["table"][s],
                                             "admit"))
                for j, key in enumerate(keys):
                    if key not in self._prefix_map:
                        self._prefix_map[key] = int(trow[j])
                        if self.lru_capacity > 0:
                            # LRU retention: the cache itself holds a device
                            # ref, so the block outlives its live users
                            self.alloc = self._retain_block(
                                self.alloc, jnp.asarray(int(trow[j]),
                                                        jnp.int32))
                            self._cache_held.add(key)
                req.prefix_keys = keys
        for key in req.prefix_keys:
            self._key_refs[key] = self._key_refs.get(key, 0) + 1
        self._touch_lru(keys)
        self.stats["prefix_hit_blocks"] += ns
        self.stats["prompt_blocks"] += fb
        return row

    def _admit_ring(self, s: int, req: Request, prompt: np.ndarray):
        """Contiguous-layout admission. SSM prompts run the chunk-aligned
        prefix in one forward, then absorb the < ssm_chunk remainder in a
        SECOND batched forward that threads the slot's recurrent state into
        the chunked scan (``prefill_slot_tail``) — no teacher-forced single
        steps. A hybrid arch mixing recurrent-state and attention blocks
        can't take the tail forward (attention has no carried state to
        resume from), so its tail falls back to teacher-forced steps.
        Returns the final prompt position's logits row."""
        plen = len(prompt)
        l0, tail = self._prefill_shape(plen)
        toks = np.zeros((1, max(l0, plen - tail)), np.int32)
        toks[0, : plen - tail] = prompt[: plen - tail]
        self.cache, row = self._prefill(
            self.params, self.qweights, self.cache, None,
            jnp.asarray(toks), plen - tail, s, 0)
        self.stats["prefill_forwards"] += 1
        if tail and self._state_only:
            tail_toks = np.asarray(prompt[plen - tail:], np.int32)[None, :]
            self.cache, row = self._prefill_tail(
                self.params, self.qweights, self.cache,
                jnp.asarray(tail_toks), s)
            self.stats["tail_forwards"] += 1
        elif tail:
            for t in prompt[plen - tail:]:
                self.cache, row = self._teacher_step(
                    self.params, self.qweights, self.cache, self.state,
                    None, jnp.asarray(int(t), jnp.int32), s)
                self.stats["teacher_steps"] += 1
        return row

    # ------------------------------------------------------------------
    # Prefix-cache LRU retention (DESIGN.md §10)
    # ------------------------------------------------------------------

    def _touch_lru(self, keys):
        """Re-derive LRU membership for ``keys``: cache-held keys with zero
        live users sit in the LRU (most-recently-touched last); any live use
        lifts them out. Then evict past capacity (oldest first), dropping
        the cache's device ref — the only place retained blocks are
        released, so capacity bounds cache-only blocks and the pool surplus
        covers them."""
        for key in keys:
            if key not in self._cache_held:
                continue
            if self._key_refs.get(key, 0) == 0:
                self._lru[key] = self._prefix_map[key]
                self._lru.move_to_end(key)
            else:
                self._lru.pop(key, None)
        while len(self._lru) > self.lru_capacity:
            key, blk = self._lru.popitem(last=False)
            self._cache_held.discard(key)
            self._prefix_map.pop(key, None)
            self.alloc = self._release_block(self.alloc,
                                             jnp.asarray(blk, jnp.int32))

    def _drop_prefix_refs(self, req: Request):
        """Release the host side of a request's hold on its prefix keys
        (shared by retirement and preemption)."""
        for key in req.prefix_keys:
            self._key_refs[key] -= 1
            if self._key_refs[key] == 0:
                del self._key_refs[key]
                if key not in self._cache_held:
                    self._prefix_map.pop(key, None)
        self._touch_lru(req.prefix_keys)

    def _drop_retained(self):
        """Evict the entire retained-prefix LRU (the ``evict_lru_prefix``
        on-full policy): every cache-only block goes back on the free
        stack, trading prefix hits for pool headroom."""
        while self._lru:
            key, blk = self._lru.popitem(last=False)
            self._cache_held.discard(key)
            self._prefix_map.pop(key, None)
            self.alloc = self._release_block(self.alloc,
                                             jnp.asarray(blk, jnp.int32))

    def _retire(self, s: int, req: Request):
        req.done = True
        req.finish_s = self._clock()
        self.finished.append(req)
        self.slot_req[s] = None
        self._pending.pop(s, None)
        if self.paged:
            self.alloc = self._free_slot_op(self.alloc, s)
            self._drop_prefix_refs(req)

    def _requeue_slot(self, s: int, *, blocks_freed: bool):
        """Host side of a preemption (§13): detach the victim request from
        its slot and put it back on the waiting queue with its original
        submission seq (so re-admission sorts ahead of newer arrivals).
        ``blocks_freed`` says whether the device already freed the slot's
        blocks (the in-tick path did; host-side ``preempt()`` hasn't).
        Returns a terminal TokenEvent if resuming is impossible."""
        req = self.slot_req[s]
        self.slot_req[s] = None
        self._pending.pop(s, None)
        if self.paged:
            if not blocks_freed:
                self.alloc = self._free_slot_op(self.alloc, s)
            self._drop_prefix_refs(req)
            req.prefix_keys = []
        req.preemptions += 1
        self.stats["preemptions"] += 1
        # resume replays prompt + output[:-1] into one slot, so it must fit
        # a slot's cache; a request oversubscribed past max_seq can't be
        # replayed (the unpreempted run would have overrun its row too)
        if len(req.prompt) + len(req.output) - 1 > self.max_seq:
            req.finish_reason = FINISHED_ERROR
            req.done = True
            req.finish_s = self._clock()
            self.finished.append(req)
            return TokenEvent(rid=req.rid, token=-1, index=len(req.output),
                              done=True, finish_reason=FINISHED_ERROR)
        self.waiting.push(req)
        return None

    def preempt(self, slot: int):
        """Forcibly preempt one running slot from the host (both KV
        layouts): deadline policy and the fault injector use this; the
        in-tick exhaustion path never does (it frees blocks on device
        inside the tick). The request re-queues and resumes normally."""
        if self.slot_req[slot] is None:
            return None
        self.state = self._deactivate(self.state, slot)
        return self._requeue_slot(slot, blocks_freed=False)

    def _expire_deadlines(self) -> list:
        """Expire waiting requests past their TTFT/wall budget and running
        requests past their wall deadline (§13). Host-side bookkeeping
        only — no device sync; an expiry surfaces as a terminal
        ``TokenEvent`` with the ``-1`` sentinel token."""
        now = self._clock()
        events = []
        for req in self.waiting.expired(now):
            self.waiting.remove(req)
            req.finish_reason = FINISHED_DEADLINE
            req.done = True
            req.finish_s = now
            self.finished.append(req)
            self.stats["deadline_expired"] += 1
            events.append(TokenEvent(rid=req.rid, token=-1,
                                     index=len(req.output), done=True,
                                     finish_reason=FINISHED_DEADLINE))
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            # a PREFILLING slot has emitted nothing yet, so its TTFT budget
            # still applies (same rule the waiting queue uses); armed slots
            # only answer to the wall deadline
            by = req.deadline_key if s in self._pending else req.deadline_by
            if by > now:
                continue
            self.state = self._deactivate(self.state, s)
            req.finish_reason = FINISHED_DEADLINE
            self._retire(s, req)
            self.stats["deadline_expired"] += 1
            events.append(TokenEvent(rid=req.rid, token=-1,
                                     index=len(req.output), done=True,
                                     finish_reason=FINISHED_DEADLINE))
        return events

    def _can_start(self, req: Request) -> bool:
        """Watermark + free-stack gate on starting a prefill (§13).

        The watermark is pure host arithmetic over worst-case projections.
        On an oversubscribed (preemption-enabled) pool there is a second,
        exact check: the admission-time fills (``alloc_range`` / CoW) have
        no in-tick preemption to save them, so the replay's immediate block
        demand must fit the actual free stack — that read is a small
        admission-time sync, ledgered under ``admit_syncs``, and only ever
        paid by engines that chose to oversubscribe."""
        if not self.paged:
            return True
        ad = self.admission
        # §17: a windowed engine's worst-case residency per slot is the
        # window demand (live-window + sink + one-chunk blocks), not the
        # full sequence — the in-tick eviction pass keeps every slot at or
        # below it, so both the watermark projection and the exact
        # free-stack check cap at ``self._slot_demand``.
        nblk = -(-(len(req.prompt) + max(len(req.output) - 1, 0))
                 // self.block_size)
        nblk = min(nblk, self._slot_demand)
        if ad is not None and ad.watermark is not None:
            usable = (self.num_blocks - 1 - len(self._lru)
                      - ad.reserve_blocks)
            committed = sum(
                projected_blocks(len(r.prompt), r.max_new, self.block_size,
                                 self.max_blocks,
                                 window_blocks=self._slot_demand)
                for r in self.slot_req if r is not None)
            mine = projected_blocks(len(req.prompt), req.max_new,
                                    self.block_size, self.max_blocks,
                                    window_blocks=self._slot_demand)
            if committed + mine > ad.watermark * usable:
                return False
        if self.preemption:
            n_free = int(self._sync(self.alloc["n_free"], "admit"))
            if nblk > n_free:
                return False
        return True

    def _rearm_slot(self, s: int, req: Request, k: int):
        """Restore a resumed request's sampling state after its replay
        prefill (§13): no new sample — the key chain continues from depth
        ``k`` (= emitted tokens) and ``last_tok`` is the last pre-eviction
        emission, so the next tick produces exactly the token the
        unpreempted run would have."""
        rows = self._param_rows(req)
        self.state = self._rearm(
            self.state, s, jnp.asarray(req.output[-1], jnp.int32),
            rows[0], rows[1], rows[2],
            self._replay_key(jnp.asarray(req.seed_used, jnp.uint32),
                             jnp.asarray(k, jnp.int32)),
            rows[4], jnp.asarray(req.max_new - k, jnp.int32),
            jnp.asarray(k, jnp.int32),
            jnp.asarray(next(self._stamp_counter), jnp.int32))
        self.stats["resumed_admissions"] += 1

    def _post_arm(self, admitted) -> list:
        """Host side of arming: ONE batched transfer for the wave's first
        tokens (the §13 non-finite flags ride in the same transfer), then
        the shared bookkeeping — first-token SLO stamp, stop-at-first /
        max_new=1 retirement, TokenEvents. Shared by the wave and
        continuous schedulers so their per-request semantics can't drift."""
        events = []
        firsts = self._sync([(f, o) for _, _, f, o in admitted], "admit") \
            if admitted else []
        now = self._clock()
        for (s, req, _, _), (first, ok) in zip(admitted, firsts):
            if not bool(ok):
                # admission logits went non-finite: the row armed inactive,
                # so retirement just frees it; nothing was emitted
                req.finish_reason = FINISHED_ERROR
                self.stats["nan_failures"] += 1
                self._retire(s, req)
                events.append(TokenEvent(rid=req.rid, token=-1, index=0,
                                         done=True,
                                         finish_reason=FINISHED_ERROR))
                continue
            tok = int(first)
            req.output.append(tok)
            if req.first_token_s is None:
                req.first_token_s = now
            self.stats["generated_tokens"] += 1
            stopped = tok in req.params.stop
            if stopped or req.max_new <= 1:
                req.finish_reason = FINISHED_STOP if stopped \
                    else FINISHED_LENGTH
                if stopped and req.max_new > 1:
                    # the device armed the row for more tokens — shut it
                    # down before retirement frees its blocks
                    self.state = self._deactivate(self.state, s)
                self._retire(s, req)
            events.append(TokenEvent(rid=req.rid, token=tok,
                                     index=len(req.output) - 1,
                                     done=req.done,
                                     finish_reason=req.finish_reason))
        return events

    def _admit(self):
        if self.prefill_chunk_tokens is not None:
            return self._admit_continuous()
        return self._admit_wave()

    def _admit_wave(self):
        t0 = time.perf_counter()
        admitted = []
        resumed = 0
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            req = self.waiting.peek()
            if req is None:
                break
            if not self._can_start(req):
                # head-of-line hold: later (possibly smaller) requests do
                # NOT jump the queue — that's the no-starvation guarantee
                break
            self.waiting.pop()
            self.slot_req[s] = req
            prompt = np.asarray(req.prompt, np.int32)
            rows = self._param_rows(req)
            if req.output:
                # resume after preemption: replay prompt + generated tokens
                # through the ordinary admission path (prefix sharing and
                # all), then restore the sampling state — NO new sample
                replay = np.concatenate(
                    [prompt, np.asarray(req.output[:-1], np.int32)]) \
                    if len(req.output) > 1 else prompt
                if self.paged:
                    self._admit_paged(s, req, replay)
                else:
                    self._admit_ring(s, req, replay)
                self._rearm_slot(s, req, len(req.output))
                resumed += 1
                self.stats["prompt_tokens"] += len(replay)
                self.stats["seed_equiv_forwards"] += len(replay)
            else:
                if self.paged:
                    row = self._admit_paged(s, req, prompt)
                else:
                    row = self._admit_ring(s, req, prompt)
                self.state, first, ok = self._arm(
                    self.state, s, row, *rows,
                    jnp.asarray(next(self._stamp_counter), jnp.int32))
                self.stats["prompt_tokens"] += len(prompt)
                self.stats["seed_equiv_forwards"] += len(prompt)
                admitted.append((s, req, first, ok))
        events = self._post_arm(admitted)
        if admitted or resumed:
            self.stats["prefill_time_s"] += time.perf_counter() - t0
        return events

    # ------------------------------------------------------------------
    # Continuous batching: chunked prefill interleaved with decode
    # (DESIGN.md §15)
    # ------------------------------------------------------------------

    def _begin_prefill(self, s: int, req: Request):
        """Bind a request to a free slot as PREFILLING: build its pending
        record and, in the paged layout, map any cached prompt prefix onto
        its existing physical blocks (the chunked prefill then starts AFTER
        the shared region — it neither recomputes nor rewrites shared
        blocks). A fully cached prompt short-circuits exactly like the wave
        scheduler: teacher-force the sub-block remainder now and leave the
        record complete, so the next ``_prefill_tick`` pass arms it without
        spending any chunk budget."""
        prompt = np.asarray(req.prompt, np.int32)
        resume = bool(req.output)
        toks = prompt
        if resume and len(req.output) > 1:
            toks = np.concatenate(
                [prompt, np.asarray(req.output[:-1], np.int32)])
        st = {"req": req, "toks": toks, "resume": resume, "pos": 0,
              "blocks": 0, "ns": 0, "keys": [], "row": None,
              "registered": not self.paged,
              "order": next(self._stamp_counter)}
        self._pending[s] = st
        if not self.paged:
            return
        plen = len(toks)
        bs = self.block_size
        fb = plen // bs
        keys = self._block_keys(toks) if self.prefix_sharing else []
        st["keys"] = keys
        shared: list[int] = []
        for key in keys:
            if key not in self._prefix_map:
                break
            shared.append(self._prefix_map[key])
        ns = len(shared)
        st["ns"] = ns
        if ns:
            phys = np.zeros((self.max_blocks,), np.int32)
            phys[:ns] = shared
            self.alloc = self._share_prefix(self.alloc, s,
                                            jnp.asarray(phys), ns)
            st["blocks"] = ns
            st["pos"] = ns * bs
        if ns and ns == fb:
            # fully cached: same CoW / teacher-force path as _admit_paged
            r = plen - ns * bs
            t0 = ns * bs if r else plen - 1
            kept_keys = keys[:ns]
            if r == 0:
                self.alloc, self.cache = self._cow(self.alloc, self.cache,
                                                   s, fb - 1)
                self.stats["cow_copies"] += 1
                kept_keys = keys[:ns - 1]
            self.cache = self._set_pos(self.cache, s, t0)
            row = None
            for t in toks[t0:]:
                self.cache, row = self._teacher_step(
                    self.params, self.qweights, self.cache, self.state,
                    self.alloc["table"], jnp.asarray(int(t), jnp.int32), s)
                self.stats["teacher_steps"] += 1
            self.stats["shared_admissions"] += 1
            req.prefix_keys = kept_keys
            for key in kept_keys:
                self._key_refs[key] = self._key_refs.get(key, 0) + 1
            self._touch_lru(keys)
            self.stats["prefix_hit_blocks"] += ns
            self.stats["prompt_blocks"] += fb
            st.update(pos=plen, row=row, registered=True)

    def _finish_prefill(self, s: int, st: dict):
        """The slot's last chunk has run: register its prompt blocks in the
        prefix map (paged, one admission-time sync for the table row), then
        arm the device row. Fresh requests return an ``(s, req, first, ok)``
        arm record for the batched ``_post_arm`` sync; resumes re-arm with
        no sample and return None."""
        req = st["req"]
        del self._pending[s]
        if self.paged and not st["registered"]:
            keys, ns = st["keys"], st["ns"]
            if keys:
                trow = np.asarray(self._sync(self.alloc["table"][s],
                                             "admit"))
                for j, key in enumerate(keys):
                    if key not in self._prefix_map:
                        self._prefix_map[key] = int(trow[j])
                        if self.lru_capacity > 0:
                            self.alloc = self._retain_block(
                                self.alloc, jnp.asarray(int(trow[j]),
                                                        jnp.int32))
                            self._cache_held.add(key)
                req.prefix_keys = keys
                for key in keys:
                    self._key_refs[key] = self._key_refs.get(key, 0) + 1
                self._touch_lru(keys)
            self.stats["prefix_hit_blocks"] += st["ns"]
            self.stats["prompt_blocks"] += len(st["toks"]) // self.block_size
        total = len(st["toks"])
        self.stats["prompt_tokens"] += total
        self.stats["seed_equiv_forwards"] += total
        if st["resume"]:
            self._rearm_slot(s, req, len(req.output))
            return None
        rows = self._param_rows(req)
        self.state, first, ok = self._arm(
            self.state, s, st["row"], *rows,
            jnp.asarray(next(self._stamp_counter), jnp.int32))
        return (s, req, first, ok)

    def _prefill_tick(self):
        """Spend this tick's token budget on pending prefills, oldest bind
        first. The budget gates STARTING a chunk (chunk boundaries are
        canonical — see ``_chunk_len`` — so a budget can't reshape them);
        slots whose incremental block allocation would overdraw an
        oversubscribed pool skip this tick instead of corrupting the free
        stack. Completed prefills arm; returns (armed records, whether any
        chunk ran, whether any slot is blocked on blocks)."""
        budget = self.tick_token_budget
        armed, ran, blocked = [], False, False
        for s in sorted(self._pending,
                        key=lambda i: self._pending[i]["order"]):
            st = self._pending[s]
            total = len(st["toks"])
            while st["pos"] < total:
                if budget is not None and budget <= 0:
                    break
                c = self._chunk_len(total - st["pos"])
                if self.paged and self.window_spec is not None:
                    # §17 between-chunk eviction: before drawing blocks for
                    # the next chunk, release this slot's blocks that the
                    # window can no longer reach (queries resume at
                    # st["pos"]). This is what bounds a long prompt's
                    # residency to window + chunk blocks on a window-sized
                    # pool. st["blocks"] stays the logical high-water count:
                    # alloc_range keeps appending at fresh logical indices.
                    w, sink_t = self._window
                    sb = sink_t // self.block_size
                    fl = max((st["pos"] - w + 1) // self.block_size, sb)
                    if fl > sb:
                        one = jnp.zeros((self.slots,), bool).at[s].set(True)
                        flv = jnp.zeros((self.slots,),
                                        jnp.int32).at[s].set(fl)
                        self.alloc = self._evict_window(
                            self.alloc, flv, one, sb)
                if self.paged:
                    need = -(-(st["pos"] + c) // self.block_size) \
                        - st["blocks"]
                    if need > 0 and self.preemption:
                        n_free = int(self._sync(self.alloc["n_free"],
                                                "admit"))
                        if need > n_free:
                            blocked = True
                            break
                    if need > 0:
                        self.alloc = self._alloc_range(
                            self.alloc, s, st["blocks"], need)
                        st["blocks"] += need
                pad = self._chunk_shape(c)
                toks = np.zeros((1, pad), np.int32)
                toks[0, :c] = st["toks"][st["pos"]:st["pos"] + c]
                self.cache, st["row"] = self._prefill_chunk(
                    self.params, self.qweights, self.cache,
                    self.alloc["table"] if self.paged else None,
                    jnp.asarray(toks), jnp.asarray(c, jnp.int32),
                    jnp.asarray(s, jnp.int32),
                    jnp.asarray(st["pos"], jnp.int32))
                st["pos"] += c
                self.stats["prefill_chunks"] += 1
                self.stats["prefill_forwards"] += 1
                if budget is not None:
                    budget -= c
                ran = True
            if st["pos"] >= total:
                rec = self._finish_prefill(s, st)
                if rec is not None:
                    armed.append(rec)
        return armed, ran, blocked

    def _admit_continuous(self):
        """Continuous admission (DESIGN.md §15): bind waiting requests to
        free slots the moment watermark + free stack allow, then advance
        every PREFILLING slot by up to ``tick_token_budget`` prompt tokens.
        Unlike the wave scheduler there is no admission barrier — new
        requests join while others decode, and a long prompt holds the tick
        for at most one chunk forward."""
        t0 = time.perf_counter()
        bound = False
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            req = self.waiting.peek()
            if req is None:
                break
            if not self._can_start(req):
                # head-of-line hold, same no-starvation rule as the wave
                break
            self.waiting.pop()
            self.slot_req[s] = req
            self._begin_prefill(s, req)
            bound = True
        armed, ran, blocked = self._prefill_tick()
        events = self._post_arm(armed)
        if blocked and not ran and not armed and len(self._pending) > 1 \
                and not any(r is not None and s not in self._pending
                            for s, r in enumerate(self.slot_req)):
            # Deadlock breaker for an oversubscribed pool: every live slot
            # is PREFILLING, none could place a chunk, and no decoder is
            # left to retire or preempt — release the youngest binding's
            # partial blocks back to the pool. The oldest survivor then
            # always completes: _can_start admitted it against the full
            # free stack and only younger bindings have drawn from it since.
            victim = max(self._pending,
                         key=lambda i: self._pending[i]["order"])
            ev = self._requeue_slot(victim, blocks_freed=False)
            if ev is not None:
                events.append(ev)
        if bound or ran or armed:
            self.stats["prefill_time_s"] += time.perf_counter() - t0
        return events

    def step(self) -> list:
        """One engine tick: expire deadlines, admit, decode the running
        batch, retire.

        Returns the tick's ``TokenEvent`` list — admission first-tokens plus
        one decode emission per active slot; empty when there was nothing to
        run (so the pre-§12 boolean use keeps working). Stop-token hits
        retire — and, paged, free their KV blocks — inside this same call;
        so do §13 preemptions (victim re-queued, blocks already freed
        in-tick) and non-finite-logit failures (victim retired with
        ``FINISHED_ERROR``, the rest of the batch unaffected).
        """
        events = self._expire_deadlines()
        events += self._admit()
        # nothing ARMED -> no decode tick: PREFILLING slots (continuous
        # scheduler) hold inactive device rows and only consume admission
        # work until their final chunk arms them
        if not any(r is not None and s not in self._pending
                   for s, r in enumerate(self.slot_req)):
            return events
        t0 = time.perf_counter()
        (self.cache, self.state, self.alloc, nxt, emitted, done, pre,
         bad) = self._tick(
            self.params, self.qweights, self.cache, self.state, self.alloc)
        # The one host sync of the tick: five (slots,)-sized vectors.
        nxt, emitted, done, pre, bad = map(
            np.asarray, self._sync((nxt, emitted, done, pre, bad), "tick"))
        self.stats["decode_time_s"] += time.perf_counter() - t0
        self.stats["decode_ticks"] += 1
        for s in np.flatnonzero(pre):
            ev = self._requeue_slot(int(s), blocks_freed=True)
            if ev is not None:
                events.append(ev)
        for s in np.flatnonzero(bad):
            req = self.slot_req[int(s)]
            req.finish_reason = FINISHED_ERROR
            self.stats["nan_failures"] += 1
            self._retire(int(s), req)
            events.append(TokenEvent(rid=req.rid, token=-1,
                                     index=len(req.output), done=True,
                                     finish_reason=FINISHED_ERROR))
        for s, req in enumerate(self.slot_req):
            if req is None or not emitted[s]:
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            self.stats["generated_tokens"] += 1
            if done[s]:
                req.finish_reason = (FINISHED_STOP if tok in req.params.stop
                                     else FINISHED_LENGTH)
                self._retire(s, req)
            events.append(TokenEvent(rid=req.rid, token=tok,
                                     index=len(req.output) - 1,
                                     done=req.done,
                                     finish_reason=req.finish_reason))
        return events

    # ------------------------------------------------------------------
    # Fault-injection seams (serving/faults.py drives these; DESIGN.md §13)
    # ------------------------------------------------------------------

    def inject_logit_fault(self, slot: int, value: float = float("nan")):
        """Add ``value`` to every logit of one slot from its next tick on
        (cleared when the slot is re-armed). ``nan``/``inf`` exercise the
        non-finite guard; finite values model a mild numeric skew."""
        self.state = self._set_bomb(self.state, slot,
                                    jnp.asarray(value, jnp.float32))

    def drain_free_blocks(self, leave: int = 0) -> int:
        """Steal the pool's free blocks (all but ``leave``) under an
        external reference, forcing the next allocating tick into the
        exhaustion path. Meant for preemption-enabled engines — a
        fully-provisioned pool has no recovery branch to steal from.
        Returns the number taken; ``restore_free_blocks`` gives them back.
        """
        assert self.paged, "no pool to drain in the ring layout"
        n_free = int(self._sync(self.alloc["n_free"], "stat"))
        n = max(n_free - leave, 0)
        if n:
            self.alloc, ids = self._steal(self.alloc,
                                          jnp.asarray(n, jnp.int32))
            self._stolen.append(ids)
        return n

    def restore_free_blocks(self):
        """Return every block taken by ``drain_free_blocks``."""
        while self._stolen:
            self.alloc = self._unsteal(self.alloc, self._stolen.pop())

    # ------------------------------------------------------------------
    # Request-lifecycle facade (DESIGN.md §12)
    # ------------------------------------------------------------------

    def _submit_batch(self, prompts: Sequence,
                      params: SamplingParams | Sequence | None):
        if params is None or isinstance(params, SamplingParams):
            plist = [params or SamplingParams()] * len(prompts)
        else:
            plist = list(params)
            if len(plist) != len(prompts):
                raise ValueError(f"{len(prompts)} prompts but "
                                 f"{len(plist)} SamplingParams")
        # build and validate the WHOLE batch before the first submit: a bad
        # member must not leave earlier ones orphaned in the waiting queue
        # of a call that raised
        reqs = []
        for prompt, p in zip(prompts, plist):
            req = Request(rid=next(self._auto_rid),
                          prompt=np.asarray(prompt), params=p)
            self._validate_request(req)
            reqs.append(req)
        for req in reqs:
            self.submit(req)
        return reqs

    def _result(self, req: Request) -> GenerationResult:
        return GenerationResult(rid=req.rid, prompt=req.prompt,
                                tokens=list(req.output),
                                finish_reason=req.finish_reason or "length",
                                params=req.params)

    def generate(self, prompts: Sequence,
                 params: SamplingParams | Sequence | None = None, *,
                 on_token: Callable | None = None,
                 max_ticks: int = 100_000) -> list:
        """Serve a batch of prompts to completion.

        ``prompts``: token-id sequences; ``params``: one ``SamplingParams``
        for all of them, a per-prompt sequence, or ``None`` for greedy
        defaults. Drives the engine (other outstanding requests ride along)
        until every prompt of THIS batch finishes and returns their
        ``GenerationResult``s in prompt order. ``on_token`` — called with
        each of this batch's ``TokenEvent``s as it is emitted — is the
        callback form of ``generate_stream``.
        """
        reqs = self._submit_batch(prompts, params)
        mine = {r.rid for r in reqs}
        for _ in range(max_ticks):
            if all(r.done for r in reqs):
                break
            for ev in self.step():
                if on_token is not None and ev.rid in mine:
                    on_token(ev)
        if not all(r.done for r in reqs):
            raise RuntimeError(f"generate() still running after "
                               f"{max_ticks} ticks")
        return [self._result(r) for r in reqs]

    def generate_stream(self, prompts: Sequence,
                        params: SamplingParams | Sequence | None = None, *,
                        max_ticks: int = 100_000) -> Iterator[TokenEvent]:
        """Streaming form of ``generate``: yields this batch's per-tick
        ``TokenEvent`` deltas (one per request per tick, admission tokens
        included) as they are emitted; each request's final event carries
        ``done=True`` and its ``finish_reason``. The batch is submitted
        EAGERLY — before the returned iterator is first advanced — so other
        engine traffic can pick the requests up either way."""
        reqs = self._submit_batch(prompts, params)
        mine = {r.rid for r in reqs}

        def _events():
            # requests finished AT submit (queue-capacity rejection) never
            # reach a tick: surface their terminal event here
            for r in reqs:
                if r.done:
                    yield TokenEvent(rid=r.rid, token=-1,
                                     index=len(r.output), done=True,
                                     finish_reason=r.finish_reason)
            for _ in range(max_ticks):
                if all(r.done for r in reqs):
                    return
                for ev in self.step():
                    if ev.rid in mine:
                        yield ev
            if not all(r.done for r in reqs):
                raise RuntimeError(f"generate_stream() still running after "
                                   f"{max_ticks} ticks")

        return _events()

    def slo_stats(self) -> dict:
        """Per-request latency summary over every finished request
        (DESIGN.md §15), measured against the engine's injectable clock:

          * TTFT — ``first_token_s - submit_s``, each request's OWN arrival
            stamp (not engine start), so percentiles are meaningful under
            ragged admission;
          * TPOT — ``(finish_s - first_token_s) / (len(output) - 1)``,
            requests with >= 2 output tokens only.

        Host arithmetic over stamps already taken on the serving path —
        calling this costs zero device syncs."""
        done = self.finished
        ttft = [r.first_token_s - r.submit_s for r in done
                if r.first_token_s is not None]
        tpot = [(r.finish_s - r.first_token_s) / (len(r.output) - 1)
                for r in done
                if r.first_token_s is not None and r.finish_s is not None
                and len(r.output) > 1]
        return {"requests": len(done),
                "ttft_s": latency_percentiles(ttft),
                "tpot_s": latency_percentiles(tpot)}

    def pool_stats(self) -> dict:
        """Paged-pool occupancy snapshot (one small host sync, ledgered as
        ``stat_syncs``; benchmarking only — never called on the tick
        path)."""
        if not self.paged:
            return {}
        n_free = int(self._sync(self.alloc["n_free"], "stat"))
        hits, total = self.stats["prefix_hit_blocks"], self.stats[
            "prompt_blocks"]
        out = {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.num_blocks - 1 - n_free,
            "retained_blocks": len(self._lru),
            "prefix_hit_rate": hits / total if total else 0.0,
        }
        if self.window_spec is not None:
            out["window"] = window_report(self.window_spec, self.max_blocks,
                                          self.block_size)
        return out

    def _assert_kv_contract(self):
        """The §10/§14 storage contract, asserted at construction: every
        attention cache entry holds exactly the declared dtype — the float
        store for bf16/fp32, or codes + fp16 scales for int8/int4."""
        for entry in jax.tree.leaves(
                self.cache["layers"], is_leaf=lambda e: isinstance(e, dict)):
            if not (isinstance(entry, dict) and "k" in entry
                    and "v" in entry):
                continue  # recurrent state rows
            if self.kv_spec is not None:
                assert "k_scale" in entry, "quantized cache missing scales"
                assert entry["k"].dtype == self.kv_spec.code_dtype, (
                    entry["k"].dtype, self.kv_spec)
                assert entry["k_scale"].dtype == jnp.dtype(
                    self.kv_spec.scale_dtype), entry["k_scale"].dtype
            else:
                assert entry["k"].dtype == jnp.dtype(self._kv_store), (
                    entry["k"].dtype, self._kv_store)

    def _expanded_kinds(self) -> list[str]:
        pat = list(self.cfg.block_pattern)
        return (pat * self.cfg.pattern_repeats
                + list(self.cfg.remainder_kinds))

    def kv_report(self) -> dict:
        """KV-cache footprint section (DESIGN.md §14): bytes per cached
        token per attention layer — codes + affine aux under ceil-packed
        accounting — against bf16 and fp32 pools of the same geometry.
        Works for float-weight engines too (no export required)."""
        return kv_cache_report(self._expanded_kinds(), self.cfg.n_kv_heads,
                               self.cfg.head_dim, spec=self.kv_spec,
                               dtype=self._kv_store, kv_dtype=self.kv_dtype)

    def quant_report(self) -> dict:
        """Bytes/BOPs ledger of the served artifact (DESIGN.md §11):
        per-site packed device bytes and model BOPs vs the fp32 and
        uniform-int8 baselines, plus the §14 KV-cache section. Requires an
        int export (use ``kv_report`` alone for float-weight engines)."""
        assert self.export_ledger is not None, "no quantized export to report"
        gates = self.quant_state["gates"]
        if self.act_specs:
            # Fold the SERVED activation widths into the certificate: each
            # ``.in`` spec contributes a per-tensor gate at the level whose
            # T(g) is exactly its bit-width, so ``model_bop`` certifies
            # true w_bits × a_bits × MACs compute (DESIGN.md §16).
            gates = dict(gates)
            for key, spec in self.act_specs.items():
                gates[key] = jnp.asarray(ACT_GATE_LEVELS[int(spec.bits)],
                                         jnp.float32)
        return quant_report(self.export_ledger, gates, kv=self.kv_report())

    def run_to_completion(self, max_ticks: int = 1000):
        ticks = 0
        while (self.waiting or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
