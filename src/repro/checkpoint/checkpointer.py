"""Sharded, async, fault-tolerant checkpointing.

Design (works single-process here, laid out for multi-host):

  * Every process writes only its addressable shards: files are keyed by
    (array path, shard index) so hosts never contend; a single manifest
    written by process 0 commits the step atomically (tmp dir + rename).
  * Async: ``save(...)`` snapshots device arrays to host (a fast device_get)
    and hands file IO to a background thread; training continues. ``wait()``
    joins before the next save or shutdown.
  * Integrity: the manifest records per-file sha256 + shapes/dtypes; restore
    verifies before install.
  * Elastic re-mesh: shards are stored with their global index-ranges, so a
    checkpoint saved on one mesh restores onto ANY mesh/topology — restore
    assembles the global array then re-shards onto the target sharding
    (tested in tests/test_checkpoint.py with different device counts).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _tree_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


def _shard_records(arr) -> list[dict]:
    """Addressable shards with global index ranges."""
    recs = []
    if hasattr(arr, "addressable_shards"):
        for sh in arr.addressable_shards:
            idx = sh.index  # tuple of slices into the global shape
            ranges = [
                [0 if s.start is None else int(s.start),
                 int(dim) if s.stop is None else int(s.stop)]
                for s, dim in zip(idx, arr.shape)
            ] if idx != () else []
            recs.append({"device": int(sh.device.id), "ranges": ranges,
                         "data": np.asarray(sh.data)})
    else:
        recs.append({"device": 0, "ranges": [], "data": np.asarray(arr)})
    # dedupe replicated shards (same ranges)
    seen, out = set(), []
    for r in recs:
        key = tuple(map(tuple, r["ranges"]))
        if key in seen:
            continue
        seen.add(key)
        out.append(r)
    return out


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False,
             extra: dict | None = None):
        """Snapshot to host, then write in the background."""
        self.wait()
        host = [(k, _shard_records(v)) for k, v in _tree_paths(tree)]
        # structure skeleton for restore
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "time": time.time(),
                        "treedef": str(treedef), "arrays": {},
                        "extra": extra or {}}
            for key, shards in host:
                entries = []
                for i, sh in enumerate(shards):
                    fname = f"{key.replace('/', '.')}.{i}.npy"
                    fpath = os.path.join(tmp, fname)
                    np.save(fpath, sh["data"])
                    with open(fpath, "rb") as f:
                        digest = hashlib.sha256(f.read()).hexdigest()
                    entries.append({
                        "file": fname, "ranges": sh["ranges"],
                        "sha256": digest,
                        "shape": list(sh["data"].shape),
                        "dtype": str(sh["data"].dtype),
                    })
                manifest["arrays"][key] = entries
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, MANIFEST)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, *, step: int | None = None,
                shardings: Any = None, verify: bool = True):
        """Restore into ``template``'s structure.

        ``shardings``: optional pytree of NamedShardings for the TARGET mesh
        (may differ from the save-time mesh — elastic re-mesh).
        Returns (tree, step, extra).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        root = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(root, MANIFEST)) as f:
            manifest = json.load(f)

        leaves = _tree_paths(template)
        shard_leaves = _tree_paths(shardings) if shardings is not None else None
        out = []
        for i, (key, leaf) in enumerate(leaves):
            entries = manifest["arrays"].get(key)
            if entries is None:
                raise KeyError(f"checkpoint missing array {key}")
            shape = tuple(leaf.shape)
            # assemble the global array from shard files
            glob = None
            for e in entries:
                fpath = os.path.join(root, e["file"])
                if verify:
                    with open(fpath, "rb") as f:
                        digest = hashlib.sha256(f.read()).hexdigest()
                    if digest != e["sha256"]:
                        raise IOError(f"corrupt shard {fpath}")
                data = np.load(fpath)
                if not e["ranges"]:
                    glob = data
                    break
                if glob is None:
                    glob = np.zeros(shape, data.dtype)
                sl = tuple(slice(a, b) for a, b in e["ranges"])
                glob[sl] = data
            arr = jax.numpy.asarray(glob.astype(leaf.dtype)
                                    if hasattr(leaf, "dtype") else glob)
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i][1])
            out.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return treedef.unflatten(out), step, manifest.get("extra", {})
