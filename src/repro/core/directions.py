"""Gate-update directions (paper §2.3).

A direction ``dir`` replaces the (identically zero) gradient of the loss with
respect to a gate variable. SGD applies ``g <- g - lr * dir``, so the two
required properties are:

  (i)  constraint Unsat  =>  dir > 0   (gates shrink, bit-widths decrease)
  (ii) constraint Sat    =>  dir <= 0  (gates may grow, bit-widths recover)

Inputs per gate group (produced by the probe/stat machinery in ``sites.py``):

  grad_stat : |(1/N_b) sum_i grad L(x_i)|, group-reduced  (weights and acts)
  mag_stat  : group-reduced |w| for weight gates; |(1/N_b) sum_i a(x_i)| for
              activation gates
  gate      : the gate value itself

The three paper directions::

  dir_1: Unsat  1 / grad_stat                  Sat  -|g|
  dir_2: Unsat  1 / (grad_stat + mag_stat)     Sat  -(|g| + mag_stat)
  dir_3: Unsat  1 / (grad_stat + mag_stat)     Sat  -(grad_stat + mag_stat)

plus a beyond-paper scale-free variant::

  dir_4: Unsat  1 / (1 + t / median(t))        Sat  -t / (t + median(t)),
         t = grad_stat + mag_stat

dir_4 is bounded in (0, 1] / [-1, 0) by construction, so a single gate
learning rate works across tensors of wildly different scales (the paper had
to lower the lr for dir_3 for exactly this reason, §4.2). The median is taken
over all gate groups of the model.

An optional ``clip`` bounds the Unsat branch of dir_1..3 into
``[eps, clip]`` — explicitly permitted by the paper ("any method ... as long
as the two properties above are satisfied"; the bounded-direction remark at
the end of §2.3). Off by default to stay paper-literal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DIRECTIONS = ("dir1", "dir2", "dir3", "dir4")


def _global_median(stats: dict[str, jnp.ndarray]) -> jnp.ndarray:
    flat = jnp.concatenate([jnp.ravel(v) for v in stats.values()])
    return jnp.median(flat)


def compute_directions(
    kind: str,
    sat: jnp.ndarray,
    gates: dict[str, jnp.ndarray],
    grad_stats: dict[str, jnp.ndarray],
    mag_stats: dict[str, jnp.ndarray],
    eps: float = 1e-12,
    clip: float | None = None,
) -> dict[str, jnp.ndarray]:
    """Directions for every gate. ``sat`` is a traced boolean scalar."""
    assert kind in DIRECTIONS, kind
    med = None
    if kind == "dir4":
        med = _global_median(
            {k: grad_stats[k] + mag_stats[k] for k in gates}
        ) + eps

    dirs = {}
    for key, g in gates.items():
        gs = grad_stats[key].astype(jnp.float32)
        ms = mag_stats[key].astype(jnp.float32)
        ga = jnp.abs(jnp.asarray(g, jnp.float32))
        if kind == "dir1":
            unsat = 1.0 / (gs + eps)
            satd = -ga
        elif kind == "dir2":
            unsat = 1.0 / (gs + ms + eps)
            satd = -(ga + ms)
        elif kind == "dir3":
            unsat = 1.0 / (gs + ms + eps)
            satd = -(gs + ms)
        else:  # dir4
            t = gs + ms
            unsat = 1.0 / (1.0 + t / med)
            satd = -t / (t + med)
        if clip is not None and kind != "dir4":
            unsat = jnp.clip(unsat, eps, clip)
            satd = -jnp.clip(-satd, 0.0, clip)
        d = jnp.where(sat, satd, unsat)
        dirs[key] = jnp.broadcast_to(d, jnp.shape(g)).astype(jnp.float32)
    return dirs


def build_stats(
    gates: dict[str, jnp.ndarray],
    probe_grads: dict[str, jnp.ndarray],
    weight_stats: dict[str, jnp.ndarray],
    act_stats: dict[str, dict[str, jnp.ndarray]],
):
    """Assemble (grad_stats, mag_stats) keyed like ``gates``.

    ``probe_grads`` holds dL/dprobe for both weight probes (key ``*.w``) and
    activation probes (key ``*.a``); with mean-over-batch loss these equal the
    paper's ``(1/N_b) sum_i grad`` exactly (group-summed).
    """
    grad_stats, mag_stats = {}, {}
    for key in gates:
        pg = probe_grads.get(key)
        if pg is None:
            grad_stats[key] = jnp.zeros_like(jnp.asarray(gates[key], jnp.float32))
        else:
            grad_stats[key] = jnp.abs(jnp.asarray(pg, jnp.float32))
        if key.endswith(".w"):
            mag_stats[key] = jnp.asarray(
                weight_stats.get(key, jnp.zeros(())), jnp.float32
            )
        else:
            st = act_stats.get(key, {})
            mag_stats[key] = jnp.asarray(st.get("mean_abs", jnp.zeros(())), jnp.float32)
        mag_stats[key] = jnp.broadcast_to(
            mag_stats[key], jnp.shape(gates[key])
        )
        grad_stats[key] = jnp.broadcast_to(
            grad_stats[key], jnp.shape(gates[key])
        )
    return grad_stats, mag_stats


def check_direction_properties(dirs: dict[str, jnp.ndarray], sat: bool) -> bool:
    """Property (i)/(ii) checker used by tests and debug assertions."""
    ok = True
    for v in dirs.values():
        v = jax.device_get(v)
        ok &= bool((v <= 0).all()) if sat else bool((v > 0).all())
    return ok
