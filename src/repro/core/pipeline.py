"""The four-stage CGMQ pipeline (paper §2.4 / §4.2) as a thin stage-sequencer
over the unified training engine (``repro.train``, DESIGN.md §9).

  1. FP32 pretraining                        (paper: 250 epochs)
  2. Range calibration at 32-bit fake quant  (paper: 1 epoch, momentum 0.1)
  3. Range learning                          (paper: 20 epochs)
  4. CGMQ: weights + ranges + gates jointly  (paper: 250 epochs)

This module owns only stage ordering, site collection/calibration (stage 2)
and the bundle/result dataclasses; all actual training — scan-based epochs,
donated device-resident state, batched eval, one host sync per eval window,
optional data-parallel sharding and full-state checkpoint/resume — lives in
``repro.train.TrainEngine``. Generic over any model exposing
``forward(qc, params, x) -> logits`` and a ``weight_lookup(params)`` site
resolver; used by the LeNet-5 reproduction, the benchmark tables, and (with
an LM loss) the LLM-scale examples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.train import EngineConfig, TrainEngine, per_example_xent

from . import bop as bop_lib
from . import controller as ctrl
from .calibration import apply_act_calibration, calibrate_activations
from .sites import (
    QuantConfig,
    collect_sites,
    init_gates,
    init_probes,
    init_ranges_from_weights,
    split_learnable_ranges,
)


@dataclasses.dataclass
class PipelineConfig:
    pretrain_epochs: int = 250
    range_epochs: int = 20
    cgmq_epochs: int = 250
    batch_size: int = 128
    lr: float = 1e-3          # weights + ranges (paper §4.2)
    eval_every: int = 10      # epochs per eval window == one host sync
    loop: str = "scan"        # 'scan' | 'python' (reference loop, same numerics)
    log: Callable[[str], None] = print


def cross_entropy(logits, labels):
    """Legacy scalar-mean loss. NOT valid as an engine ``loss_fn`` (the
    engine needs per-example losses for tail-batch weighting and will raise
    if handed a scalar); kept for external callers evaluating a model."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@dataclasses.dataclass
class PretrainedBundle:
    """Stages 1-3 output, shared across CGMQ variants (paper §4.2: 'All
    different choices of CGMQ start with the same pre-trained model and the
    same learned quantization ranges')."""

    params: Any
    betas: Any
    signed: dict
    gates: dict
    probes: dict
    sites: dict
    qcfg: QuantConfig
    fp32_test_acc: float


@dataclasses.dataclass
class PipelineResult:
    params: Any
    betas: Any
    signed: dict
    state: ctrl.CGMQState
    sites: dict
    budget_bop: float
    history: list
    fp32_test_acc: float
    final_test_acc: float

    @property
    def final_rbop(self) -> float:
        gates = ctrl.export_gates(self.state)
        return float(
            jax.device_get(bop_lib.model_bop(self.sites, gates))
        ) / bop_lib.fp32_bop(self.sites)

    @property
    def satisfied(self) -> bool:
        return ctrl.guarantee_satisfied(self.state, self.sites, self.budget_bop)


def _epoch_batches(data, batch_size, rng):
    """Permuted minibatches INCLUDING the tail partial batch (the seed loop
    stopped at the last full batch, silently dropping up to batch_size - 1
    samples per epoch). Host-side; used for calibration streams — training
    batches are staged on device by the engine (train/engine.stage_epoch)."""
    xs, ys = data
    order = rng.permutation(xs.shape[0])
    for i in range(0, xs.shape[0], batch_size):
        idx = order[i : i + batch_size]
        yield xs[idx], ys[idx]


def steps_per_epoch(n_samples: int, batch_size: int) -> int:
    """ceil — the engine runs the weighted tail batch as a real step."""
    return max(1, -(-n_samples // batch_size))


def _engine(forward, pcfg: PipelineConfig, qcfg, loss_fn, plan) -> TrainEngine:
    return TrainEngine(
        forward,
        EngineConfig(batch_size=pcfg.batch_size, lr=pcfg.lr,
                     eval_every=pcfg.eval_every, loop=pcfg.loop, log=pcfg.log),
        qcfg=qcfg, loss_fn=loss_fn, plan=plan,
    )


def prepare_bundle(
    forward: Callable,
    weight_lookup_fn: Callable,
    params: Any,
    train_data,
    test_data,
    qcfg: QuantConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = per_example_xent,
    seed: int = 0,
    pretrained_params: Any = None,
    plan=None,
) -> PretrainedBundle:
    """Stages 1-3: FP32 pretrain -> calibrate -> range learning.

    ``loss_fn(logits, labels) -> (B,)`` per-example losses (engine contract).
    """
    log = pcfg.log
    eng = _engine(forward, pcfg, qcfg, loss_fn, plan)

    # ---------------- stage 1: FP32 pretraining ----------------
    if pretrained_params is None:
        state = eng.shard_state(eng.init_fp_state(params, seed=seed))
        state, _ = eng.run_stage(state, "fp", train_data, pcfg.pretrain_epochs,
                                 eval_data=test_data, label="pretrain")
        params = state.params
    else:
        params = pretrained_params
    fp32_acc = eng.eval_accuracy(params, test_data, quant=False)
    log(f"[pretrain] FP32 test accuracy: {fp32_acc:.4f}")

    # ---------------- stage 2: site collection + calibration ----------------
    sites = collect_sites(
        lambda qc, p, x: forward(qc, p, x),
        params,
        jax.ShapeDtypeStruct((pcfg.batch_size,) + train_data[0].shape[1:],
                             jnp.float32),
        cfg=qcfg,
    )
    gates = init_gates(sites, qcfg)
    probes = init_probes(sites, qcfg)
    for s in sites.values():  # weight gradient taps
        probes[s.name + ".w"] = jnp.zeros_like(
            jnp.asarray(gates[s.name + ".w"], jnp.float32)
        )
    ranges = init_ranges_from_weights(sites, qcfg, weight_lookup_fn(params))

    calib_batches = (
        x for x, _ in _epoch_batches(train_data, pcfg.batch_size,
                                     np.random.default_rng(seed))
    )
    act_ranges = calibrate_activations(
        lambda qc, batch: forward(qc, params, batch), calib_batches, qcfg
    )
    ranges = apply_act_calibration(ranges, act_ranges)
    betas, signed = split_learnable_ranges(ranges)
    log(f"[calibrate] {len(sites)} sites, "
        f"{sum(np.prod(np.shape(g)) if np.ndim(g) else 1 for g in gates.values()):.0f} gates")

    # ---------------- stage 3: range learning (32-bit FQ) ----------------
    eng.bind_sites(sites, signed)
    state = eng.shard_state(
        eng.init_quant_state(params, betas, gates, probes, seed=seed))
    state, _ = eng.run_stage(state, "range", train_data, pcfg.range_epochs,
                             label="ranges")
    log(f"[ranges] learned for {pcfg.range_epochs} epochs")

    return PretrainedBundle(
        params=state.params, betas=state.betas, signed=signed,
        gates=state.cgmq.gates, probes=state.probes,
        sites=sites, qcfg=qcfg, fp32_test_acc=fp32_acc,
    )


def run_cgmq_stage(
    forward: Callable,
    bundle: PretrainedBundle,
    train_data,
    test_data,
    ccfg: ctrl.CGMQConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = per_example_xent,
    seed: int = 0,
    plan=None,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    resume: bool = False,
) -> PipelineResult:
    """Stage 4: CGMQ joint training of weights + ranges + gates.

    With ``ckpt_dir`` the full TrainState (gates, Sat/best flags, RNG
    included) checkpoints every ``ckpt_every`` epochs (default: the eval
    window) and ``resume=True`` continues a previous run bit-identically.
    """
    # paper: Sat checked at the END of each epoch — but only default it when
    # the user left check_every unset (the seed overwrote user values).
    spe = steps_per_epoch(train_data[0].shape[0], pcfg.batch_size)
    if ccfg.check_every is None:
        ccfg = dataclasses.replace(ccfg, check_every=spe)

    budget = bop_lib.budget_from_rbop(bundle.sites, ccfg.budget_rbop)
    eng = _engine(forward, pcfg, bundle.qcfg, loss_fn, plan)
    eng.bind_sites(bundle.sites, bundle.signed)
    eng.bind_controller(ccfg, budget)

    def _init():
        return eng.init_quant_state(bundle.params, bundle.betas, bundle.gates,
                                    bundle.probes, seed=seed + 1000)

    ckpt = None
    start_epoch = 0
    state = None
    if resume and ckpt_dir is None:
        pcfg.log("[cgmq] WARNING: resume requested without a checkpoint dir "
                 "— starting from epoch 0")
    if ckpt_dir is not None:
        ckpt = Checkpointer(ckpt_dir)
        ckpt_every = ckpt_every or pcfg.eval_every
        if resume:
            if ckpt.latest_step() is not None:
                # restore against an abstract template: no throwaway
                # allocation of params/moments just to read shapes
                template = jax.eval_shape(_init)
                state, start_epoch, _ = ckpt.restore(template)
                state = eng.shard_state(state)  # restore lands on default dev
                pcfg.log(f"[cgmq] resumed at epoch {start_epoch}")
            else:
                pcfg.log(f"[cgmq] WARNING: resume requested but no checkpoint "
                         f"in {ckpt_dir} — starting from epoch 0")
    if state is None:
        state = eng.shard_state(_init())

    state, history = eng.run_stage(
        state, "cgmq", train_data, pcfg.cgmq_epochs, eval_data=test_data,
        label="cgmq", ckpt=ckpt, ckpt_every=ckpt_every,
        start_epoch=start_epoch)

    final_acc = eng.eval_accuracy(
        state.params, test_data, betas=state.betas,
        gates=ctrl.export_gates(state.cgmq), quant=True)
    return PipelineResult(
        params=state.params, betas=state.betas, signed=bundle.signed,
        state=state.cgmq, sites=bundle.sites,
        budget_bop=budget, history=history,
        fp32_test_acc=bundle.fp32_test_acc, final_test_acc=final_acc,
    )


def run_pipeline(
    forward: Callable,
    weight_lookup_fn: Callable,
    params: Any,
    train_data,
    test_data,
    qcfg: QuantConfig,
    ccfg: ctrl.CGMQConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = per_example_xent,
    seed: int = 0,
    pretrained_params: Any = None,
    plan=None,
    ckpt_dir: str | None = None,
    resume: bool = False,
) -> PipelineResult:
    """All four stages in sequence (convenience wrapper)."""
    bundle = prepare_bundle(
        forward, weight_lookup_fn, params, train_data, test_data, qcfg, pcfg,
        loss_fn=loss_fn, seed=seed, pretrained_params=pretrained_params,
        plan=plan,
    )
    return run_cgmq_stage(
        forward, bundle, train_data, test_data, ccfg, pcfg,
        loss_fn=loss_fn, seed=seed, plan=plan, ckpt_dir=ckpt_dir,
        resume=resume,
    )
