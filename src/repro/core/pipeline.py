"""The four-stage CGMQ pipeline (paper §2.4 / §4.2).

  1. FP32 pretraining                        (paper: 250 epochs)
  2. Range calibration at 32-bit fake quant  (paper: 1 epoch, momentum 0.1)
  3. Range learning                          (paper: 20 epochs)
  4. CGMQ: weights + ranges + gates jointly  (paper: 250 epochs)

Generic over any model exposing ``forward(qc, params, x) -> logits`` and a
``weight_lookup(params)`` site resolver. Used by the LeNet-5 reproduction,
the benchmark tables, and (with the LM loss) the LLM-scale examples.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import AdamConfig, adam, apply_updates

from . import bop as bop_lib
from . import controller as ctrl
from .calibration import apply_act_calibration, calibrate_activations
from .sites import (
    QuantConfig,
    QuantContext,
    collect_sites,
    init_gates,
    init_probes,
    init_ranges_from_weights,
    merge_ranges,
    split_learnable_ranges,
)


@dataclasses.dataclass
class PipelineConfig:
    pretrain_epochs: int = 250
    range_epochs: int = 20
    cgmq_epochs: int = 250
    batch_size: int = 128
    lr: float = 1e-3          # weights + ranges (paper §4.2)
    eval_every: int = 10
    log: Callable[[str], None] = print


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


@dataclasses.dataclass
class PretrainedBundle:
    """Stages 1-3 output, shared across CGMQ variants (paper §4.2: 'All
    different choices of CGMQ start with the same pre-trained model and the
    same learned quantization ranges')."""

    params: Any
    betas: Any
    signed: dict
    gates: dict
    probes: dict
    sites: dict
    qcfg: QuantConfig
    fp32_test_acc: float


@dataclasses.dataclass
class PipelineResult:
    params: Any
    betas: Any
    signed: dict
    state: ctrl.CGMQState
    sites: dict
    budget_bop: float
    history: list
    fp32_test_acc: float
    final_test_acc: float

    @property
    def final_rbop(self) -> float:
        gates = ctrl.export_gates(self.state)
        return float(
            jax.device_get(bop_lib.model_bop(self.sites, gates))
        ) / bop_lib.fp32_bop(self.sites)

    @property
    def satisfied(self) -> bool:
        return ctrl.guarantee_satisfied(self.state, self.sites, self.budget_bop)


def _epoch_batches(data, batch_size, rng):
    xs, ys = data
    order = rng.permutation(xs.shape[0])
    for i in range(0, xs.shape[0] - batch_size + 1, batch_size):
        idx = order[i : i + batch_size]
        yield xs[idx], ys[idx]


def prepare_bundle(
    forward: Callable,
    weight_lookup_fn: Callable,
    params: Any,
    train_data,
    test_data,
    qcfg: QuantConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = cross_entropy,
    seed: int = 0,
    pretrained_params: Any = None,
) -> PretrainedBundle:
    """Stages 1-3: FP32 pretrain -> calibrate -> range learning."""
    log = pcfg.log
    rng = np.random.default_rng(seed)
    opt_init, opt_update = adam(AdamConfig(lr=pcfg.lr))

    # ---------------- stage 1: FP32 pretraining ----------------
    @jax.jit
    def fp_step(params, opt_state, x, y):
        def _loss(p):
            qc = QuantContext(mode="off")
            return loss_fn(forward(qc, p, x), y)

        loss, grads = jax.value_and_grad(_loss)(params)
        upd, opt_state = opt_update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    @jax.jit
    def fp_eval(params, x, y):
        qc = QuantContext(mode="off")
        logits = forward(qc, params, x)
        return accuracy(logits, y)

    if pretrained_params is None:
        opt_state = opt_init(params)
        t0 = time.time()
        for epoch in range(pcfg.pretrain_epochs):
            for x, y in _epoch_batches(train_data, pcfg.batch_size, rng):
                params, opt_state, loss = fp_step(params, opt_state, x, y)
            if (epoch + 1) % pcfg.eval_every == 0 or epoch == pcfg.pretrain_epochs - 1:
                acc = float(fp_eval(params, *test_data))
                log(f"[pretrain] epoch {epoch+1} loss {float(loss):.4f} acc {acc:.4f}"
                    f" ({time.time()-t0:.1f}s)")
    else:
        params = pretrained_params
    fp32_acc = float(fp_eval(params, *test_data))
    log(f"[pretrain] FP32 test accuracy: {fp32_acc:.4f}")

    # ---------------- stage 2: site collection + calibration ----------------
    sites = collect_sites(
        lambda qc, p, x: forward(qc, p, x),
        params,
        jax.ShapeDtypeStruct((pcfg.batch_size,) + train_data[0].shape[1:], jnp.float32),
        cfg=qcfg,
    )
    gates = init_gates(sites, qcfg)
    probes = init_probes(sites, qcfg)
    for s in sites.values():  # weight gradient taps
        probes[s.name + ".w"] = jnp.zeros_like(
            jnp.asarray(gates[s.name + ".w"], jnp.float32)
        )
    ranges = init_ranges_from_weights(sites, qcfg, weight_lookup_fn(params))

    calib_batches = (
        x for x, _ in _epoch_batches(train_data, pcfg.batch_size, rng)
    )
    act_ranges = calibrate_activations(
        lambda qc, batch: forward(qc, params, batch), calib_batches, qcfg
    )
    ranges = apply_act_calibration(ranges, act_ranges)
    betas, signed = split_learnable_ranges(ranges)
    log(f"[calibrate] {len(sites)} sites, "
        f"{sum(np.prod(np.shape(g)) if np.ndim(g) else 1 for g in gates.values()):.0f} gates")

    # ---------------- stage 3: range learning (32-bit FQ) ----------------
    @jax.jit
    def range_step(params, betas, opt_state, x, y):
        def _loss(pb):
            p, b = pb
            qc = QuantContext(
                mode="train", cfg=qcfg, gates=gates,
                ranges=merge_ranges(b, signed), probes={},
            )
            return loss_fn(forward(qc, p, x), y)

        loss, grads = jax.value_and_grad(_loss)((params, betas))
        upd, opt_state = opt_update(grads, opt_state, (params, betas))
        (params, betas) = apply_updates((params, betas), upd)
        return params, betas, opt_state, loss

    opt_state = opt_init((params, betas))
    for epoch in range(pcfg.range_epochs):
        for x, y in _epoch_batches(train_data, pcfg.batch_size, rng):
            params, betas, opt_state, loss = range_step(params, betas, opt_state, x, y)
    log(f"[ranges] learned for {pcfg.range_epochs} epochs, loss {float(loss):.4f}")

    return PretrainedBundle(
        params=params, betas=betas, signed=signed, gates=gates, probes=probes,
        sites=sites, qcfg=qcfg, fp32_test_acc=fp32_acc,
    )


def run_cgmq_stage(
    forward: Callable,
    bundle: PretrainedBundle,
    train_data,
    test_data,
    ccfg: ctrl.CGMQConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = cross_entropy,
    seed: int = 0,
) -> PipelineResult:
    """Stage 4: CGMQ joint training of weights + ranges + gates."""
    log = pcfg.log
    rng = np.random.default_rng(seed + 1000)
    opt_init, opt_update = adam(AdamConfig(lr=pcfg.lr))
    history = []
    params, betas = bundle.params, bundle.betas
    signed, gates, probes = bundle.signed, bundle.gates, bundle.probes
    sites, qcfg = bundle.sites, bundle.qcfg

    budget = bop_lib.budget_from_rbop(sites, ccfg.budget_rbop)
    state = ctrl.init_state(gates, sites)
    steps_per_epoch = max(1, train_data[0].shape[0] // pcfg.batch_size)
    # paper: Sat checked at the END of each epoch
    ccfg = dataclasses.replace(ccfg, check_every=steps_per_epoch)

    @jax.jit
    def cgmq_step(params, betas, opt_state, state, x, y):
        def _loss(pbp):
            p, b, pr = pbp
            qc = QuantContext(
                mode="train", cfg=qcfg, gates=state.gates,
                ranges=merge_ranges(b, signed), probes=pr,
            )
            logits = forward(qc, p, x)
            return loss_fn(logits, y), (qc.act_stats, qc.weight_stats, logits)

        (loss, (astats, wstats, logits)), grads = jax.value_and_grad(
            _loss, has_aux=True
        )((params, betas, probes))
        gp, gb, gprobe = grads
        upd, opt_state = opt_update((gp, gb), opt_state, (params, betas))
        (params, betas) = apply_updates((params, betas), upd)
        state = ctrl.controller_update(
            state, ccfg, sites, gprobe, wstats, astats, budget
        )
        return params, betas, opt_state, state, loss

    @jax.jit
    def q_eval(params, betas, gates, x, y):
        qc = QuantContext(
            mode="train", cfg=qcfg, gates=gates,
            ranges=merge_ranges(betas, signed), probes={},
        )
        return accuracy(forward(qc, params, x), y)

    opt_state = opt_init((params, betas))
    t0 = time.time()
    for epoch in range(pcfg.cgmq_epochs):
        for x, y in _epoch_batches(train_data, pcfg.batch_size, rng):
            params, betas, opt_state, state, loss = cgmq_step(
                params, betas, opt_state, state, x, y
            )
        if (epoch + 1) % pcfg.eval_every == 0 or epoch == pcfg.cgmq_epochs - 1:
            acc = float(q_eval(params, betas, state.gates, *test_data))
            cur_rbop = float(state.bop) / bop_lib.fp32_bop(sites)
            history.append(dict(epoch=epoch + 1, loss=float(loss), acc=acc,
                                rbop=cur_rbop, sat=bool(state.sat)))
            log(f"[cgmq] epoch {epoch+1} loss {float(loss):.4f} acc {acc:.4f} "
                f"rbop {cur_rbop*100:.3f}% sat={bool(state.sat)} "
                f"({time.time()-t0:.1f}s)")

    final_acc = float(q_eval(params, betas, ctrl.export_gates(state), *test_data))
    return PipelineResult(
        params=params, betas=betas, signed=signed, state=state, sites=sites,
        budget_bop=budget, history=history, fp32_test_acc=bundle.fp32_test_acc,
        final_test_acc=final_acc,
    )


def run_pipeline(
    forward: Callable,
    weight_lookup_fn: Callable,
    params: Any,
    train_data,
    test_data,
    qcfg: QuantConfig,
    ccfg: ctrl.CGMQConfig,
    pcfg: PipelineConfig,
    *,
    loss_fn: Callable = cross_entropy,
    seed: int = 0,
    pretrained_params: Any = None,
) -> PipelineResult:
    """All four stages in sequence (convenience wrapper)."""
    bundle = prepare_bundle(
        forward, weight_lookup_fn, params, train_data, test_data, qcfg, pcfg,
        loss_fn=loss_fn, seed=seed, pretrained_params=pretrained_params,
    )
    return run_cgmq_stage(
        forward, bundle, train_data, test_data, ccfg, pcfg,
        loss_fn=loss_fn, seed=seed,
    )
