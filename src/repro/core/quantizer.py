"""Fake quantization primitives (paper Eq. 1) with STE and learnable ranges.

The quantizer maps a float ``x`` in ``[alpha, beta]`` onto a ``b``-bit uniform
grid::

    Q(x, b, alpha, beta) = alpha + s * round((clip(x) - alpha) / s),
    s = (beta - alpha) / (2^b - 1)

which is algebraically identical to the paper's Eq. 1 (the paper writes the
``alpha = -beta`` / ``alpha = 0`` cases with the offset folded in; we keep the
explicit affine form so both cases share one code path).

Design notes (TPU adaptation, see DESIGN.md §3):
  * ``bits`` may be a traced array (per-element mixed precision) — every op is
    elementwise, so the same code path serves per-tensor, per-channel and
    per-weight gate granularities.
  * ``bits >= 32`` is treated as identity: rounding at scale ``2^32 - 1``
    exceeds the fp32 mantissa, and ``x_32 == x`` to below fp32 eps by
    construction, so the pass-through is bit-exact for all practical purposes.
  * Backward pass: straight-through estimator for ``x`` (gradient masked to the
    clip range, as in Bengio et al. 2013 / LSQ), and the STE-consistent
    derivative w.r.t. the learnable range ``beta`` (round treated as constant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Bit-width levels considered by the paper (B in Eq. 2, plus the base 2).
LEVELS = (2, 4, 8, 16, 32)
# Quantization at >= this many bits is an exact pass-through in fp32.
PASSTHROUGH_BITS = 32


def _num_steps(bits: jnp.ndarray) -> jnp.ndarray:
    """``2^b - 1`` computed in float32; safe for b <= 31."""
    return jnp.exp2(bits.astype(jnp.float32)) - 1.0


def quantize(
    x: jnp.ndarray,
    bits: jnp.ndarray | int,
    beta: jnp.ndarray,
    signed: bool,
) -> jnp.ndarray:
    """Pure quantization (no STE). ``alpha = -beta`` if signed else ``0``.

    ``bits``/``beta`` broadcast against ``x``. ``bits >= 32`` passes through.
    """
    out_dtype = x.dtype
    # fp32 internals regardless of input dtype: rounding against a 2^16-step
    # grid in bf16 (8-bit mantissa) would corrupt codes, and bf16 weights are
    # exactly what the half-precision FSDP gather path feeds us.
    x = x.astype(jnp.float32)
    bits = jnp.asarray(bits, jnp.float32)
    beta = jnp.maximum(jnp.asarray(beta, jnp.float32), 1e-8)
    alpha = -beta if signed else jnp.zeros_like(beta)
    span = beta - alpha
    # Clamp bits into [2, 31] for the arithmetic; pass-through selected below.
    b_eff = jnp.clip(bits, 2.0, 31.0)
    n = _num_steps(b_eff)
    s = span / n
    xc = jnp.clip(x, alpha, beta)
    q = alpha + s * jnp.round((xc - alpha) / s)
    return jnp.where(bits >= PASSTHROUGH_BITS, x, q).astype(out_dtype)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant(x: jnp.ndarray, bits: jnp.ndarray, beta: jnp.ndarray, signed: bool):
    """STE fake quantization: forward = ``quantize``; backward below."""
    return quantize(x, bits, beta, signed)


def _fq_fwd(x, bits, beta, signed):
    q = quantize(x, bits, beta, signed)
    return q, (x, bits, beta)


def _fq_bwd(signed, res, ct):
    x, bits, beta = res
    bits = jnp.asarray(bits, jnp.float32)
    beta_c = jnp.maximum(jnp.asarray(beta, x.dtype), jnp.asarray(1e-8, x.dtype))
    alpha = -beta_c if signed else jnp.zeros_like(beta_c)
    passthrough = bits >= PASSTHROUGH_BITS

    # --- STE w.r.t. x: identity inside [alpha, beta], zero outside. ---
    in_range = jnp.logical_and(x >= alpha, x <= beta_c)
    dx = jnp.where(jnp.logical_or(in_range, passthrough), ct, jnp.zeros_like(ct))

    # --- LSQ-style derivative w.r.t. beta (round-as-constant). ---
    # q = alpha(beta) + s(beta) * n  with  n = round((clip(x)-alpha)/s) const.
    #   signed:   alpha' = -1, s' = 2/(2^b-1)  -> dq/dbeta = -1 + 2n/(2^b-1)
    #   unsigned: alpha' = 0,  s' = 1/(2^b-1)  -> dq/dbeta = n/(2^b-1)
    # Clipped regions: top -> +1; bottom -> alpha' (= -1 signed, 0 unsigned).
    b_eff = jnp.clip(bits, 2.0, 31.0)
    nsteps = _num_steps(b_eff).astype(x.dtype)
    span = beta_c - alpha
    s = span / nsteps
    xc = jnp.clip(x, alpha, beta_c)
    n = jnp.round((xc - alpha) / s)
    frac = n / nsteps
    if signed:
        dq_db_in = -1.0 + 2.0 * frac
        dq_db_lo = jnp.asarray(-1.0, x.dtype)
    else:
        dq_db_in = frac
        dq_db_lo = jnp.asarray(0.0, x.dtype)
    dq_db = jnp.where(x > beta_c, 1.0, jnp.where(x < alpha, dq_db_lo, dq_db_in))
    dq_db = jnp.where(passthrough, 0.0, dq_db)
    dbeta_full = ct * dq_db
    # Sum the cotangent down to beta's shape (beta broadcasts against x).
    beta_arr = jnp.asarray(beta)
    if beta_arr.ndim == 0:
        dbeta = dbeta_full.sum()
    else:
        extra = dbeta_full.ndim - beta_arr.ndim
        axes = tuple(range(extra)) + tuple(
            extra + i for i, d in enumerate(beta_arr.shape) if d == 1
        )
        dbeta = dbeta_full.sum(axis=axes, keepdims=False)
        dbeta = dbeta.reshape(beta_arr.shape)
    dbeta = dbeta.astype(beta_arr.dtype)

    # No gradient for bits (handled by CGMQ directions).
    return dx, None, dbeta


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def affine_grid(
    bits, beta: jnp.ndarray, signed: bool
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The ``(scale, bias)`` of ``quantize_to_int``'s centered-code grid.

    ``codes * scale + bias`` reconstructs the fake-quant value for codes on
    this grid; the integer zero-point is ``-bias / scale``. Exposed so
    activation specs can export their affine terms (and the integer GEMM
    can fold them into its epilogue) without quantizing anything.
    """
    beta = jnp.maximum(jnp.asarray(beta, jnp.float32), 1e-8)
    alpha = -beta if signed else jnp.zeros_like(beta)
    bits_f = jnp.asarray(bits, jnp.float32)
    n = jnp.exp2(bits_f) - 1.0
    s = (beta - alpha) / n
    offset = jnp.exp2(bits_f - 1.0)
    return s, alpha + offset * s


def quantize_to_int(
    x: jnp.ndarray, bits, beta: jnp.ndarray, signed: bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Export path: integer codes + affine dequantization terms.

    Returns ``(codes, scale, bias)`` with ``codes * scale + bias`` exactly
    equal to ``quantize(x, bits, beta, signed)`` (same grid, so the int
    serving path reproduces the fake-quant forward bit-for-bit in fp32).
    Codes are centered so ``bits <= 8`` fits int8 (range
    ``[-2^(b-1), 2^(b-1)-1]`` covers the ``2^b - 1``-step grid after
    centering). ``bits`` and ``beta`` may be arrays broadcasting against
    ``x`` (per-channel / per-layer-stacked mixed precision); the code dtype
    is int8 iff every element is <= 8 bits. Used when freezing a
    CGMQ-trained model for deployment (serving engine / quant_matmul kernel).
    """
    beta = jnp.maximum(jnp.asarray(beta, jnp.float32), 1e-8)
    alpha = -beta if signed else jnp.zeros_like(beta)
    bits_f = jnp.asarray(bits, jnp.float32)
    s, bias = affine_grid(bits, beta, signed)
    x = jnp.asarray(x, jnp.float32)
    raw = jnp.round((jnp.clip(x, alpha, beta) - alpha) / s)  # in [0, 2^b-1]
    offset = jnp.exp2(bits_f - 1.0)
    codes = raw - offset  # in [-2^(b-1), 2^(b-1)-1]
    max_bits = int(np.asarray(jax.device_get(bits_f)).max()) if not isinstance(
        bits, int) else bits
    dtype = jnp.int8 if max_bits <= 8 else jnp.int32
    return codes.astype(dtype), s, bias
