"""The CGMQ constraint controller (paper §2.2-2.3 + guarantee of §3).

Owns the gate state and implements the training-time protocol:

  1. The Sat/Unsat flag is evaluated on the *total* BOP count once per check
     window (paper: end of epoch; at LLM scale ``check_every`` steps — same
     guarantee: while Unsat every gate strictly decreases between checks, so
     the constraint is reached if reachable, after which gates may recover).
  2. Every step, directions are computed from the current flag (i.e. the flag
     *lags*, exactly as in the paper: "checked at the end of the epoch and
     this result is used to determine the case of dir during the next epoch")
     and gates take one plain-SGD step ``g <- max(g - lr*dir, 0.5)``.

Everything is jit-compatible; ``sites`` is static, the state is a pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import bop as bop_lib
from .directions import build_stats, compute_directions
from .gates import clamp_gate
from .sites import SiteInfo


@dataclasses.dataclass(frozen=True)
class CGMQConfig:
    budget_rbop: float = 0.004      # relative BOP bound (paper tables: 0.4%..5%)
    direction: str = "dir1"
    gate_lr: float = 0.01           # paper: 0.01 for dir1/dir2, 0.001 for dir3
    # Steps between Sat re-evaluation. None = unset: the pipeline defaults it
    # to steps-per-epoch (paper: end of epoch); a user-set value is honored
    # everywhere (the seed pipeline silently overwrote it). Direct
    # controller_update use treats None as 1 (check every step).
    check_every: int | None = None
    dir_clip: float | None = None   # bound the Unsat direction (off = paper-literal)
    eps: float = 1e-12

    def lr_for(self) -> float:
        return self.gate_lr


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CGMQState:
    gates: dict[str, jnp.ndarray]
    sat: jnp.ndarray          # bool scalar, lagged constraint flag
    bop: jnp.ndarray          # BOP at the last check
    step: jnp.ndarray         # int32 step counter
    best_gates: dict[str, jnp.ndarray]   # last constraint-satisfying snapshot
    best_valid: jnp.ndarray   # bool: a satisfying snapshot exists

    def tree_flatten(self):
        return (
            self.gates, self.sat, self.bop, self.step,
            self.best_gates, self.best_valid,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(gates: dict[str, jnp.ndarray], sites: dict[str, SiteInfo]) -> CGMQState:
    cost = bop_lib.model_bop(sites, gates)
    return CGMQState(
        gates=gates,
        sat=jnp.asarray(False),
        bop=cost,
        step=jnp.asarray(0, jnp.int32),
        # materialized copy — aliasing `gates` would break buffer donation
        best_gates={k: jnp.array(v, copy=True) for k, v in gates.items()},
        best_valid=jnp.asarray(False),
    )


def controller_update(
    state: CGMQState,
    cfg: CGMQConfig,
    sites: dict[str, SiteInfo],
    probe_grads: dict[str, jnp.ndarray],
    weight_stats: dict[str, jnp.ndarray],
    act_stats: dict[str, dict[str, jnp.ndarray]],
    budget_bop: float,
) -> CGMQState:
    """One CGMQ gate update (jit-safe)."""
    grad_stats, mag_stats = build_stats(
        state.gates, probe_grads, weight_stats, act_stats
    )
    dirs = compute_directions(
        cfg.direction,
        state.sat,
        state.gates,
        grad_stats,
        mag_stats,
        eps=cfg.eps,
        clip=cfg.dir_clip,
    )
    new_gates = {
        k: clamp_gate(g - cfg.gate_lr * dirs[k]) for k, g in state.gates.items()
    }
    step = state.step + 1
    # Re-evaluate Sat at the end of each check window; flag applies to the
    # NEXT window (lagged, per the paper).
    due = (step % (cfg.check_every or 1)) == 0
    cost = bop_lib.model_bop(sites, new_gates)
    new_sat = jnp.where(due, cost <= budget_bop, state.sat)
    new_bop = jnp.where(due, cost, state.bop)
    # Snapshot the gates whenever a check certifies satisfaction: the gates
    # oscillate around the budget boundary once reached (Sat lets them grow
    # back), so the deployable artifact is the last *certified* snapshot —
    # this is what makes the §3 guarantee hold at export time, not just "at
    # some point during training".
    take = jnp.logical_and(due, cost <= budget_bop)
    best_gates = {
        k: jnp.where(take, new_gates[k], state.best_gates[k])
        for k in new_gates
    }
    best_valid = jnp.logical_or(state.best_valid, take)
    return CGMQState(
        gates=new_gates, sat=new_sat, bop=new_bop, step=step,
        best_gates=best_gates, best_valid=best_valid,
    )


def export_gates(state: CGMQState) -> dict[str, jnp.ndarray]:
    """The deployable gate set: last certified snapshot if one exists."""
    if bool(jax.device_get(state.best_valid)):
        return state.best_gates
    return state.gates


def guarantee_satisfied(
    state: CGMQState, sites: dict[str, SiteInfo], budget_bop: float
) -> bool:
    """Hard check used at export time: does the exported model meet B_BOP?"""
    gates = export_gates(state)
    cost = float(jax.device_get(bop_lib.model_bop(sites, gates)))
    return cost <= budget_bop + 1e-6


def export_bits(state: CGMQState) -> dict[str, Any]:
    """Freeze gates into integer bit-widths for deployment."""
    from .gates import gate_to_bits

    return {
        k: jax.device_get(gate_to_bits(g)).astype("int32")
        for k, g in export_gates(state).items()
    }
