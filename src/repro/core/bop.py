"""BOP (Bit-Operations) cost model (paper §2.5).

For a dense layer ``l(x) = W^T x + a`` the paper defines::

    BOP(l) = < sum_j b_W[j, :], b_a >

i.e. for every output activation, the product of the output activation's
bit-width with the sum of the bit-widths of the weights that produce it. With
per-tensor gates this reduces to ``MACs * b_w * b_a`` (the Uhlich/Baskin BOP
count). Convolutions multiply by the number of output positions.

Conventions (documented in DESIGN.md §3/§7):
  * Sites whose output stays floating point (the network head; paper §4.2
    "the activation of the output layer is not taken into account for the BOP
    count") are excluded from both the quantized and FP32 counts. This
    reproduces the paper's stated theoretical lower bound RBOP ~= 4/1024 =
    0.3906% for an all-2-bit LeNet-5 (paper: 0.392%).
  * MoE sites are scaled by ``active_frac = top_k / n_experts`` — BOP is a
    deployment-cost metric, so only activated expert MACs count; per-expert
    gates enter through the sum over experts scaled by ``active_frac``.
  * Attention score/value matmuls are activation-activation products with no
    weight operand; the paper's constraint covers weighted layers only, so
    they are not part of the constrained cost (KV-cache quantization for
    serving is a separate, beyond-paper feature).

All functions are jit-compatible: ``sites`` is static metadata, only gate
arrays are traced.

Gate array shapes per granularity (leading ``stack`` dim for scan-stacked
sites): per-tensor ``()``/``(k,)``; per-channel ``(O,)``/``(k, O)``;
per-weight ``weight_shape``/``(k, *weight_shape)`` with the output-channel
axis last.
"""

from __future__ import annotations

import jax.numpy as jnp

from .gates import gate_to_bits
from .sites import SiteInfo

FP_BITS = 32.0


def _per_out_weight_bits(bw: jnp.ndarray, site: SiteInfo) -> jnp.ndarray:
    """``sum_j b_W[j, o]`` per output channel; keeps a stack dim if present.

    Returns shape (), (k,), (O,), or (k, O) and is exact for every
    granularity (scalar results mean "same value for every channel").
    """
    fan_in = float(site.fan_in)
    stacked = site.stack > 1 and bw.ndim >= 1
    core = bw.shape[1:] if stacked else bw.shape
    if core == ():  # per-tensor
        return fan_in * bw
    if core == (site.out_features,):  # per-channel
        return fan_in * bw
    # per-weight: output axis last; sum every other non-stack axis.
    red = tuple(range(1, bw.ndim - 1)) if stacked else tuple(range(bw.ndim - 1))
    return bw.sum(axis=red)


def site_bop(
    site: SiteInfo,
    w_gate: jnp.ndarray | None,
    a_gate: jnp.ndarray | None,
) -> jnp.ndarray:
    """BOP of one site from its gates (either may be None -> fp32 bits)."""
    if not site.act_quantized:
        return jnp.asarray(0.0, jnp.float32)

    bw = gate_to_bits(w_gate) if w_gate is not None else jnp.asarray(FP_BITS)
    ba = gate_to_bits(a_gate) if a_gate is not None else jnp.asarray(FP_BITS)
    out = float(site.out_features)
    k = site.stack

    wsum = _per_out_weight_bits(bw, site)

    def _kind(arr):
        """'scalar' (per-tensor view), 'stack', 'chan', or 'stack_chan'."""
        if arr.ndim == 0:
            return "scalar"
        if k > 1 and arr.shape[0] == k:
            return "stack" if arr.ndim == 1 else "stack_chan"
        return "chan"

    kw, ka = _kind(wsum), _kind(ba)
    # Align shapes to (stack, chan) broadcasting space.
    def _lift(arr, kind):
        if kind == "scalar":
            return arr.reshape(1, 1)
        if kind == "stack":
            return arr.reshape(-1, 1)
        if kind == "chan":
            return arr.reshape(1, -1)
        return arr  # (k, O)

    prod = _lift(wsum, kw) * _lift(ba, ka)  # (k?, O?)
    total = jnp.sum(prod)
    # Multiply out the dims that stayed broadcast-collapsed.
    if kw in ("scalar", "stack") and ka in ("scalar", "stack"):
        total = total * out
    if kw == "scalar" and ka in ("scalar", "chan") and k > 1:
        # metadata says stacked but the gates carry no stack dim
        total = total * k
    return total * float(site.positions) * float(site.active_frac)


def activation_gate(
    gates: dict[str, jnp.ndarray], name: str
) -> jnp.ndarray | None:
    """The gate carrying a site's GEMM activation width (DESIGN.md §16).

    Resolution order: the ``.in`` input-activation gate (the operand the
    MACs actually consume — with it the certificate is TRUE BOPs), else the
    ``.a`` output gate (the historical proxy, kept so weight-only and
    output-act configs reproduce their numbers exactly), else None (fp32).
    """
    ag = gates.get(name + ".in")
    if ag is None:
        ag = gates.get(name + ".a")
    return ag


def model_bop(
    sites: dict[str, SiteInfo], gates: dict[str, jnp.ndarray]
) -> jnp.ndarray:
    """Total BOP of the model under the current gates."""
    total = jnp.asarray(0.0, jnp.float32)
    for s in sites.values():
        wg = gates.get(s.name + ".w")
        ag = activation_gate(gates, s.name)
        total = total + site_bop(s, wg, ag)
    return total


def fp32_bop(sites: dict[str, SiteInfo]) -> float:
    """BOP of the all-32-bit model (the RBOP denominator). Static."""
    total = 0.0
    for s in sites.values():
        if not s.act_quantized:
            continue
        total += s.macs_per_token * s.stack * FP_BITS * FP_BITS
    return total


def min_bop(sites: dict[str, SiteInfo]) -> float:
    """All-2-bit lower bound (paper: no pruning => b >= 2)."""
    total = 0.0
    for s in sites.values():
        if not s.act_quantized:
            continue
        total += s.macs_per_token * s.stack * 2.0 * 2.0
    return total


def rbop(sites: dict[str, SiteInfo], gates: dict[str, jnp.ndarray]):
    """Relative BOP: quantized cost / fp32 cost (paper §4.2)."""
    return model_bop(sites, gates) / fp32_bop(sites)


def budget_from_rbop(sites: dict[str, SiteInfo], rbop_bound: float) -> float:
    """Absolute BOP budget B_BOP from a relative bound (e.g. 0.004 = 0.4%)."""
    return float(rbop_bound) * fp32_bop(sites)
