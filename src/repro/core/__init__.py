"""CGMQ core: the paper's contribution as a composable JAX module.

Public surface:
  quantizer   -- Eq. 1 fake quantization with STE + learnable ranges
  gates       -- Eq. 2-4 gate variables, T / G_b, residual decomposition
  sites       -- QuantContext threaded through model forwards; site registry
  bop         -- Eq. (BOP) cost model and RBOP helpers
  directions  -- dir_1..dir_3 (paper) and dir_4 (beyond-paper, scale-free)
  controller  -- Sat/Unsat window protocol + gate SGD (the guarantee of §3)
  calibration -- range calibration pipeline (paper §2.4)
"""

from . import bop, calibration, controller, directions, gates, quantizer, sites  # noqa: F401
from .controller import CGMQConfig, CGMQState, controller_update, init_state  # noqa: F401
from .gates import gate_to_bits, gated_fake_quant, residual_fake_quant  # noqa: F401
from .quantizer import fake_quant, quantize, quantize_to_int  # noqa: F401
from .sites import (  # noqa: F401
    PER_CHANNEL,
    PER_TENSOR,
    PER_WEIGHT,
    QuantConfig,
    QuantContext,
    collect_sites,
    init_gates,
    init_probes,
    init_ranges_from_weights,
    merge_ranges,
    split_learnable_ranges,
)
