"""Quantization sites and the QuantContext threaded through model forwards.

A *site* is one weight-matmul (dense / conv / expert GEMM) together with the
activation-quantization point of its output (paper Fig. 1: ``Q(W) -> layer ->
activation -> Q(a)``). Models never touch gates directly; they call::

    w_q      = qc.weight(name, w)             # quantize a weight tensor
    a_q      = qc.act(name, a)                # quantize an output activation
    qc.register_matmul(name, w_shape, positions=..., stack=k, active_frac=f)

``QuantContext`` operates in one of six modes:

  off        -- identity; used for FP32 pretraining and baselines.
  collect    -- abstract tracing (``jax.eval_shape``): records site metadata
                (MAC counts, shapes, signedness defaults) without compute.
  calibrate  -- FP32 forward that additionally records running range/mean
                statistics per site (returned functionally, jit-safe).
  train      -- fake quantization using gates + learnable ranges; also emits
                per-site activation statistics needed by the CGMQ directions
                (paper §2.3) and injects zero-valued "probe" parameters whose
                gradients equal the batch-summed activation gradients.
  export     -- weight-capture pass: ``weight()`` records the full tensor per
                site name in ``weight_stats`` (stacked along the scan axis by
                the existing stats plumbing) and everything else is identity.
                Used by ``quant.export.export_sites`` (via
                ``serving.engine.export_int_model``) to build the site-name
                -> weight mapping without a hand-maintained table.
  serve      -- deployment forward (DESIGN.md §8/§11). Serve mode carries NO
                gates or ranges: it runs off ``specs`` (site ->
                ``quant.QuantSpec``, the frozen bits/range/sign the
                controller certified) plus ``qweights`` (site ->
                ``quant.QuantizedTensor``, the packed int-code export).
                Matmul sites with an export dispatch the bit-width-matched
                fused-dequant GEMM (``layers.qmatmul`` consults
                ``serving_weight``); non-matmul callers of ``weight()`` get
                the dequantized frozen codes; remaining sites fall back to
                fake quantization at the spec bit-width. Activations
                fake-quantize at the spec bits — numerically the train-mode
                path with ``bits = T(g)`` precomputed — so serve logits
                match the train-mode fake-quant reference.

The probe trick: ``a + probe`` with ``probe = 0`` of the gate-group shape makes
``dL/dprobe = sum over batch (and group) of dL/da`` — exactly the
``|sum_i grad_a L|`` statistic the paper's directions need, without hooks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import gates as G
from .quantizer import fake_quant

# Gate granularities (paper §2.1 "two settings", plus per-channel for LLMs).
PER_TENSOR = "per_tensor"    # one gate per weight tensor / activation tensor ("layer")
PER_CHANNEL = "per_channel"  # one gate per output channel
PER_WEIGHT = "per_weight"    # one gate per element ("indiv.")

GRANULARITIES = (PER_TENSOR, PER_CHANNEL, PER_WEIGHT)


@dataclasses.dataclass(frozen=True)
class SiteInfo:
    """Static metadata for one matmul site (recorded in collect mode)."""

    name: str
    weight_shape: tuple[int, ...]   # full weight tensor shape
    fan_in: int                     # MACs contributed per output element
    out_features: int               # number of output channels
    positions: int                  # output positions per token/sample (conv spatial, seq kept out)
    stack: int                      # scan-stacked copies (leading gate dim), 1 if unstacked
    active_frac: float              # MoE: fraction of experts active per token
    act_quantized: bool             # False for fp outputs (head) -- excluded from BOP
    w_signed: bool = True
    a_signed: bool = True

    @property
    def macs_per_token(self) -> float:
        """MACs per token for ONE stacked copy of this site."""
        return float(self.fan_in) * self.out_features * self.positions * self.active_frac


@dataclasses.dataclass
class QuantConfig:
    enabled: bool = True
    granularity: str = PER_TENSOR
    impl: str = "direct"            # 'direct' (telescoped) | 'residual' (paper-literal)
    input_bits: int = 8             # fixed input quantization (paper §4.2)
    quantize_acts: bool = True
    act_granularity: str | None = None   # defaults to `granularity`
    # Gate the matmul INPUT activations too (".in" sites, DESIGN.md §16):
    # per-tensor affine, so the cost certificate covers compute
    # (w_bits x a_bits x MACs) and serving can run integer GEMMs. Off by
    # default: weight-only configs keep their exact pytree structure.
    quantize_inputs: bool = False

    def __post_init__(self):
        if self.act_granularity is None:
            self.act_granularity = (
                PER_CHANNEL if self.granularity == PER_WEIGHT else self.granularity
            )


def _group_shape(granularity: str, full_shape: tuple[int, ...], out_features: int):
    if granularity == PER_TENSOR:
        return ()
    if granularity == PER_CHANNEL:
        return (out_features,)
    return tuple(full_shape)


class QuantContext:
    """Threaded through model forwards; see module docstring for modes."""

    def __init__(
        self,
        mode: str = "off",
        cfg: QuantConfig | None = None,
        gates: dict[str, jnp.ndarray] | None = None,
        ranges: dict[str, Any] | None = None,
        probes: dict[str, jnp.ndarray] | None = None,
        qweights: dict[str, Any] | None = None,
        specs: dict[str, Any] | None = None,
        matmul_impl: str = "ref",
    ):
        assert mode in ("off", "collect", "calibrate", "train", "export",
                        "serve")
        assert matmul_impl in ("ref", "pallas", "pallas_interpret")
        self.mode = mode
        self.cfg = cfg or QuantConfig()
        self.gates = gates or {}
        self.ranges = ranges or {}
        self.probes = probes or {}
        # serve mode: site name -> quant.QuantizedTensor (packed int codes)
        self.qweights = qweights or {}
        # serve mode: site name -> quant.QuantSpec (frozen bits/range/sign)
        self.specs = specs or {}
        self.matmul_impl = matmul_impl
        # Outputs populated during tracing:
        self.sites: dict[str, SiteInfo] = {}
        self.act_stats: dict[str, dict[str, jnp.ndarray]] = {}
        self.weight_stats: dict[str, jnp.ndarray] = {}
        # Stack context for scan-over-layers bodies.
        self._stack = 1
        self._prefix: list[str] = []

    # ---- naming / scan support -------------------------------------------
    def child(self, gates=None, ranges=None, probes=None,
              qweights=None, specs=None) -> "QuantContext":
        """Sub-context for a ``lax.scan`` body with per-layer slices.

        The body must return ``(child.act_stats, child.weight_stats)`` as scan
        outputs; the caller merges them back via ``absorb_stacked_stats``.
        """
        c = QuantContext(
            mode=self.mode,
            cfg=self.cfg,
            gates=self.gates if gates is None else gates,
            ranges=self.ranges if ranges is None else ranges,
            probes=self.probes if probes is None else probes,
            qweights=self.qweights if qweights is None else qweights,
            specs=self.specs if specs is None else specs,
            matmul_impl=self.matmul_impl,
        )
        c._prefix = list(self._prefix)
        c._stack = self._stack
        c.sites = self.sites  # collect mode: share the registry
        return c

    def absorb_stacked_stats(self, act_stats, weight_stats):
        """Merge stacked per-layer stats (scan outputs) into this context."""
        for k, v in act_stats.items():
            self.act_stats[k] = v
        for k, v in weight_stats.items():
            self.weight_stats[k] = v

    def scope(self, name: str):
        ctx = self

        class _Scope:
            def __enter__(self_s):
                ctx._prefix.append(name)

            def __exit__(self_s, *a):
                ctx._prefix.pop()

        return _Scope()

    def layer_stack(self, k: int):
        ctx = self

        class _Stack:
            def __enter__(self_s):
                ctx._stack *= k

            def __exit__(self_s, *a):
                ctx._stack //= k

        return _Stack()

    def _full(self, name: str) -> str:
        return "/".join(self._prefix + [name])

    # ---- site registration ------------------------------------------------
    def register_matmul(
        self,
        name: str,
        weight_shape: tuple[int, ...],
        fan_in: int,
        out_features: int,
        positions: int = 1,
        active_frac: float = 1.0,
        act_quantized: bool = True,
        w_signed: bool = True,
        a_signed: bool = True,
    ) -> str:
        full = self._full(name)
        if self.mode in ("collect", "export") and full not in self.sites:
            self.sites[full] = SiteInfo(
                name=full,
                weight_shape=tuple(int(d) for d in weight_shape),
                fan_in=int(fan_in),
                out_features=int(out_features),
                positions=int(positions),
                stack=self._stack,
                active_frac=float(active_frac),
                act_quantized=bool(act_quantized),
                w_signed=w_signed,
                a_signed=a_signed,
            )
        return full

    # ---- quantization entry points -----------------------------------------
    def serving_weight(self, name: str):
        """Int-code export for this site, or None (serve mode only)."""
        if self.mode != "serve":
            return None
        return self.qweights.get(self._full(name) + ".w")

    def weight(self, name: str, w: jnp.ndarray) -> jnp.ndarray:
        full = self._full(name)
        if self.mode == "export":
            # Capture pass: record the full tensor under its site name; the
            # scan-stats plumbing stacks per-layer slices back to (R, ...).
            self.weight_stats[full + ".w"] = w
            return w
        if self.mode in ("off", "collect", "calibrate") or not self.cfg.enabled:
            return w
        key = full + ".w"
        if self.mode == "serve":
            qt = self.qweights.get(key)
            if qt is not None:
                # Non-matmul consumers of an exported site (e.g. LeNet's
                # explicit `h @ w`): serve the dequantized frozen codes, so
                # every serving path reads the same artifact.
                return qt.dequantize().astype(w.dtype)
            # Fallback for sites without an int-code export (per-weight
            # granularity, >8-bit, MoE/conv shapes): fake-quant at the
            # spec bit-width, no stats or probes.
            spec = self.specs[key]
            return fake_quant(w, spec.bits, spec.beta, spec.signed)
        g = self.gates[key]
        beta = self.ranges[key]["beta"]
        signed = self.ranges[key]["signed"]
        # Group-reduced |w| for dir_2/dir_3 (paper §2.3).
        self.weight_stats[key] = self._w_group_stat(w, g)
        # Probe param: dL/dprobe == (group-summed) dL/dw through the STE.
        if key in self.probes:
            w = w + jnp.broadcast_to(
                self._expand_w_probe(self.probes[key], w), w.shape
            ).astype(w.dtype)
        return self._fq(w, g, beta, signed)

    def act(self, name: str, a: jnp.ndarray, *, feature_axis: int = -1) -> jnp.ndarray:
        """Quantize an output activation; records stats per mode."""
        full = self._full(name)
        key = full + ".a"
        if self.mode in ("off", "export") or not self.cfg.enabled \
                or not self.cfg.quantize_acts:
            return a
        if self.mode == "collect":
            return a
        if self.mode == "serve":
            spec = self.specs[key]
            return fake_quant(a, self._expand_act_gate(spec.bits, a),
                              self._expand_act_gate(spec.beta, a),
                              spec.signed)
        if self.mode == "calibrate":
            # Running-range statistics (momentum handled by the caller loop).
            red = tuple(i for i in range(a.ndim) if i != a.ndim + feature_axis)
            self.act_stats[key] = {
                "max": jnp.max(jnp.abs(a)),
                "max_per_ch": jnp.max(jnp.abs(a), axis=red),
                "min": jnp.min(a),
                "mean_abs": jnp.mean(jnp.abs(a)),
            }
            return a
        # train mode
        g = self.gates[key]
        beta = self.ranges[key]["beta"]
        signed = self.ranges[key]["signed"]
        # Activation statistic for dir_2/dir_3 (|mean over batch of a|),
        # reduced to the gate-group shape.
        stat = self._act_group_stat(a, g)
        self.act_stats[key] = {"mean_abs": stat}
        if key in self.probes:
            a = a + jnp.broadcast_to(self.probes[key], a.shape).astype(a.dtype)
        return self._fq(a, self._expand_act_gate(g, a), self._expand_act_gate(beta, a), signed)

    def input_spec(self, name: str):
        """Activation spec for this matmul's INPUT, or None (serve only).

        Serve-mode ``layers.qmatmul`` consults this next to
        ``serving_weight``: an exported int-code weight PLUS a calibrated
        input spec dispatches the int8×int8 integer-accumulation kernel
        (DESIGN.md §16).
        """
        if self.mode != "serve":
            return None
        return self.specs.get(self._full(name) + ".in")

    def act_in(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        """Quantize a matmul INPUT activation (the ``.in`` site, §16).

        Per-tensor affine, gated like any other site so gate descent trades
        weight vs activation precision and the BOP certificate covers
        compute. In serve mode the integer GEMM quantizes its own tile
        (``quant_matmul_qt``); this path fake-quants only the fp-fallback
        sites that still carry a spec, keeping their logits on the same
        grid as the integer path.
        """
        key = self._full(name) + ".in"
        if not self.cfg.enabled:
            return x
        if self.mode == "serve":
            spec = self.specs.get(key)
            if spec is None:
                return x
            return fake_quant(x, jnp.asarray(spec.bits, jnp.float32),
                              jnp.asarray(spec.beta, jnp.float32),
                              spec.signed)
        if self.mode in ("off", "collect", "export") \
                or not self.cfg.quantize_inputs:
            return x
        if self.mode == "calibrate":
            # Per-tensor running-range stats (same EMA loop as ``.a`` sites).
            self.act_stats[key] = {
                "max": jnp.max(jnp.abs(x)),
                "min": jnp.min(x),
                "mean_abs": jnp.mean(jnp.abs(x)),
            }
            return x
        # train mode — tolerate states trained before ``.in`` gates existed.
        g = self.gates.get(key)
        if g is None:
            return x
        beta = self.ranges[key]["beta"]
        signed = self.ranges[key]["signed"]
        self.act_stats[key] = {"mean_abs": self._act_group_stat(x, g)}
        if key in self.probes:
            x = x + jnp.broadcast_to(self.probes[key], x.shape).astype(x.dtype)
        return self._fq(x, self._expand_act_gate(g, x),
                        self._expand_act_gate(beta, x), signed)

    def input(self, x: jnp.ndarray) -> jnp.ndarray:
        """Fixed-width input quantization (paper: 8-bit sensor data)."""
        if self.mode not in ("train", "serve") or not self.cfg.enabled:
            return x
        beta = jnp.maximum(jnp.max(jnp.abs(jax.lax.stop_gradient(x))), 1e-8)
        signed = True
        return fake_quant(x, jnp.asarray(float(self.cfg.input_bits)), beta, signed)

    # ---- helpers ------------------------------------------------------------
    def _fq(self, x, g, beta, signed):
        if self.cfg.impl == "residual":
            return G.residual_fake_quant(x, g, beta, signed)
        return G.gated_fake_quant(x, g, beta, signed)

    @staticmethod
    def _expand_act_gate(g: jnp.ndarray, a: jnp.ndarray):
        """Broadcast a group-shaped array against activation ``a`` (feature-last)."""
        g = jnp.asarray(g)
        if g.ndim == 0:
            return g
        return g.reshape((1,) * (a.ndim - g.ndim) + g.shape)

    @staticmethod
    def _act_group_stat(a: jnp.ndarray, g: jnp.ndarray):
        """|mean over batch (and non-group dims) of a|, shaped like the gate."""
        g = jnp.asarray(g)
        a = jax.lax.stop_gradient(a)
        if g.ndim == 0:
            return jnp.abs(jnp.mean(a))
        red = tuple(range(a.ndim - g.ndim))
        return jnp.abs(jnp.mean(a, axis=red))

    @staticmethod
    def _w_group_stat(w: jnp.ndarray, g: jnp.ndarray):
        """Group-reduced |w| (mean within group), shaped like the gate."""
        g = jnp.asarray(g)
        w = jax.lax.stop_gradient(w)
        if g.ndim == 0:
            return jnp.mean(jnp.abs(w))
        if g.shape == w.shape:
            return jnp.abs(w)
        # per-channel (last axis) or stacked variants: reduce all axes whose
        # sizes don't line up with the trailing gate shape.
        extra = w.ndim - g.ndim
        red = tuple(i for i in range(w.ndim) if not (
            i >= extra and w.shape[i] == g.shape[i - extra]
        ))
        return jnp.mean(jnp.abs(w), axis=red)

    @staticmethod
    def _expand_w_probe(p: jnp.ndarray, w: jnp.ndarray):
        """Broadcast a probe of group shape against weight ``w``.

        Per-tensor: scalar. Per-weight: same shape. Per-channel / stacked:
        align trailing dims (channel-last convention).
        """
        p = jnp.asarray(p)
        if p.ndim == 0 or p.shape == w.shape:
            return p
        return p.reshape((1,) * (w.ndim - p.ndim) + p.shape)


# ---------------------------------------------------------------------------
# State initialization from collected sites
# ---------------------------------------------------------------------------


def collect_sites(forward, *abstract_args, cfg: QuantConfig | None = None):
    """Trace ``forward(qc, *args)`` under eval_shape and return its sites."""
    qc = QuantContext(mode="collect", cfg=cfg)

    def _fn(*args):
        return forward(qc, *args)

    jax.eval_shape(_fn, *abstract_args)
    return qc.sites


def _stacked(shape: tuple[int, ...], stack: int) -> tuple[int, ...]:
    return ((stack,) + shape) if stack > 1 else shape


def init_gates(
    sites: dict[str, SiteInfo], cfg: QuantConfig, init: float = G.GATE_INIT
) -> dict[str, jnp.ndarray]:
    """Gate pytree: one array per weight site and per quantized activation."""
    out = {}
    for s in sites.values():
        wshape = _group_shape(cfg.granularity, s.weight_shape, s.out_features)
        out[s.name + ".w"] = jnp.full(_stacked(wshape, s.stack), init, jnp.float32)
        if s.act_quantized:
            ashape = _group_shape(cfg.act_granularity, (s.out_features,), s.out_features)
            out[s.name + ".a"] = jnp.full(_stacked(ashape, s.stack), init, jnp.float32)
        if cfg.quantize_inputs and s.act_quantized:
            # ``.in`` sites are per-tensor by contract: the integer GEMM
            # quantizes the whole input tile against ONE affine grid (§16).
            out[s.name + ".in"] = jnp.full(_stacked((), s.stack), init,
                                           jnp.float32)
    return out


def init_probes(sites: dict[str, SiteInfo], cfg: QuantConfig) -> dict[str, jnp.ndarray]:
    """Zero probe params added to quantized activations (gradient taps)."""
    out = {}
    for s in sites.values():
        if s.act_quantized:
            ashape = _group_shape(cfg.act_granularity, (s.out_features,), s.out_features)
            out[s.name + ".a"] = jnp.zeros(_stacked(ashape, s.stack), jnp.float32)
        if cfg.quantize_inputs and s.act_quantized:
            out[s.name + ".in"] = jnp.zeros(_stacked((), s.stack), jnp.float32)
    return out


def init_ranges_from_weights(
    sites: dict[str, SiteInfo],
    cfg: QuantConfig,
    weight_lookup,
) -> dict[str, Any]:
    """Weight ranges from min/max (paper §2.4). ``weight_lookup(name)->array``.

    Activation ranges are placeholders (beta=1) until calibration runs.
    """
    ranges: dict[str, Any] = {}
    for s in sites.values():
        w = weight_lookup(s.name)
        if w is None:
            beta = jnp.ones(_stacked((), s.stack), jnp.float32)
            signed = True
        else:
            w = jnp.asarray(w)
            if cfg.granularity == PER_CHANNEL:
                red = tuple(range(w.ndim - 1)) if s.stack == 1 else tuple(
                    range(1, w.ndim - 1)
                )
                beta = jnp.max(jnp.abs(w), axis=red)
                all_pos = jnp.all(jnp.min(w, axis=red) >= 0)
            elif cfg.granularity == PER_WEIGHT:
                beta = jnp.abs(w) + 1e-8
                all_pos = jnp.all(w >= 0)
            else:
                if s.stack > 1:
                    red = tuple(range(1, w.ndim))
                    beta = jnp.max(jnp.abs(w), axis=red)
                else:
                    beta = jnp.max(jnp.abs(w))
                all_pos = jnp.all(w >= 0)
            signed = not bool(all_pos)
        ranges[s.name + ".w"] = {"beta": beta.astype(jnp.float32), "signed": signed}
        if s.act_quantized:
            ashape = _group_shape(cfg.act_granularity, (s.out_features,), s.out_features)
            ranges[s.name + ".a"] = {
                "beta": jnp.ones(_stacked(ashape, s.stack), jnp.float32),
                "signed": True,
            }
        if cfg.quantize_inputs and s.act_quantized:
            ranges[s.name + ".in"] = {
                "beta": jnp.ones(_stacked((), s.stack), jnp.float32),
                "signed": True,
            }
    return ranges


def split_learnable_ranges(ranges: dict[str, Any]):
    """Split into (learnable betas pytree, static signed map)."""
    betas = {k: v["beta"] for k, v in ranges.items()}
    signed = {k: bool(v["signed"]) for k, v in ranges.items()}
    return betas, signed


def merge_ranges(betas: dict[str, jnp.ndarray], signed: dict[str, bool]):
    return {k: {"beta": betas[k], "signed": signed[k]} for k in betas}


def total_gate_count(gts: dict[str, jnp.ndarray]) -> int:
    return int(sum(np.prod(v.shape) if v.ndim else 1 for v in gts.values()))
