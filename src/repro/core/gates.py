"""Gate variables (paper §2.1, Eqs. 2-4).

A gate ``g`` is a free real variable; ``T(g)`` (Eq. 4) maps it onto a
bit-width in {0, 2, 4, 8, 16, 32}; the binary gate functions
``G_b(g) = [T(g) >= b]`` assemble the quantized value from the power-of-2
residual decomposition (Eq. 3)::

    x_q = G2 * (x_2 + G4 * (eps_4 + G8 * (eps_8 + G16 * (eps_16 + G32*eps_32))))

Because ``eps_j := x_j - x_{j/2}`` the chain telescopes exactly to
``x_q = Q(x, T(g))`` — ``gated_fake_quant`` uses that identity (one rounding
pass instead of five; see DESIGN.md §3), while ``residual_fake_quant`` keeps
the paper's literal form as the reference implementation. Equality of the two
is property-tested in ``tests/test_gates.py``.

Pruning (T = 0) is out of scope for the paper; gates are clamped to
``g >= GATE_MIN = 0.5`` after every update ("as soon as a value g < 0.5 is
obtained, it is replaced with 0.5").
"""

from __future__ import annotations

import jax.numpy as jnp

from .quantizer import LEVELS, fake_quant

# Paper: gates below 0.5 are reset to 0.5 (no pruning), so T(g) >= 2.
GATE_MIN = 0.5
# Initial gate value (paper §4.2): T(5.5) = 32-bit at the start of training.
GATE_INIT = 5.5
# Upper clamp (framework addition): everything above 4 is 32-bit already;
# capping keeps cost-free gates from drifting far and slows oscillation.
GATE_MAX = 6.0

# Thresholds of T (Eq. 4): g in (k-1, k] -> bits; g > 4 -> 32.
_T_EDGES = (0.0, 1.0, 2.0, 3.0, 4.0)
_T_BITS = (0.0, 2.0, 4.0, 8.0, 16.0, 32.0)


def transform(g: jnp.ndarray) -> jnp.ndarray:
    """``T(g)`` (Eq. 4): piecewise-constant map from gate to bit-width."""
    g = jnp.asarray(g, jnp.float32)
    bits = jnp.full_like(g, _T_BITS[0])
    for edge, b in zip(_T_EDGES, _T_BITS[1:]):
        bits = jnp.where(g > edge, b, bits)
    return bits


def gate_fn(g: jnp.ndarray, b: int) -> jnp.ndarray:
    """``G_b(g) = 1[T(g) >= b]`` (binary gate of Eq. 3)."""
    return (transform(g) >= b).astype(jnp.float32)


def gate_to_bits(g: jnp.ndarray) -> jnp.ndarray:
    """Bit-width implied by a (clamped) gate. Minimum is 2 (no pruning)."""
    return transform(jnp.maximum(g, GATE_MIN))


def clamp_gate(g: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(g, GATE_MIN, GATE_MAX)


def gated_fake_quant(x, g, beta, signed: bool):
    """Optimized fake quantization at bit-width ``T(g)`` (telescoped Eq. 3)."""
    bits = gate_to_bits(g)
    return fake_quant(x, bits, beta, signed)


def residual_fake_quant(x, g, beta, signed: bool):
    """Paper-literal Eq. 3: explicit residual chain with binary gates.

    Reference implementation (used by tests and available via
    ``QuantConfig.impl='residual'``); numerically identical to
    ``gated_fake_quant``.
    """
    g = jnp.maximum(jnp.asarray(g, jnp.float32), GATE_MIN)
    # x_b for every level b in {2, 4, 8, 16, 32}.
    xs = {b: fake_quant(x, jnp.asarray(float(b)), beta, signed) for b in LEVELS}
    # eps_j = x_j - x_{j/2}
    eps = {b: xs[b] - xs[b // 2] for b in LEVELS[1:]}
    out = xs[LEVELS[-1]] - xs[LEVELS[-1]]  # zeros with correct dtype/shape
    # Build innermost-out: G32*eps32 -> +eps16 ... -> x2 * G2.
    acc = gate_fn(g, 32) * eps[32]
    acc = gate_fn(g, 16) * (eps[16] + acc)
    acc = gate_fn(g, 8) * (eps[8] + acc)
    acc = gate_fn(g, 4) * (eps[4] + acc)
    out = gate_fn(g, 2) * (xs[2] + acc)
    # G2 is always 1 after clamping (no pruning), so `out` == Q(x, T(g)).
    return out
