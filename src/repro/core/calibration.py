"""Quantization-range calibration (paper §2.4).

Pipeline stage 2: given an FP32-pretrained model, determine initial
quantization ranges before range learning and CGMQ:

  * weights: per-group max/|min| (``alpha = -beta`` when any value is
    negative, ``alpha = 0`` otherwise) — computed directly from the weights.
  * activations: running mean of the per-batch max statistic with momentum
    0.1 (paper: "a running mean is used to update the ranges. The momentum of
    this running mean is 0.1"), aggregated over calibration batches; the sign
    flag comes from whether any negative activation was observed.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .sites import PER_CHANNEL, QuantConfig, QuantContext, SiteInfo

MOMENTUM = 0.1


def calibrate_activations(
    forward: Callable,
    batches,
    cfg: QuantConfig,
    momentum: float = MOMENTUM,
) -> dict[str, dict[str, Any]]:
    """Run calibration batches through ``forward(qc, batch)``.

    Returns {act_key: {'beta': running max, 'signed': bool}}. The forward is
    jitted once; stats are returned functionally from the traced context.
    """

    @jax.jit
    def _run(batch):
        qc = QuantContext(mode="calibrate", cfg=cfg)
        forward(qc, batch)
        return qc.act_stats

    running: dict[str, dict[str, Any]] = {}
    for batch in batches:
        stats = jax.device_get(_run(batch))
        for key, st in stats.items():
            per_ch = cfg.act_granularity == PER_CHANNEL
            # ``.in`` (GEMM-input) sites are per-tensor by contract and
            # record no per-channel max (DESIGN.md §16).
            mx = st["max_per_ch"] if per_ch and "max_per_ch" in st else st["max"]
            neg = bool(np.any(np.asarray(st["min"]) < 0))
            if key not in running:
                running[key] = {"beta": np.asarray(mx, np.float32), "signed": neg}
            else:
                r = running[key]
                r["beta"] = (1 - momentum) * r["beta"] + momentum * np.asarray(
                    mx, np.float32
                )
                r["signed"] = r["signed"] or neg
    return {
        k: {"beta": jnp.asarray(v["beta"]), "signed": bool(v["signed"])}
        for k, v in running.items()
    }


def apply_act_calibration(
    ranges: dict[str, Any], act_ranges: dict[str, dict[str, Any]]
) -> dict[str, Any]:
    """Overwrite placeholder activation ranges with calibrated ones."""
    out = dict(ranges)
    for key, v in act_ranges.items():
        if key in out:
            base = out[key]
            beta = jnp.broadcast_to(
                jnp.asarray(v["beta"], jnp.float32), jnp.shape(base["beta"])
            )
            out[key] = {"beta": beta, "signed": bool(v["signed"])}
    return out


def stack_act_ranges(
    per_layer: list[dict[str, dict[str, Any]]]
) -> dict[str, dict[str, Any]]:
    """Stack per-layer calibration results for scan-stacked sites."""
    keys = per_layer[0].keys()
    out = {}
    for k in keys:
        out[k] = {
            "beta": jnp.stack([jnp.asarray(p[k]["beta"]) for p in per_layer]),
            "signed": any(bool(p[k]["signed"]) for p in per_layer),
        }
    return out
