"""The one TrainState pytree shared by every training stack (DESIGN.md §9).

Layout (all leaves are device arrays; ``None`` marks a field a stage does not
use — e.g. ``cgmq`` during FP32 pretraining before sites exist):

  params  model parameters (any pytree)
  betas   learnable quantization ranges, keyed ``<site>.w`` / ``<site>.a``
          (the static ``signed`` half of a range lives in the engine/recipe,
          not in state — it is a python bool map, not an array)
  opt     AdamState over ``(params, betas)``
  cgmq    controller state: gates, lagged Sat flag, BOP at last check, the
          last *certified* gate snapshot and its validity flag (paper §3)
  probes  zero-valued gradient taps (never updated; their gradients feed the
          controller's direction statistics)
  rng     PRNG key driving epoch permutations — carrying it in state is what
          makes a restored run replay the exact batch order of the
          uninterrupted one
  step    global step counter (int32), monotonic across stages

Checkpointing the whole state through ``checkpoint/checkpointer.py``
therefore preserves gate trajectories, controller flags and data order:
a resumed run is bit-identical to an uninterrupted one
(tests/test_train_engine.py).

Note: checkpoints written before this unified layout (the old 4-field
``launch/steps.TrainState`` without probes/rng/step) are not restorable —
``Checkpointer.restore`` reports the missing arrays; rerun from scratch.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    betas: Any
    opt: Any
    cgmq: Any = None
    probes: Any = None
    rng: Any = None
    step: Any = None

    def tree_flatten(self):
        return (
            self.params, self.betas, self.opt, self.cgmq,
            self.probes, self.rng, self.step,
        ), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)
