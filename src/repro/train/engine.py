"""Device-resident training engine for all four CGMQ pipeline stages.

One engine drives FP32 pretraining ("fp"), range learning ("range") and the
CGMQ joint stage ("cgmq") from the same unified ``TrainState``
(``train/state.py``); stage 2 (calibration) is a forward-only pass the
sequencer (``core/pipeline.py``) runs between them. Contract (DESIGN.md §9):

  * **Scan epochs.** An epoch is ONE jitted computation: the permutation is
    drawn on device from ``state.rng``, the dataset is gathered into
    ``(num_batches, batch, ...)`` staged batches, and ``jax.lax.scan`` runs
    the step over them with the ``TrainState`` as the (donated) carry.
    Metrics accumulate in the carry; nothing crosses to the host inside an
    epoch.
  * **Tail batches.** ``ceil(N / B)`` batches per epoch; the final batch is
    padded with repeated samples carrying zero weight, so every sample
    contributes exactly once (the legacy python loop dropped up to ``B - 1``
    samples per epoch). Losses/metrics are weighted means.
  * **Host-sync model.** The outer loop dispatches ``eval_every`` epochs
    asynchronously and then performs exactly ONE ``device_get`` per eval
    window (metrics + batched eval accuracy together). ``host_syncs`` counts
    them; tests assert one sync per window.
  * **Loop modes.** ``loop="scan"`` (default) and ``loop="python"`` — the
    per-batch dispatch reference. Both share the same staging and step
    functions, so trajectories are numerically identical; the python mode
    exists as the equivalence oracle and the benchmark baseline.
  * **Sharding.** An optional ``ShardingPlan`` data-parallel-shards the
    staged batches (state is replicated); model code is unchanged.
  * **Checkpointing.** ``save_state`` / ``restore_state`` persist the whole
    ``TrainState`` — params, betas, Adam moments, gates, Sat/best flags,
    probes, RNG, step — through ``checkpoint/checkpointer.py``, so a resumed
    run replays the uninterrupted trajectory bit-for-bit and preserves the
    §3 satisfaction guarantee (the last certified snapshot travels with the
    state).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bop as bop_lib
from repro.core import controller as ctrl
from repro.core.sites import QuantConfig, QuantContext, merge_ranges
from repro.optim.adam import AdamConfig, adam, apply_updates

from .state import TrainState

STAGES = ("fp", "range", "cgmq")


# ---------------------------------------------------------------------------
# Losses / metrics (weighted: ``w`` is 1 for real samples, 0 for tail padding)
# ---------------------------------------------------------------------------


def per_example_xent(logits, labels):
    """Per-example cross entropy, shape (B,). The engine's loss contract is
    per-example so tail-padding weights can mask before the mean."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]


def masked_mean(values, weights):
    if jnp.ndim(values) == 0:
        raise ValueError(
            "engine loss_fn must return PER-EXAMPLE losses of shape (B,) so "
            "tail-padding weights can mask them (got a scalar — a legacy "
            "mean loss like pipeline.cross_entropy; use per_example_xent)")
    return jnp.sum(values * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def masked_accuracy(logits, labels, weights):
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return masked_mean(hit, weights)


# ---------------------------------------------------------------------------
# Batch staging (jit-safe; shared by scan epochs, python epochs and eval)
# ---------------------------------------------------------------------------


def stage_epoch(rng, xs, ys, batch_size: int, *, plan=None):
    """Stage one epoch: ``(nb, B, ...)`` batches + per-sample weights.

    ``nb = ceil(N / B)``; the tail batch is padded by repeating the head of
    the permutation with weight 0, so every sample is seen exactly once.
    ``rng=None`` skips the permutation (eval order). Returns
    ``(bx, by, bw, new_rng)``.
    """
    n = int(xs.shape[0])
    b = int(batch_size)
    nb = -(-n // b)
    pad = nb * b - n
    if rng is None:
        idx = jnp.arange(n)
    else:
        rng, sub = jax.random.split(rng)
        idx = jax.random.permutation(sub, n)
    if pad:
        # jnp.resize cycles, so this also covers pad > n (dataset smaller
        # than half a batch) where a plain idx[:pad] would under-fill
        idx = jnp.concatenate([idx, jnp.resize(idx, (pad,))])
    w = jnp.concatenate(
        [jnp.ones((n,), jnp.float32), jnp.zeros((pad,), jnp.float32)]
    ) if pad else jnp.ones((n,), jnp.float32)
    bx = xs[idx].reshape((nb, b) + xs.shape[1:])
    by = ys[idx].reshape((nb, b) + ys.shape[1:])
    bw = w.reshape(nb, b)
    if plan is not None and b % plan.dp_size == 0:
        from jax.sharding import PartitionSpec as P

        def _c(t):
            spec = P(None, plan.batch_axes, *((None,) * (t.ndim - 2)))
            return jax.lax.with_sharding_constraint(t, plan.named(spec))

        bx, by, bw = _c(bx), _c(by), _c(bw)
    return bx, by, bw, rng


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 128
    lr: float = 1e-3
    eval_every: int = 10      # epochs per host sync / eval window
    loop: str = "scan"        # 'scan' | 'python' (per-batch dispatch reference)
    log: Callable[[str], None] = print

    def __post_init__(self):
        assert self.loop in ("scan", "python"), self.loop


class TrainEngine:
    """See module docstring. ``forward(qc, params, x) -> logits``."""

    def __init__(
        self,
        forward: Callable,
        ecfg: EngineConfig,
        *,
        qcfg: QuantConfig | None = None,
        loss_fn: Callable = per_example_xent,
        plan=None,
        adam_cfg: AdamConfig | None = None,
    ):
        self.forward = forward
        self.ecfg = ecfg
        self.qcfg = qcfg or QuantConfig()
        self.loss_fn = loss_fn
        self.plan = plan
        if plan is not None and ecfg.batch_size % plan.dp_size != 0:
            ecfg.log(f"[engine] WARNING: batch_size {ecfg.batch_size} not "
                     f"divisible by dp_size {plan.dp_size} — staged batches "
                     "will NOT be data-parallel sharded")
        self.adam_cfg = adam_cfg or AdamConfig(lr=ecfg.lr)
        self._adam_init, self._adam_update = adam(self.adam_cfg)
        # bound after site collection (stage 2):
        self.sites: dict | None = None
        self.signed: dict = {}
        self.ccfg: ctrl.CGMQConfig | None = None
        self.budget_bop: float | None = None
        self.fp32_bop: float | None = None
        # host-transfer ledger: run_stage performs exactly one per eval window
        self.host_syncs = 0
        self._jitted: dict = {}

    # ---- binding / state construction ------------------------------------
    def bind_sites(self, sites: dict, signed: dict):
        self.sites = sites
        self.signed = signed
        self.fp32_bop = bop_lib.fp32_bop(sites)

    def bind_controller(self, ccfg: ctrl.CGMQConfig, budget_bop: float):
        assert ccfg.check_every, "resolve check_every before binding"
        self.ccfg = ccfg
        self.budget_bop = budget_bop

    @staticmethod
    def _own(tree):
        """Materialized copy: epoch calls DONATE the state, so the engine
        must never put caller-owned buffers (e.g. a shared PretrainedBundle's
        params/gates) into the carry."""
        return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)

    def init_fp_state(self, params, *, seed: int = 0) -> TrainState:
        """Stage-1 state: no sites exist yet, so betas/probes are empty."""
        params = self._own(params)
        return TrainState(
            params=params, betas={}, opt=self._adam_init((params, {})),
            cgmq=None, probes={}, rng=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
        )

    def init_quant_state(self, params, betas, gates, probes, *,
                         seed: int = 0) -> TrainState:
        """Stage-3/4 state (fresh optimizer + controller, per paper §4.2)."""
        assert self.sites is not None, "bind_sites first"
        params, betas, gates, probes = self._own((params, betas, gates, probes))
        return TrainState(
            params=params, betas=betas,
            opt=self._adam_init((params, betas)),
            cgmq=ctrl.init_state(gates, self.sites),
            probes=probes, rng=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
        )

    def shard_state(self, state: TrainState) -> TrainState:
        """Replicate the state across the plan's mesh (data-parallel mode)."""
        if self.plan is None:
            return state
        return jax.tree.map(
            lambda x: jax.device_put(x, self.plan.replicated(x)), state)

    # ---- step / epoch builders -------------------------------------------
    def _make_step(self, stage: str):
        assert stage in STAGES, stage
        use_quant = stage != "fp"
        use_ctrl = stage == "cgmq"

        def step(state: TrainState, x, y, w):
            def _loss(pbp):
                p, b, pr = pbp
                if use_quant:
                    qc = QuantContext(
                        mode="train", cfg=self.qcfg, gates=state.cgmq.gates,
                        ranges=merge_ranges(b, self.signed), probes=pr,
                    )
                else:
                    qc = QuantContext(mode="off")
                logits = self.forward(qc, p, x)
                loss = masked_mean(self.loss_fn(logits, y), w)
                return loss, (qc.act_stats, qc.weight_stats)

            (loss, (astats, wstats)), grads = jax.value_and_grad(
                _loss, has_aux=True
            )((state.params, state.betas, state.probes))
            gp, gb, gprobe = grads
            upd, opt = self._adam_update(
                (gp, gb), state.opt, (state.params, state.betas))
            params, betas = apply_updates((state.params, state.betas), upd)
            cgmq = state.cgmq
            if use_ctrl:
                cgmq = ctrl.controller_update(
                    state.cgmq, self.ccfg, self.sites, gprobe, wstats, astats,
                    self.budget_bop,
                )
            new = TrainState(
                params=params, betas=betas, opt=opt, cgmq=cgmq,
                probes=state.probes, rng=state.rng, step=state.step + 1,
            )
            return new, loss, jnp.sum(w)

        return step

    def _make_epoch(self, stage: str):
        step = self._make_step(stage)

        def epoch(state: TrainState, xs, ys):
            bx, by, bw, rng = stage_epoch(
                state.rng, xs, ys, self.ecfg.batch_size, plan=self.plan)
            state = dataclasses.replace(state, rng=rng)

            def body(carry, batch):
                st, lsum, wsum = carry
                x, y, w = batch
                st, loss, bws = step(st, x, y, w)
                return (st, lsum + loss * bws, wsum + bws), None

            zero = jnp.zeros((), jnp.float32)
            (state, lsum, wsum), _ = jax.lax.scan(
                body, (state, zero, zero), (bx, by, bw))
            return state, self._epoch_metrics(stage, state,
                                              lsum / jnp.maximum(wsum, 1.0))

        return epoch

    def _epoch_metrics(self, stage, state, loss):
        m = {"loss": loss}
        if stage == "cgmq":
            m["bop"] = state.cgmq.bop
            m["sat"] = state.cgmq.sat
        return m

    def _jit(self, key, builder, **kw):
        if key not in self._jitted:
            self._jitted[key] = jax.jit(builder(), **kw)
        return self._jitted[key]

    def _scan_epoch_fn(self, stage):
        return self._jit(("epoch", stage), lambda: self._make_epoch(stage),
                         donate_argnums=(0,))

    def _stage_fn(self):
        b = self.ecfg.batch_size
        return self._jit(
            ("stage",),
            lambda: (lambda rng, xs, ys:
                     stage_epoch(rng, xs, ys, b, plan=self.plan)))

    def _step_fn(self, stage):
        return self._jit(("step", stage), lambda: self._make_step(stage),
                         donate_argnums=(0,))

    def _python_epoch(self, stage, state, xs, ys):
        """Per-batch dispatch reference: identical staging + step functions,
        so the trajectory matches the scan epoch; only dispatch differs."""
        bx, by, bw, rng = self._stage_fn()(state.rng, xs, ys)
        state = dataclasses.replace(state, rng=rng)
        step = self._step_fn(stage)
        lsum = jnp.zeros((), jnp.float32)
        wsum = jnp.zeros((), jnp.float32)
        for i in range(bx.shape[0]):
            state, loss, bws = step(state, bx[i], by[i], bw[i])
            lsum = lsum + loss * bws
            wsum = wsum + bws
        return state, self._epoch_metrics(stage, state,
                                          lsum / jnp.maximum(wsum, 1.0))

    # ---- batched eval -----------------------------------------------------
    def _make_eval(self, quant: bool):
        def ev(params, betas, gates, xs, ys):
            bx, by, bw, _ = stage_epoch(None, xs, ys, self.ecfg.batch_size,
                                        plan=self.plan)

            def body(carry, batch):
                x, y, w = batch
                if quant:
                    qc = QuantContext(
                        mode="train", cfg=self.qcfg, gates=gates,
                        ranges=merge_ranges(betas, self.signed), probes={},
                    )
                else:
                    qc = QuantContext(mode="off")
                logits = self.forward(qc, params, x)
                hit = jnp.sum(
                    (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32) * w)
                return (carry[0] + hit, carry[1] + jnp.sum(w)), None

            zero = jnp.zeros((), jnp.float32)
            (hits, tot), _ = jax.lax.scan(body, (zero, zero), (bx, by, bw))
            return hits / jnp.maximum(tot, 1.0)

        return ev

    def eval_device(self, params, data, *, betas=None, gates=None,
                    quant: bool = False):
        """Batched test-set accuracy as a DEVICE scalar (no host sync) — the
        full-test-set single forward of the seed OOMed beyond toy scale."""
        fn = self._jit(("eval", quant), lambda: self._make_eval(quant))
        return fn(params, betas if betas is not None else {},
                  gates if gates is not None else {}, *data)

    def eval_accuracy(self, params, data, *, betas=None, gates=None,
                      quant: bool = False) -> float:
        return float(self._sync(self.eval_device(
            params, data, betas=betas, gates=gates, quant=quant)))

    # ---- outer loop --------------------------------------------------------
    def _sync(self, tree):
        """The engine's ONLY host-transfer point."""
        self.host_syncs += 1
        return jax.device_get(tree)

    def run_stage(self, state: TrainState, stage: str, train_data, epochs: int,
                  *, eval_data=None, label: str | None = None, ckpt=None,
                  ckpt_every: int = 0, start_epoch: int = 0):
        """Run ``epochs`` epochs of ``stage``; one host sync per eval window.

        Returns ``(state, history)`` where history has one entry per window.
        Windows are aligned to absolute ``eval_every`` boundaries so a run
        resumed from ``start_epoch`` replays the same sync/checkpoint points.
        """
        xs, ys = train_data
        label = label or stage
        log = self.ecfg.log
        history: list[dict] = []
        t0 = time.time()
        saving = ckpt is not None and ckpt_every
        e = start_epoch
        while e < epochs:
            # dispatch up to the next eval OR checkpoint boundary (a ckpt
            # cadence finer than the eval window is honored; saving moves
            # arrays to host anyway, but metrics sync only at eval windows)
            nxt = e + min(self.ecfg.eval_every - (e % self.ecfg.eval_every),
                          epochs - e)
            if saving:
                nxt = min(nxt, e + ckpt_every - (e % ckpt_every))
            while e < nxt:
                if self.ecfg.loop == "scan":
                    state, metrics = self._scan_epoch_fn(stage)(state, xs, ys)
                else:
                    state, metrics = self._python_epoch(stage, state, xs, ys)
                e += 1
            if e % self.ecfg.eval_every == 0 or e == epochs:
                payload = dict(metrics)
                if eval_data is not None:
                    payload["acc"] = self.eval_device(
                        state.params, eval_data, betas=state.betas,
                        gates=None if stage == "fp" else state.cgmq.gates,
                        quant=stage != "fp")
                host = self._sync(payload)  # ONE transfer per eval window
                entry: dict[str, Any] = {"epoch": e,
                                         "loss": float(host["loss"])}
                msg = f"[{label}] epoch {e} loss {entry['loss']:.4f}"
                if "acc" in host:
                    entry["acc"] = float(host["acc"])
                    msg += f" acc {entry['acc']:.4f}"
                if stage == "cgmq":
                    entry["rbop"] = float(host["bop"]) / self.fp32_bop
                    entry["sat"] = bool(host["sat"])
                    msg += f" rbop {entry['rbop']*100:.3f}% sat={entry['sat']}"
                history.append(entry)
                log(msg + f" ({time.time()-t0:.1f}s)")
            if saving and (e % ckpt_every == 0 or e == epochs):
                # intermediate saves are async (Checkpointer snapshots to
                # host before returning, so the donated state can keep
                # mutating); the final save blocks so it survives process
                # exit
                save_state(ckpt, e, state,
                           extra={"stage": stage, "epoch": e},
                           blocking=e == epochs)
        return state, history


# ---------------------------------------------------------------------------
# Full-state checkpointing (gates + controller flags + RNG included)
# ---------------------------------------------------------------------------


def save_state(ckpt, step: int, state: TrainState, *, extra: dict | None = None,
               blocking: bool = True):
    """Persist the whole TrainState at ``step`` (epoch for the pipeline)."""
    ckpt.save(step, state, blocking=blocking, extra=extra)


def restore_state(ckpt, template: TrainState, *, step: int | None = None,
                  shardings=None):
    """Restore a TrainState saved by ``save_state``; returns
    ``(state, step, extra)``. ``template`` provides structure/shapes only."""
    return ckpt.restore(jax.eval_shape(lambda: template), step=step,
                        shardings=shardings)
