"""Unified device-resident CGMQ training engine (DESIGN.md §9).

One ``TrainState`` pytree and one ``TrainEngine`` drive every stage of the
paper's four-stage pipeline as well as the LLM-scale steps in
``launch/steps.py``. Epochs run as a jitted ``lax.scan`` over pre-staged,
pre-permuted device batches; the host syncs once per eval window.
"""

from .engine import (
    EngineConfig,
    TrainEngine,
    masked_accuracy,
    masked_mean,
    per_example_xent,
    restore_state,
    save_state,
    stage_epoch,
)
from .state import TrainState

__all__ = [
    "EngineConfig",
    "TrainEngine",
    "TrainState",
    "masked_accuracy",
    "masked_mean",
    "per_example_xent",
    "restore_state",
    "save_state",
    "stage_epoch",
]
