"""Deterministic synthetic datasets (no network access in this environment).

Two generators:

  * ``digits(...)`` — a procedurally rendered 28x28 10-class digit set used as
    the MNIST stand-in for the paper reproduction. Digits are drawn from
    seven-segment stroke skeletons with per-sample random affine jitter,
    stroke thickness, and Gaussian pixel noise. The distribution is fixed by
    the seed, so experiments are exactly reproducible. (MNIST itself is not
    bundled offline; DESIGN.md §7 documents that the paper's *claims* —
    constraint guarantee and parity with the FP32 baseline — are validated
    relative to an FP32 model on identical data.)

  * ``lm_tokens(...)`` — an infinite deterministic LM token stream with a
    learnable affine-Markov structure, used by the LLM-scale CGMQ examples
    and the training-loop tests. Cross-entropy has a known floor (the noise
    rate), so learning progress is verifiable.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Synthetic digits
# ---------------------------------------------------------------------------

# Seven-segment endpoints on the unit square.
_TL, _TR = (0.25, 0.18), (0.75, 0.18)
_ML, _MR = (0.25, 0.50), (0.75, 0.50)
_BL, _BR = (0.25, 0.82), (0.75, 0.82)
_SEGS = {
    "A": (_TL, _TR),
    "B": (_TR, _MR),
    "C": (_MR, _BR),
    "D": (_BL, _BR),
    "E": (_ML, _BL),
    "F": (_TL, _ML),
    "G": (_ML, _MR),
}
_DIGIT_SEGS = {
    0: "ABCDEF",
    1: "BC",
    2: "ABGED",
    3: "ABGCD",
    4: "FGBC",
    5: "AFGCD",
    6: "AFGEDC",
    7: "ABC",
    8: "ABCDEFG",
    9: "ABCDFG",
}
IMG = 28


def _render(label: int, rng: np.random.Generator) -> np.ndarray:
    """Render one digit with random affine + thickness + noise."""
    segs = np.array([[_SEGS[s][0], _SEGS[s][1]] for s in _DIGIT_SEGS[label]])
    pts = segs.reshape(-1, 2) - 0.5
    theta = rng.uniform(-0.25, 0.25)
    scale = rng.uniform(0.8, 1.15)
    shear = rng.uniform(-0.15, 0.15)
    rot = np.array(
        [[np.cos(theta), -np.sin(theta)], [np.sin(theta), np.cos(theta)]]
    )
    aff = rot @ np.array([[1.0, shear], [0.0, 1.0]]) * scale
    pts = pts @ aff.T + 0.5 + rng.uniform(-0.08, 0.08, size=(1, 2))
    segs = pts.reshape(-1, 2, 2)

    ys, xs = np.mgrid[0:IMG, 0:IMG]
    grid = np.stack([xs, ys], axis=-1).reshape(-1, 2) / (IMG - 1.0)

    a = segs[:, 0][:, None, :]          # (S,1,2)
    b = segs[:, 1][:, None, :]
    ab = b - a
    t = ((grid[None] - a) * ab).sum(-1) / np.maximum((ab * ab).sum(-1), 1e-9)
    t = np.clip(t, 0.0, 1.0)[..., None]
    proj = a + t * ab
    d = np.linalg.norm(grid[None] - proj, axis=-1).min(axis=0)  # (P,)

    sigma = rng.uniform(0.018, 0.032)
    img = np.exp(-0.5 * (d / sigma) ** 2).reshape(IMG, IMG)
    img = img * rng.uniform(0.85, 1.0)
    img += rng.normal(0.0, 0.035, size=img.shape)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def digits(n: int, *, split: str = "train", seed: int = 0):
    """Return (images (n,28,28,1) float32 in [0,1], labels (n,) int32)."""
    base = {"train": 0x5EED0000, "test": 0x7E570000}[split] + seed
    imgs = np.empty((n, IMG, IMG, 1), np.float32)
    labels = np.empty((n,), np.int32)
    for i in range(n):
        rng = np.random.default_rng(base + i)
        lab = i % 10
        labels[i] = lab
        imgs[i, :, :, 0] = _render(lab, rng)
    # normalize to mean 0.5 / std 0.5 as the paper does for MNIST
    imgs = (imgs - 0.5) / 0.5
    return imgs, labels


# ---------------------------------------------------------------------------
# Synthetic LM token stream
# ---------------------------------------------------------------------------


def lm_tokens(
    n_seqs: int,
    seq_len: int,
    vocab: int,
    *,
    seed: int = 0,
    noise: float = 0.1,
):
    """Deterministic next-token-predictable sequences.

    ``x[t+1] = (a * x[t] + b) mod vocab`` with probability ``1 - noise``,
    uniform otherwise; (a, b) fixed per stream. Returns int32 (n, seq_len+1)
    so callers can split into inputs/targets.
    """
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, max(3, vocab - 1))) | 1  # odd -> full-period-ish
    b = int(rng.integers(1, vocab))
    out = np.empty((n_seqs, seq_len + 1), np.int64)
    x = rng.integers(0, vocab, size=(n_seqs,))
    out[:, 0] = x
    for t in range(1, seq_len + 1):
        nxt = (a * out[:, t - 1] + b) % vocab
        flip = rng.random(n_seqs) < noise
        nxt = np.where(flip, rng.integers(0, vocab, size=(n_seqs,)), nxt)
        out[:, t] = nxt
    return out.astype(np.int32)


def batches(arrays, batch_size: int, *, seed: int = 0, epochs: int = 1):
    """Shuffled minibatch iterator over aligned arrays."""
    n = arrays[0].shape[0]
    for e in range(epochs):
        rng = np.random.default_rng(seed + e)
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i : i + batch_size]
            yield tuple(a[idx] for a in arrays)
