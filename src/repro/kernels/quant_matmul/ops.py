"""Jit'd wrappers for the bit-width-dispatched fused dequant GEMM.

The kernels compute the complete affine dequant
``y = scale * (x @ codes) + bias * rowsum(x)`` (== ``x @ (codes*scale+bias)``
exactly) in their epilogue; these wrappers flatten the leading activation
dims, compute ``rowsum(x)`` (one VPU reduction, fused into the x load by
XLA) and pick the Pallas kernel or the pure-jnp oracle. See quant_matmul.py
for the kernel contracts.

``quant_matmul_op`` is the raw int8 entry point (unchanged: the oracle path
every packed configuration is gated against). ``quant_matmul_qt`` is the
serving dispatcher: it takes a ``quant.QuantizedTensor`` and selects the
int8 or packed-sub-byte kernel from its static storage class — the one
place bit-width dispatch happens, for every model layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant_matmul import quant_matmul_packed_pallas, quant_matmul_pallas
from .ref import quant_matmul_packed_ref, quant_matmul_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ (codes*scale + bias); x: (..., K), codes: (K, N) int8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1]).astype(jnp.float32)
    if use_pallas:
        rowsum = jnp.sum(x2, axis=1)
        y = quant_matmul_pallas(x2, codes, scale, bias, rowsum,
                                interpret=interpret)
    else:
        y = quant_matmul_ref(x2, codes, scale, bias)
    return y.reshape(orig[:-1] + (codes.shape[1],))


@functools.partial(jax.jit,
                   static_argnames=("bits", "k", "use_pallas", "interpret"))
def quant_matmul_packed_op(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bits: int,
    k: int,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed twin of ``quant_matmul_op``: packed (ceil(K/per), N) uint8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1]).astype(jnp.float32)
    if use_pallas:
        rowsum = jnp.sum(x2, axis=1)
        y = quant_matmul_packed_pallas(x2, packed, scale, bias, rowsum,
                                       bits=bits, k=k, interpret=interpret)
    else:
        y = quant_matmul_packed_ref(x2, packed, scale, bias, bits=bits, k=k)
    return y.reshape(orig[:-1] + (packed.shape[-1],))


def quant_matmul_qt(x, qt, *, use_pallas: bool = True,
                    interpret: bool = True) -> jnp.ndarray:
    """Serving dispatcher: ``y = x @ dequant(qt)`` off a QuantizedTensor.

    Static dispatch on ``qt.storage_bits`` (pytree aux data, so each jit /
    scan specialization compiles exactly one kernel per site): 8-bit codes
    take the int8 kernel unchanged; 2/4-bit packed codes take the fused
    unpack+dequant kernel. ``scale``/``bias`` arrive per-tensor (scalar-ish)
    or per-channel; the kernel contract is per-output-channel (N,) vectors.
    """
    n = qt.codes.shape[-1]
    scale = jnp.broadcast_to(qt.scale.reshape(-1), (n,))
    bias = jnp.broadcast_to(qt.bias.reshape(-1), (n,))
    if qt.storage_bits == 8:
        return quant_matmul_op(x, qt.codes, scale, bias,
                               use_pallas=use_pallas, interpret=interpret)
    return quant_matmul_packed_op(
        x, qt.codes, scale, bias, bits=qt.storage_bits, k=qt.k,
        use_pallas=use_pallas, interpret=interpret)
