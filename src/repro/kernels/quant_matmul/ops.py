"""Jit'd wrapper for the fused dequant GEMM.

The kernel computes the complete affine dequant
``y = scale * (x @ codes) + bias * rowsum(x)`` (== ``x @ (codes*scale+bias)``
exactly) in its epilogue; this wrapper flattens the leading activation dims,
computes ``rowsum(x)`` (one VPU reduction, fused into the x load by XLA) and
picks the Pallas kernel or the pure-jnp oracle. See quant_matmul.py for the
kernel contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant_matmul import quant_matmul_pallas
from .ref import quant_matmul_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ (codes*scale + bias); x: (..., K), codes: (K, N) int8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1]).astype(jnp.float32)
    if use_pallas:
        rowsum = jnp.sum(x2, axis=1)
        y = quant_matmul_pallas(x2, codes, scale, bias, rowsum,
                                interpret=interpret)
    else:
        y = quant_matmul_ref(x2, codes, scale, bias)
    return y.reshape(orig[:-1] + (codes.shape[1],))
