"""Jit'd wrappers for the bit-width-dispatched fused dequant GEMM.

The kernels compute the complete affine dequant
``y = scale * (x @ codes) + bias * rowsum(x)`` (== ``x @ (codes*scale+bias)``
exactly) in their epilogue; these wrappers flatten the leading activation
dims, compute ``rowsum(x)`` (one VPU reduction, fused into the x load by
XLA) and pick the Pallas kernel or the pure-jnp oracle. See quant_matmul.py
for the kernel contracts.

``quant_matmul_op`` is the raw int8 entry point (unchanged: the oracle path
every packed configuration is gated against). ``quant_matmul_qt`` is the
serving dispatcher: it takes a ``quant.QuantizedTensor`` and selects the
int8 or packed-sub-byte kernel from its static storage class — the one
place bit-width dispatch happens, for every model layer. With an
``act_spec`` (a ``quant.ActQuantSpec``, DESIGN.md §16) it instead
quantizes the incoming activation tile on the fly to int8 codes and
dispatches the INTEGER kernels: the weight grid's per-channel
``(scale, bias)`` and the activation grid's per-tensor ``(sx, bx)`` fold
into ``eff_scale``/``eff_bias``/``const`` exactly (see quant_matmul.py),
so the integer path equals ``fake_quant(x) @ dequant(qt)`` up to fp32
epilogue rounding — the requantization tolerance the serving oracle gate
documents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantizer import quantize_to_int

from .quant_matmul import (int_matmul_packed_pallas, int_matmul_pallas,
                           quant_matmul_packed_pallas, quant_matmul_pallas)
from .ref import (int_matmul_packed_ref, int_matmul_ref,
                  quant_matmul_packed_ref, quant_matmul_ref)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ (codes*scale + bias); x: (..., K), codes: (K, N) int8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1]).astype(jnp.float32)
    if use_pallas:
        rowsum = jnp.sum(x2, axis=1)
        y = quant_matmul_pallas(x2, codes, scale, bias, rowsum,
                                interpret=interpret)
    else:
        y = quant_matmul_ref(x2, codes, scale, bias)
    return y.reshape(orig[:-1] + (codes.shape[1],))


@functools.partial(jax.jit,
                   static_argnames=("bits", "k", "use_pallas", "interpret"))
def quant_matmul_packed_op(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    bits: int,
    k: int,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed twin of ``quant_matmul_op``: packed (ceil(K/per), N) uint8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1]).astype(jnp.float32)
    if use_pallas:
        rowsum = jnp.sum(x2, axis=1)
        y = quant_matmul_packed_pallas(x2, packed, scale, bias, rowsum,
                                       bits=bits, k=k, interpret=interpret)
    else:
        y = quant_matmul_packed_ref(x2, packed, scale, bias, bits=bits, k=k)
    return y.reshape(orig[:-1] + (packed.shape[-1],))


@functools.partial(jax.jit,
                   static_argnames=("act_bits", "act_signed", "use_pallas",
                                    "interpret"))
def int_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    colsum: jnp.ndarray,
    act_beta: jnp.ndarray,
    *,
    act_bits: int,
    act_signed: bool = True,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Integer entry point: quantize ``x`` per-tensor, int8×int8 GEMM.

    ``x``: (..., K) float; ``codes``: (K, N) int8 weight codes with their
    per-channel affine ``scale``/``bias`` (N,) and precomputed K-sum
    ``colsum`` (N,) int32. Returns (..., N) fp32 equal (exactly, in exact
    arithmetic) to ``fake_quant(x) @ (codes*scale + bias)``.
    """
    orig = x.shape
    k = orig[-1]
    x2 = x.reshape(-1, k)
    qx, sx, bx = quantize_to_int(x2, act_bits, act_beta, act_signed)
    rowsum = jnp.sum(qx.astype(jnp.int32), axis=1).astype(jnp.float32)
    eff_scale = sx * scale
    eff_bias = sx * bias
    const = bx * (scale * colsum.astype(jnp.float32) + k * bias)
    if use_pallas:
        y = int_matmul_pallas(qx, codes, eff_scale, eff_bias, rowsum, const,
                              interpret=interpret)
    else:
        y = int_matmul_ref(qx, codes, eff_scale, eff_bias, rowsum, const)
    return y.reshape(orig[:-1] + (codes.shape[-1],))


@functools.partial(jax.jit,
                   static_argnames=("bits", "k", "act_bits", "act_signed",
                                    "use_pallas", "interpret"))
def int_matmul_packed_op(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    colsum: jnp.ndarray,
    act_beta: jnp.ndarray,
    *,
    bits: int,
    k: int,
    act_bits: int,
    act_signed: bool = True,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed twin of ``int_matmul_op``: sub-byte weight codes decoded to
    int8 in-kernel, same on-the-fly activation quantization."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    qx, sx, bx = quantize_to_int(x2, act_bits, act_beta, act_signed)
    rowsum = jnp.sum(qx.astype(jnp.int32), axis=1).astype(jnp.float32)
    eff_scale = sx * scale
    eff_bias = sx * bias
    const = bx * (scale * colsum.astype(jnp.float32) + k * bias)
    if use_pallas:
        y = int_matmul_packed_pallas(qx, packed, eff_scale, eff_bias, rowsum,
                                     const, bits=bits, k=k,
                                     interpret=interpret)
    else:
        y = int_matmul_packed_ref(qx, packed, eff_scale, eff_bias, rowsum,
                                  const, bits=bits, k=k)
    return y.reshape(orig[:-1] + (packed.shape[-1],))


def quant_matmul_qt(x, qt, *, act_spec=None, use_pallas: bool = True,
                    interpret: bool = True) -> jnp.ndarray:
    """Serving dispatcher: ``y = x @ dequant(qt)`` off a QuantizedTensor.

    Static dispatch on ``qt.storage_bits`` (pytree aux data, so each jit /
    scan specialization compiles exactly one kernel per site): 8-bit codes
    take the int8 kernel unchanged; 2/4-bit packed codes take the fused
    unpack+dequant kernel. ``scale``/``bias`` arrive per-tensor (scalar-ish)
    or per-channel; the kernel contract is per-output-channel (N,) vectors.

    With ``act_spec`` (per-tensor ``quant.ActQuantSpec``) the activation is
    quantized on the fly and the int8×int8 integer-accumulation kernels run
    instead — fully-integer MACs for both storage classes (DESIGN.md §16).
    """
    n = qt.codes.shape[-1]
    scale = jnp.broadcast_to(qt.scale.reshape(-1), (n,))
    bias = jnp.broadcast_to(qt.bias.reshape(-1), (n,))
    if act_spec is not None:
        colsum = jnp.broadcast_to(qt.code_colsum().reshape(-1), (n,))
        act_beta = jnp.asarray(act_spec.beta, jnp.float32).reshape(())
        if qt.storage_bits == 8:
            return int_matmul_op(
                x, qt.codes, scale, bias, colsum, act_beta,
                act_bits=act_spec.bits, act_signed=act_spec.signed,
                use_pallas=use_pallas, interpret=interpret)
        return int_matmul_packed_op(
            x, qt.codes, scale, bias, colsum, act_beta,
            bits=qt.storage_bits, k=qt.k, act_bits=act_spec.bits,
            act_signed=act_spec.signed, use_pallas=use_pallas,
            interpret=interpret)
    if qt.storage_bits == 8:
        return quant_matmul_op(x, qt.codes, scale, bias,
                               use_pallas=use_pallas, interpret=interpret)
    return quant_matmul_packed_op(
        x, qt.codes, scale, bias, bits=qt.storage_bits, k=qt.k,
        use_pallas=use_pallas, interpret=interpret)
