"""Jit'd wrapper for the fused dequant GEMM.

Folds the per-channel bias into the GEMM exactly by augmenting ``x`` with a
ones column and ``codes`` with one extra row holding ``bias / scale``:

    y = scale * ([x, 1] @ [[codes], [bias/scale]])
      = scale * (x @ codes) + bias * rowsum-of-ones = x @ (codes*scale + bias)

(The extra row is fp-valued; it rides in a separate fp32 row tensor so codes
stay int8 in HBM — implemented by augmenting AFTER dequant-free accumulation
would lose exactness, so we simply add the rank-1 term outside the kernel:
``y += rowsum(x) ⊗ bias``, one cheap VPU pass.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .quant_matmul import quant_matmul_pallas
from .ref import quant_matmul_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ (codes*scale + bias); x: (..., K), codes: (K, N) int8."""
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    if use_pallas:
        y = quant_matmul_pallas(x2, codes, scale, bias, interpret=interpret)
        # exact rank-1 bias term (see module docstring)
        y = y + jnp.sum(x2.astype(jnp.float32), axis=1, keepdims=True) * bias[None, :]
    else:
        y = quant_matmul_ref(x2, codes, scale, bias)
    return y.reshape(orig[:-1] + (codes.shape[1],))
