"""Pallas TPU kernel: fused int8-dequant GEMM (CGMQ serving path).

Weights exported by CGMQ (core.quantizer.quantize_to_int) are stored as int8
codes with per-output-channel affine terms ``w = codes * scale + bias``.
Serving wants ``y = x @ w`` without materializing the fp16/fp32 weight in
HBM — the Marlin/AWQ idiom (taxonomy B.12) adapted to the MXU:

    y[m, n] = scale[n] * (x @ codes)[m, n] + bias[n] * rowsum(x)[m]

The first term comes from MXU matmuls over tiles resident in VMEM; the
rank-1 bias term reuses ``rowsum(x)``, a single cheap VPU reduction over the
activations, which the wrapper (ops.py) computes once and feeds in as a
fourth operand. Both terms are applied in the epilogue on the final K step,
while the fp32 output tile is still in VMEM — the full affine dequant costs
zero extra passes over the (M, N) output in HBM. int8 codes halve (vs bf16)
or quarter (vs fp32) the weight bytes streamed from HBM — decode is
weight-bandwidth-bound, so roofline time drops proportionally.

Tiling: grid (M/bm, N/bn, K/bk); accumulation in the fp32 output tile across
the K grid dimension (output revisiting), 128-aligned tiles for the MXU.

Kernel contract (DESIGN.md §8):
    x:      (M, K)  fp32/bf16 activations
    codes:  (K, N)  int8 centered codes
    scale:  (N,)    fp32 per-output-channel scale
    bias:   (N,)    fp32 per-output-channel offset (asymmetric / unsigned
                    grids; exactly zero only for symmetric signed grids)
    rowsum: (M,)    fp32 ``sum_k x[m, k]``
    out:    (M, N)  fp32 ``x @ (codes * scale + bias)``, exact in fp32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, s_ref, b_ref, r_ref, o_ref, *, k_steps: int,
            k_total: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    codes = c_ref[...].astype(jnp.float32)       # (bk, bn)
    if k_total % bk:
        # Ragged K: the final block reads past K; zero the out-of-bounds
        # tail so it contributes nothing. (Ragged M/N only pollute cropped
        # output padding; ragged K would corrupt real accumulations.)
        # 2-D iota: Pallas-TPU rejects 1-D jnp.arange at lowering time.
        k0 = pl.program_id(2) * bk
        kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
        kc = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 0) + k0
        x = jnp.where(kx < k_total, x, 0.0)
        codes = jnp.where(kc < k_total, codes, 0.0)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # Affine dequant on the resident output tile:
        #   y = scale * (x @ codes) + bias * rowsum(x)
        o_ref[...] = (
            o_ref[...] * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
        )


def quant_matmul_pallas(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); codes: (K, N) int8; scale/bias: (N,); rowsum: (M,).

    Returns (M, N) fp32 ``x @ (codes * scale + bias)`` — the complete affine
    epilogue runs inside the kernel (see module docstring for the contract).
    """
    m, k = x.shape
    _, n = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    k_steps = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, codes, scale, bias, rowsum)
