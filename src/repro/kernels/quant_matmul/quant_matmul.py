"""Pallas TPU kernels: bit-width-dispatched fused dequant GEMM (CGMQ
serving path).

Weights exported by CGMQ (``quant.QuantizedTensor``) are stored as integer
codes with per-output-channel affine terms ``w = codes * scale + bias`` —
int8 words for the 8-bit storage class, bit-PACKED uint8 words for the 2/4-
bit classes (``quant.pack``: ``8 // bits`` codes per byte along K). Serving
wants ``y = x @ w`` without materializing the fp16/fp32 weight in HBM — the
Marlin/AWQ idiom (taxonomy B.12) adapted to the MXU:

    y[m, n] = scale[n] * (x @ codes)[m, n] + bias[n] * rowsum(x)[m]

The first term comes from MXU matmuls over tiles resident in VMEM; the
rank-1 bias term reuses ``rowsum(x)``, a single cheap VPU reduction over the
activations, which the wrapper (ops.py) computes once and feeds in as a
fourth operand. Both terms are applied in the epilogue on the final K step,
while the fp32 output tile is still in VMEM — the full affine dequant costs
zero extra passes over the (M, N) output in HBM.

The PACKED variant additionally unpacks the sub-byte codes in-register
(shift/mask on the int32-widened tile, interleave, ONE dot) before the same
epilogue — the weight bytes streamed from HBM are ``K * bits / 8`` per
column, i.e. 16x fewer than fp32 at 2 bits. Decode is weight-bandwidth-
bound, so roofline decode time drops proportionally to the certified
bit-width, not to a uniform int8 floor.

Tiling: grid (M/bm, N/bn, K/bk); accumulation in the fp32 output tile across
the K grid dimension (output revisiting), 128-aligned tiles for the MXU.
For the packed kernel the K block is counted in UNPACKED columns (``bk``
must be a multiple of ``8 // bits``; the packed block is ``bk * bits / 8``
rows), so the two kernels share one grid/masking scheme.

Kernel contract (DESIGN.md §8/§11):
    x:      (M, K)  fp32/bf16 activations
    codes:  (K, N) int8 centered codes, or (ceil(K/per), N) uint8 packed
    scale:  (N,)    fp32 per-output-channel scale
    bias:   (N,)    fp32 per-output-channel offset (asymmetric / unsigned
                    grids; exactly zero only for symmetric signed grids)
    rowsum: (M,)    fp32 ``sum_k x[m, k]``
    out:    (M, N)  fp32 ``x @ (codes * scale + bias)``, exact in fp32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, s_ref, b_ref, r_ref, o_ref, *, k_steps: int,
            k_total: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    codes = c_ref[...].astype(jnp.float32)       # (bk, bn)
    if k_total % bk:
        # Ragged K: the final block reads past K; zero the out-of-bounds
        # tail so it contributes nothing. (Ragged M/N only pollute cropped
        # output padding; ragged K would corrupt real accumulations.)
        # 2-D iota: Pallas-TPU rejects 1-D jnp.arange at lowering time.
        k0 = pl.program_id(2) * bk
        kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
        kc = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 0) + k0
        x = jnp.where(kx < k_total, x, 0.0)
        codes = jnp.where(kc < k_total, codes, 0.0)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # Affine dequant on the resident output tile:
        #   y = scale * (x @ codes) + bias * rowsum(x)
        o_ref[...] = (
            o_ref[...] * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
        )


def quant_matmul_pallas(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); codes: (K, N) int8; scale/bias: (N,); rowsum: (M,).

    Returns (M, N) fp32 ``x @ (codes * scale + bias)`` — the complete affine
    epilogue runs inside the kernel (see module docstring for the contract).
    """
    m, k = x.shape
    _, n = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    k_steps = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, codes, scale, bias, rowsum)


# ---------------------------------------------------------------------------
# Packed sub-byte variant: fused unpack + dequant GEMM
# ---------------------------------------------------------------------------


def _packed_kernel(x_ref, p_ref, s_ref, b_ref, r_ref, o_ref, *, bits: int,
                   k_steps: int, k_total: int, bk: int):
    per = 8 // bits
    offset = 1 << (bits - 1)
    mask = (1 << bits) - 1

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    # Mask x columns past K: pack-padding words and ragged-K block tails
    # then multiply a zeroed activation, so garbage codes contribute nothing.
    k0 = pl.program_id(2) * bk
    kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
    x = jnp.where(kx < k_total, x, 0.0)
    p = p_ref[...].astype(jnp.int32)                  # (bk // per, bn)
    # In-register unpack: byte i holds codes i*per + j (j little-endian).
    cols = [((p >> (j * bits)) & mask) - offset for j in range(per)]
    stacked = jnp.stack(cols, axis=1)                 # (bk//per, per, bn)
    codes = stacked.reshape(bk, stacked.shape[-1]).astype(jnp.float32)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = (
            o_ref[...] * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
        )


def quant_matmul_packed_pallas(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    *,
    bits: int,
    k: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); packed: (ceil(K/per), N) uint8 sub-byte codes.

    Returns (M, N) fp32 ``x @ (unpack(packed) * scale + bias)`` with the
    unpack fused into the K loop (see module docstring). ``bits`` in {2, 4};
    ``k`` is the logical (unpacked) fan-in.
    """
    assert bits in (2, 4), bits
    per = 8 // bits
    m = x.shape[0]
    kp, n = packed.shape
    bm, bn = min(block_m, m), min(block_n, n)
    # K block in unpacked columns, forced to a whole number of packed rows.
    bkp = min(max(block_k // per, 1), kp)
    bk = bkp * per
    k_steps = pl.cdiv(kp, bkp)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_packed_kernel, bits=bits, k_steps=k_steps,
                          k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkp, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, packed, scale, bias, rowsum)
