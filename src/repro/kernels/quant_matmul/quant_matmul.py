"""Pallas TPU kernel: fused int8-dequant GEMM (CGMQ serving path).

Weights exported by CGMQ (core.quantizer.quantize_to_int) are stored as int8
codes with per-output-channel affine terms ``w = codes * scale + bias``.
Serving wants ``y = x @ w`` without materializing the fp16/fp32 weight in
HBM — the Marlin/AWQ idiom (taxonomy B.12) adapted to the MXU:

    y[m, n] = scale[n] * (x @ codes)[m, n] + bias[n] * rowsum(x)[m]

Both terms come from MXU matmuls over tiles resident in VMEM; the affine
epilogue is applied once per output tile on the final K step. int8 codes
halve (vs bf16) or quarter (vs fp32) the weight bytes streamed from HBM —
decode is weight-bandwidth-bound, so roofline time drops proportionally.

Tiling: grid (M/bm, N/bn, K/bk); accumulation in the fp32 output tile across
the K grid dimension (output revisiting), 128-aligned tiles for the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, s_ref, b_ref, o_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    codes = c_ref[...].astype(jnp.float32)       # (bk, bn)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # y = scale * acc + bias * rowsum(x_full) — rowsum accumulated into
        # the first output column? No: recompute via a second accumulator is
        # avoided by folding bias through the ones-vector trick below in ops.
        o_ref[...] = o_ref[...] * s_ref[...][None, :]


def quant_matmul_pallas(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); codes: (K, N) int8; scale/bias: (N,) -> (M, N) fp32.

    The bias term ``bias[n] * sum_k x[m, k]`` is folded in by augmenting x
    with a ones column and codes with a bias row (exact, keeps the kernel a
    pure scaled GEMM): handled in ops.py. This kernel computes
    ``scale[n] * (x @ codes)``.
    """
    m, k = x.shape
    _, n = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    k_steps = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, codes, scale, bias)
