"""Pallas TPU kernels: bit-width-dispatched fused dequant GEMM (CGMQ
serving path).

Weights exported by CGMQ (``quant.QuantizedTensor``) are stored as integer
codes with per-output-channel affine terms ``w = codes * scale + bias`` —
int8 words for the 8-bit storage class, bit-PACKED uint8 words for the 2/4-
bit classes (``quant.pack``: ``8 // bits`` codes per byte along K). Serving
wants ``y = x @ w`` without materializing the fp16/fp32 weight in HBM — the
Marlin/AWQ idiom (taxonomy B.12) adapted to the MXU:

    y[m, n] = scale[n] * (x @ codes)[m, n] + bias[n] * rowsum(x)[m]

The first term comes from MXU matmuls over tiles resident in VMEM; the
rank-1 bias term reuses ``rowsum(x)``, a single cheap VPU reduction over the
activations, which the wrapper (ops.py) computes once and feeds in as a
fourth operand. Both terms are applied in the epilogue on the final K step,
while the fp32 output tile is still in VMEM — the full affine dequant costs
zero extra passes over the (M, N) output in HBM.

The PACKED variant additionally unpacks the sub-byte codes in-register
(shift/mask on the int32-widened tile, interleave, ONE dot) before the same
epilogue — the weight bytes streamed from HBM are ``K * bits / 8`` per
column, i.e. 16x fewer than fp32 at 2 bits. Decode is weight-bandwidth-
bound, so roofline decode time drops proportionally to the certified
bit-width, not to a uniform int8 floor.

Tiling: grid (M/bm, N/bn, K/bk); accumulation in the fp32 output tile across
the K grid dimension (output revisiting), 128-aligned tiles for the MXU
picked by the shared ``layout`` helper. For the packed kernels the K block
is counted in UNPACKED columns (``bk`` is a whole number of packed rows),
so all kernels share one grid/masking scheme, and the in-register sub-byte
decode is ``layout.unpack_tile`` — repeat + shift/mask, no sublane
interleave.

Kernel contract (DESIGN.md §8/§11):
    x:      (M, K)  fp32/bf16 activations
    codes:  (K, N) int8 centered codes, or (ceil(K/per), N) uint8 packed
    scale:  (N,)    fp32 per-output-channel scale
    bias:   (N,)    fp32 per-output-channel offset (asymmetric / unsigned
                    grids; exactly zero only for symmetric signed grids)
    rowsum: (M,)    fp32 ``sum_k x[m, k]``
    out:    (M, N)  fp32 ``x @ (codes * scale + bias)``, exact in fp32

The INTEGER variants (`int_matmul_pallas` / `int_matmul_packed_pallas`,
DESIGN.md §16) take int8 activation CODES instead of float activations and
accumulate on the MXU in **int32** (an int32 VMEM scratch tile persists
across the sequential K grid steps). The affine epilogue is the same rank-1
structure with the activation's per-tensor affine folded in: with
``x = qx*sx + bx`` and ``w[k, n] = codes[k, n]*scale[n] + bias[n]``,

    y[m, n] = (sx*scale[n]) * acc[m, n]            # int32 MXU accumulator
            + (sx*bias[n])  * rowsum(qx)[m]        # rank-1, like the fp path
            + bx * (scale[n]*colsum(codes)[n] + K*bias[n])   # constant (N,)

so the wrapper passes ``eff_scale = sx*scale``, ``eff_bias = sx*bias``,
integer ``rowsum(qx)`` and the precomputed ``const`` vector (``colsum`` is
exported once with the weights — recomputing it per decode tick would cost
a second GEMM-sized reduction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .layout import gemm_blocks, packed_blocks, unpack_tile


def _kernel(x_ref, c_ref, s_ref, b_ref, r_ref, o_ref, *, k_steps: int,
            k_total: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    codes = c_ref[...].astype(jnp.float32)       # (bk, bn)
    if k_total % bk:
        # Ragged K: the final block reads past K; zero the out-of-bounds
        # tail so it contributes nothing. (Ragged M/N only pollute cropped
        # output padding; ragged K would corrupt real accumulations.)
        # 2-D iota: Pallas-TPU rejects 1-D jnp.arange at lowering time.
        k0 = pl.program_id(2) * bk
        kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
        kc = jax.lax.broadcasted_iota(jnp.int32, codes.shape, 0) + k0
        x = jnp.where(kx < k_total, x, 0.0)
        codes = jnp.where(kc < k_total, codes, 0.0)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # Affine dequant on the resident output tile:
        #   y = scale * (x @ codes) + bias * rowsum(x)
        o_ref[...] = (
            o_ref[...] * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
        )


def quant_matmul_pallas(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); codes: (K, N) int8; scale/bias: (N,); rowsum: (M,).

    Returns (M, N) fp32 ``x @ (codes * scale + bias)`` — the complete affine
    epilogue runs inside the kernel (see module docstring for the contract).
    """
    m, k = x.shape
    _, n = codes.shape
    bm, bn, bk = gemm_blocks(m, n, k, block_m=block_m, block_n=block_n,
                             block_k=block_k)
    k_steps = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, codes, scale, bias, rowsum)


# ---------------------------------------------------------------------------
# Packed sub-byte variant: fused unpack + dequant GEMM
# ---------------------------------------------------------------------------


def _packed_kernel(x_ref, p_ref, s_ref, b_ref, r_ref, o_ref, *, bits: int,
                   k_steps: int, k_total: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    # Mask x columns past K: pack-padding words and ragged-K block tails
    # then multiply a zeroed activation, so garbage codes contribute nothing.
    k0 = pl.program_id(2) * bk
    kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
    x = jnp.where(kx < k_total, x, 0.0)
    p = p_ref[...].astype(jnp.int32)                  # (bk // per, bn)
    codes = unpack_tile(p, bits).astype(jnp.float32)  # (bk, bn)
    o_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = (
            o_ref[...] * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
        )


def quant_matmul_packed_pallas(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    *,
    bits: int,
    k: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, K); packed: (ceil(K/per), N) uint8 sub-byte codes.

    Returns (M, N) fp32 ``x @ (unpack(packed) * scale + bias)`` with the
    unpack fused into the K loop (see module docstring). ``bits`` in {2, 4};
    ``k`` is the logical (unpacked) fan-in.
    """
    assert bits in (2, 4), bits
    per = 8 // bits
    m = x.shape[0]
    kp, n = packed.shape
    # K block in unpacked columns, forced to a whole number of packed rows.
    bm, bn, bkp, bk = packed_blocks(m, n, kp, per, block_m=block_m,
                                    block_n=block_n, block_k=block_k)
    k_steps = pl.cdiv(kp, bkp)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_packed_kernel, bits=bits, k_steps=k_steps,
                          k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkp, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, packed, scale, bias, rowsum)


# ---------------------------------------------------------------------------
# Integer variants: int8 x int8 GEMM with int32 MXU accumulation (§16)
# ---------------------------------------------------------------------------


def _int_kernel(x_ref, c_ref, s_ref, b_ref, r_ref, cst_ref, o_ref, acc_ref,
                *, k_steps: int, k_total: int, bk: int):
    # acc_ref: int32 VMEM scratch — TPU grids execute sequentially per core,
    # so the accumulator persists across the K grid steps of one (i, j) tile.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                    # (bm, bk) int8 codes
    codes = c_ref[...]                                # (bk, bn) int8 codes
    if k_total % bk:
        # Ragged K: zero the activation tail; a zeroed int8 operand makes
        # the out-of-bounds products exact zeros in the int32 accumulator.
        k0 = pl.program_id(2) * bk
        kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
        x = jnp.where(kx < k_total, x, jnp.zeros_like(x))
    acc_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        # One cast of the int32 accumulator, then the zero-point-corrected
        # affine: y = eff_scale*acc + eff_bias*rowsum(qx) + const.
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
            + cst_ref[...][None, :]
        )


def int_matmul_pallas(
    qx: jnp.ndarray,
    codes: jnp.ndarray,
    eff_scale: jnp.ndarray,
    eff_bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    const: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """qx: (M, K) int8 activation codes; codes: (K, N) int8 weight codes.

    Returns (M, N) fp32
    ``eff_scale * (qx @ codes) + eff_bias * rowsum + const`` with the GEMM
    accumulated in int32 (see module docstring for how the wrapper folds
    the two affine grids into these vectors). ``rowsum``: (M,) fp32
    ``sum_k qx[m, k]``; ``const``: (N,) fp32.
    """
    m, k = qx.shape
    _, n = codes.shape
    bm, bn, bk = gemm_blocks(m, n, k, block_m=block_m, block_n=block_n,
                             block_k=block_k)
    k_steps = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_int_kernel, k_steps=k_steps, k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, codes, eff_scale, eff_bias, rowsum, const)


def _int_packed_kernel(x_ref, p_ref, s_ref, b_ref, r_ref, cst_ref, o_ref,
                       acc_ref, *, bits: int, k_steps: int, k_total: int,
                       bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                    # (bm, bk) int8 codes
    # Mask activation columns past K: pack-padding words and ragged tails
    # then multiply a zeroed operand (same scheme as the float kernel).
    k0 = pl.program_id(2) * bk
    kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k0
    x = jnp.where(kx < k_total, x, jnp.zeros_like(x))
    p = p_ref[...].astype(jnp.int32)                  # (bk // per, bn)
    codes = unpack_tile(p, bits).astype(jnp.int8)     # (bk, bn)
    acc_ref[...] += jax.lax.dot(x, codes, preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * s_ref[...][None, :]
            + r_ref[...][:, None] * b_ref[...][None, :]
            + cst_ref[...][None, :]
        )


def int_matmul_packed_pallas(
    qx: jnp.ndarray,
    packed: jnp.ndarray,
    eff_scale: jnp.ndarray,
    eff_bias: jnp.ndarray,
    rowsum: jnp.ndarray,
    const: jnp.ndarray,
    *,
    bits: int,
    k: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Packed twin of ``int_matmul_pallas``: 2/4-bit weight codes are
    decoded to int8 in-register (``layout.unpack_tile``) and fed to the
    same int32-accumulating dot — sub-byte weight bandwidth AND integer
    MACs in one kernel."""
    assert bits in (2, 4), bits
    per = 8 // bits
    m = qx.shape[0]
    kp, n = packed.shape
    bm, bn, bkp, bk = packed_blocks(m, n, kp, per, block_m=block_m,
                                    block_n=block_n, block_k=block_k)
    k_steps = pl.cdiv(kp, bkp)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_int_packed_kernel, bits=bits, k_steps=k_steps,
                          k_total=k, bk=bk),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkp, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, packed, eff_scale, eff_bias, rowsum, const)
