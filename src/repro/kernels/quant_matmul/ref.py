"""Pure-jnp oracles for the fused dequant GEMM (int8 and packed) and the
int8×int8 integer-accumulation GEMM (DESIGN.md §16)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                     bias: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) fp; codes: (K, N) int8; scale/bias: (N,).

    y = x @ (codes * scale + bias) computed exactly in fp32.
    """
    w = codes.astype(jnp.float32) * scale[None, :] + bias[None, :]
    return x.astype(jnp.float32) @ w


def quant_matmul_packed_ref(x: jnp.ndarray, packed: jnp.ndarray,
                            scale: jnp.ndarray, bias: jnp.ndarray, *,
                            bits: int, k: int) -> jnp.ndarray:
    """Packed oracle: unpack to int8 codes, then ``quant_matmul_ref``.

    Literally unpack-then-int8-oracle, so the packed serving path is
    bit-for-bit identical to the int8 path whenever the pack/unpack
    round-trip is exact (guaranteed by ``quant.pack``) — the property the
    every-config equivalence test in ``tests/test_serving.py`` pins down.
    """
    from repro.quant.pack import unpack_codes

    return quant_matmul_ref(x, unpack_codes(packed, bits, k), scale, bias)


def int_matmul_ref(qx: jnp.ndarray, codes: jnp.ndarray,
                   eff_scale: jnp.ndarray, eff_bias: jnp.ndarray,
                   rowsum: jnp.ndarray, const: jnp.ndarray) -> jnp.ndarray:
    """qx: (M, K) int8 act codes; codes: (K, N) int8 weight codes.

    ``eff_scale * (qx @ codes) + eff_bias * rowsum + const`` with the GEMM
    accumulated in int32 — the jnp oracle the Pallas integer kernel is
    property-tested against (exact: same int32 accumulator, same fp32
    epilogue expression). The wrapper (ops.py) derives the three affine
    vectors from the weight's and activation's per-tensor/per-channel grids
    so this equals ``(qx*sx + bx) @ (codes*scale + bias)`` in exact
    arithmetic.
    """
    acc = jax.lax.dot(qx.astype(jnp.int32), codes.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * eff_scale[None, :]
            + rowsum[:, None] * eff_bias[None, :] + const[None, :])


def int_matmul_packed_ref(qx: jnp.ndarray, packed: jnp.ndarray,
                          eff_scale: jnp.ndarray, eff_bias: jnp.ndarray,
                          rowsum: jnp.ndarray, const: jnp.ndarray, *,
                          bits: int, k: int) -> jnp.ndarray:
    """Packed oracle: unpack the sub-byte weight codes, then
    ``int_matmul_ref`` — so packed integer serving is bit-for-bit the int8
    integer path whenever the pack round-trip is exact."""
    from repro.quant.pack import unpack_codes

    return int_matmul_ref(qx, unpack_codes(packed, bits, k), eff_scale,
                          eff_bias, rowsum, const)
