"""Pure-jnp oracles for the fused dequant GEMM (int8 and packed)."""

from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                     bias: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) fp; codes: (K, N) int8; scale/bias: (N,).

    y = x @ (codes * scale + bias) computed exactly in fp32.
    """
    w = codes.astype(jnp.float32) * scale[None, :] + bias[None, :]
    return x.astype(jnp.float32) @ w


def quant_matmul_packed_ref(x: jnp.ndarray, packed: jnp.ndarray,
                            scale: jnp.ndarray, bias: jnp.ndarray, *,
                            bits: int, k: int) -> jnp.ndarray:
    """Packed oracle: unpack to int8 codes, then ``quant_matmul_ref``.

    Literally unpack-then-int8-oracle, so the packed serving path is
    bit-for-bit identical to the int8 path whenever the pack/unpack
    round-trip is exact (guaranteed by ``quant.pack``) — the property the
    every-config equivalence test in ``tests/test_serving.py`` pins down.
    """
    from repro.quant.pack import unpack_codes

    return quant_matmul_ref(x, unpack_codes(packed, bits, k), scale, bias)
