"""Pure-jnp oracle for the fused dequant GEMM."""

from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                     bias: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) fp; codes: (K, N) int8; scale/bias: (N,).

    y = x @ (codes * scale + bias) computed exactly in fp32.
    """
    w = codes.astype(jnp.float32) * scale[None, :] + bias[None, :]
    return x.astype(jnp.float32) @ w
