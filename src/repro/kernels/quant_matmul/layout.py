"""Shared Mosaic-friendly tile layout for the quant_matmul kernel family.

Two things every kernel in this package (fp-epilogue dequant GEMM, packed
sub-byte variant, int8×int8 integer-accumulation GEMM) needs and used to
duplicate:

  * **Block-size selection.** TPU vector registers are (8, 128) sublane ×
    lane tiles; the MXU wants 128-aligned operands. ``gemm_blocks`` clamps
    the requested (bm, bn, bk) to the problem size while keeping any block
    that spans a full lane dimension a multiple of ``LANE`` — so a caller
    passing an odd ``block_n`` still hands Mosaic aligned tiles, and small
    (decode, M=1) problems degrade to their exact size instead of padding.
    ``packed_blocks`` is the packed twin: the K block is counted in
    UNPACKED columns and forced to a whole number of packed rows, so the
    packed and unpacked kernels share one grid/masking scheme.

  * **Interleave-free sub-byte unpack.** ``quant.pack`` stores byte ``i``
    of a column as codes ``i*per + j`` (``j`` little-endian in the byte).
    The old in-kernel decode shifted out the ``per`` fields, stacked them
    on a new axis and reshaped — a sublane interleave Mosaic lowers as a
    cross-lane shuffle (the ROADMAP carry-over). ``unpack_tile`` instead
    widens the packed tile with a sublane ``repeat`` (row ``r`` holds byte
    ``r // per``) and applies one elementwise shift/mask keyed off the row
    index — repeat + iota + elementwise only, no reshape, same codes.

CPU-interpret-mode equivalence against ``quant.pack.unpack_codes`` is
property-tested in ``tests/test_int_gemm.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# TPU register tile geometry: (SUBLANE, LANE) fp32 vregs; MXU is LANE×LANE.
LANE = 128
SUBLANE = 8


def _align_lane(block: int, dim: int) -> int:
    """Clamp ``block`` to ``dim``; keep it LANE-aligned while it spans one."""
    b = min(block, dim)
    if b >= LANE:
        b = (b // LANE) * LANE
    return b


def gemm_blocks(m: int, n: int, k: int, *, block_m: int, block_n: int,
                block_k: int) -> tuple[int, int, int]:
    """(bm, bn, bk) for an (M, K) × (K, N) kernel: clamped, lane-aligned."""
    return min(block_m, m), _align_lane(block_n, n), min(block_k, k)


def packed_blocks(m: int, n: int, kp: int, per: int, *, block_m: int,
                  block_n: int, block_k: int) -> tuple[int, int, int, int]:
    """(bm, bn, bkp, bk): K block in unpacked columns, whole packed rows.

    ``kp`` is the packed K length (``ceil(K / per)``); ``bk = bkp * per`` is
    the unpacked block the activation tile and the masking scheme see.
    """
    bm, bn = min(block_m, m), _align_lane(block_n, n)
    bkp = min(max(block_k // per, 1), kp)
    return bm, bn, bkp, bkp * per


def unpack_tile(p: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Decode a packed (bkp, bn) int32 tile to (bkp*per, bn) centered codes.

    Row ``r`` of the result is field ``r % per`` of packed row ``r // per``
    — identical to ``quant.pack.unpack_codes`` on the tile, but built from
    a sublane repeat plus elementwise shift/mask (no stack+reshape sublane
    interleave), which Mosaic lowers without cross-lane data movement.
    Returns int32; callers cast to the dtype their dot wants.
    """
    per = 8 // bits
    offset = 1 << (bits - 1)
    mask = (1 << bits) - 1
    widened = jnp.repeat(p, per, axis=0)             # row r = byte r // per
    rows = jax.lax.broadcasted_iota(jnp.int32, widened.shape, 0)
    return ((widened >> ((rows % per) * bits)) & mask) - offset
