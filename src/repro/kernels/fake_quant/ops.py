"""Jit'd public wrapper for the fused fake-quant kernel.

Normalizes arbitrary tensor shapes / gate granularities onto the kernel's
(M, N) x (N,) layout, and falls back to the pure-jnp path where Pallas is not
available (the XLA fallback is what the CPU dry-run lowers; kernels are
validated in interpret mode — DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .fake_quant import fake_quant_pallas
from .ref import fake_quant_ref


@functools.partial(jax.jit, static_argnames=("signed", "use_pallas", "interpret"))
def fake_quant_op(
    x: jnp.ndarray,
    gate: jnp.ndarray,
    beta: jnp.ndarray,
    *,
    signed: bool = True,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fake-quantize ``x`` at bit-width T(gate) with range beta.

    gate/beta may be scalar (per-tensor) or (x.shape[-1],) (per-channel).
    """
    orig_shape = x.shape
    n = orig_shape[-1]
    x2 = x.reshape(-1, n).astype(jnp.float32)
    g = jnp.broadcast_to(jnp.asarray(gate, jnp.float32), (n,))
    b = jnp.broadcast_to(jnp.asarray(beta, jnp.float32), (n,))
    if use_pallas:
        out = fake_quant_pallas(x2, g, b, signed, interpret=interpret)
    else:
        out = fake_quant_ref(x2, g, b, signed)
    return out.reshape(orig_shape).astype(x.dtype)
