"""Pure-jnp oracle for the fused fake-quant kernel.

Matches core.quantizer/gates semantics exactly: bits = T(max(g, 0.5)),
alpha = -beta (signed) or 0, b >= 32 passes through.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.gates import gate_to_bits
from repro.core.quantizer import quantize


def fake_quant_ref(x: jnp.ndarray, gate: jnp.ndarray, beta: jnp.ndarray,
                   signed: bool) -> jnp.ndarray:
    """x: (M, N); gate/beta: (N,) per-channel (broadcast by caller)."""
    bits = gate_to_bits(gate)[None, :]
    return quantize(x, bits, beta[None, :], signed)
