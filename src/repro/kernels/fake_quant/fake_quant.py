"""Pallas TPU kernel: fused gated fake quantization (CGMQ hot path).

The paper's Eq. 3 residual decomposition naively costs 5 elementwise
quantization passes (b = 2,4,8,16,32) per tensor per training step — five
HBM round-trips of VPU work. Exploiting the telescoping identity
``x_q = Q(x, T(g))`` (property-tested against the residual form in
tests/test_gates.py), this kernel fuses the gate->bit-width map, range clip,
scale, round and pass-through select into ONE HBM->VMEM->HBM pass.

Tiling: 2D grid over (row, col) blocks; (block_m x block_n) fp32 tiles in
VMEM (default 256x512 = 512 KiB in + 512 KiB out, well under the ~16 MiB
v5e VMEM); gate/beta are per-column (bn,) slices. All arithmetic is VPU
elementwise — the kernel is HBM-bandwidth bound by construction, which is
exactly why the fusion matters (5x fewer bytes moved than the unfused chain).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# T(g) thresholds (paper Eq. 4), encoded branchlessly in-kernel.
_EDGES = (0.0, 1.0, 2.0, 3.0, 4.0)
_BITS = (0.0, 2.0, 4.0, 8.0, 16.0, 32.0)
GATE_MIN = 0.5


def _kernel(x_ref, g_ref, b_ref, o_ref, *, signed: bool):
    x = x_ref[...]
    g = jnp.maximum(g_ref[...], GATE_MIN)  # no pruning (paper)
    beta = jnp.maximum(b_ref[...], 1e-8)

    # bits = T(g), branchless
    bits = jnp.full_like(g, _BITS[0])
    for edge, b in zip(_EDGES, _BITS[1:]):
        bits = jnp.where(g > edge, b, bits)

    alpha = -beta if signed else jnp.zeros_like(beta)
    span = beta - alpha
    b_eff = jnp.clip(bits, 2.0, 31.0)
    n = jnp.exp2(b_eff) - 1.0
    s = span / n
    xc = jnp.clip(x, alpha[None, :], beta[None, :])
    q = alpha[None, :] + s[None, :] * jnp.round((xc - alpha[None, :]) / s[None, :])
    o_ref[...] = jnp.where(bits[None, :] >= 32.0, x, q)


def fake_quant_pallas(
    x: jnp.ndarray,
    gate: jnp.ndarray,
    beta: jnp.ndarray,
    signed: bool,
    *,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """x: (M, N) fp32; gate/beta: (N,). Returns fake-quantized x.

    ``interpret=True`` executes the kernel body on CPU (this container);
    on TPU pass ``interpret=False``.
    """
    m, n = x.shape
    bm, bn = min(block_m, m), min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, signed=signed),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, gate, beta)
