"""Pure-jnp oracle: single-token GQA decode through a paged KV cache.

Numerics deliberately mirror ``models.attention.attention_decode`` (bf16
matmuls with fp32 accumulation, fp32 softmax) so the paged path's logits can
be gated against the contiguous ring-cache path at bf16 tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, block_table, pos, *,
                        window: int | None = None,
                        softcap: float | None = None):
    """q: (B, KV, G, hd); pools: (num_blocks, bs, KV, hd);
    block_table: (B, max_blocks) int32 (-1 = unallocated); pos: (B,) int32.
    Returns (B, KV, G, hd).

    Unallocated table entries gather the garbage block 0; every logical
    position they cover is > ``pos`` for that row, so the mask discards them.
    """
    b, kvh, g, hd = q.shape
    bs = k_pool.shape[1]
    mb = block_table.shape[1]
    safe = jnp.where(block_table >= 0, block_table, 0)
    k = k_pool[safe].reshape(b, mb * bs, kvh, hd)
    v = v_pool[safe].reshape(b, mb * bs, kvh, hd)
    scale = hd ** -0.5
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", q, k.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    sids = jnp.arange(mb * bs)[None, :]
    posb = pos[:, None]
    valid = sids <= posb
    if window is not None:
        valid &= (posb - sids) < window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(q.dtype),
                      preferred_element_type=jnp.float32)
