"""Pure-jnp oracle: single-token GQA decode through a paged KV cache.

Numerics deliberately mirror ``models.attention.attention_decode`` (bf16
matmuls with fp32 accumulation, fp32 softmax) so the paged path's logits can
be gated against the contiguous ring-cache path at bf16 tolerance.

Quantized pools (DESIGN.md §14): when ``k_scale``/``v_scale`` are given the
pools hold integer codes (int8, or uint8 nibble-packed int4) with fp16
per-group scales along head_dim. The oracle gathers codes and scales with
the SAME block-table index and dequantizes right after the gather — the
reference semantics for the Pallas kernel's fused dequant-on-block-load —
then runs the identical masked-softmax math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.kv import dequant_codes, unpack_int4

NEG_INF = -1e30


def _dequant_gathered(codes, scale, hd):
    """(B, S, KV, packed) codes + (B, S, KV, ng) scales -> (B, S, KV, hd)."""
    if codes.dtype == jnp.uint8:  # nibble-packed int4
        codes = unpack_int4(codes, hd)
    return dequant_codes(codes, scale, hd, hd // scale.shape[-1])


def paged_attention_ref(q, k_pool, v_pool, block_table, pos, *,
                        window: int | None = None,
                        sinks: int = 0,
                        softcap: float | None = None,
                        k_scale=None, v_scale=None):
    """q: (B, KV, G, hd); pools: (num_blocks, bs, KV, hd) float, or
    (num_blocks, bs, KV, packed_head) codes with ``k_scale``/``v_scale``
    (num_blocks, bs, KV, num_groups) fp16; block_table: (B, max_blocks)
    int32 (-1 = unallocated); pos: (B,) int32. Returns (B, KV, G, hd).

    Unallocated table entries gather the garbage block 0; every logical
    position they cover is > ``pos`` for that row, so the mask discards them.
    With a ``window``, evicted (out-of-window) entries are also ``-1`` and
    their positions fail the window test, so they gather garbage AND mask
    out. ``sinks`` (token count, block-aligned by the engine) re-admits the
    pinned leading positions regardless of window age — the §17 mask rule
    ``kp <= qp and (qp - kp < window or kp < sinks)``.
    """
    b, kvh, g, hd = q.shape
    bs = k_pool.shape[1]
    mb = block_table.shape[1]
    safe = jnp.where(block_table >= 0, block_table, 0)
    k = k_pool[safe].reshape(b, mb * bs, kvh, k_pool.shape[-1])
    v = v_pool[safe].reshape(b, mb * bs, kvh, v_pool.shape[-1])
    if k_scale is not None:
        ng = k_scale.shape[-1]
        ks = k_scale[safe].reshape(b, mb * bs, kvh, ng)
        vs = v_scale[safe].reshape(b, mb * bs, kvh, ng)
        k = _dequant_gathered(k, ks, hd)
        v = _dequant_gathered(v, vs, hd)
    scale = hd ** -0.5
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", q, k.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    sids = jnp.arange(mb * bs)[None, :]
    posb = pos[:, None]
    valid = sids <= posb
    if window is not None:
        in_win = (posb - sids) < window
        if sinks:
            in_win |= sids < sinks
        valid &= in_win
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(q.dtype),
                      preferred_element_type=jnp.float32)
