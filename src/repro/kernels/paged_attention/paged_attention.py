"""Pallas TPU kernel: paged single-token decode attention (vLLM-style).

The decode-time half of the paged KV cache (DESIGN.md §10): each serving
slot's K/V lives in fixed-size token blocks scattered through a physical
pool, addressed by a per-slot block table. The kernel walks one slot's table
entries and runs an online-softmax accumulation over its blocks — the paged
analogue of FlashDecoding — without ever materializing the gathered
(B, L, KV, hd) K/V that the jnp oracle builds.

Layout and TPU mapping:

  * grid ``(B, max_blocks)`` with the block dimension innermost, so the
    softmax statistics (m, l) and the output accumulator stay resident in
    VMEM scratch across a slot's blocks — same carry discipline as the
    flash_attention kernel.
  * the block table and per-slot positions ride in as **scalar prefetch**
    (``PrefetchScalarGridSpec``): the K/V BlockSpec index_map reads
    ``table[b, j]`` to DMA exactly the physical block the slot's j-th
    logical block lives in. Unallocated entries (-1) clip to the reserved
    garbage block 0 and are masked out by the position test.
  * GQA: q arrives as (B, KV*G, hd); scores run as a KV-batched dot_general
    so every query group hits the MXU against its own KV head.
  * blocks wholly past the row's position (and, for sliding-window layers,
    wholly fallen out of the window) are pruned with ``pl.when`` before any
    compute.
  * long-context windows (DESIGN.md §17) add a third scalar-prefetch
    operand: the per-slot **first-live-block index** ``fl``. The K/V
    index_map routes every dead block (``j < fl[b]`` and not a pinned sink
    block) to the garbage block 0, so out-of-window blocks are never DMA'd
    at all — the window walk touches O(window/bs + sinks) blocks per slot
    regardless of prompt length, on all KV dtypes (the quantized scale
    operands share the same routed index_map). ``sinks`` (leading token
    count, block-aligned by the engine) re-admits the pinned prefix in both
    the block prune and the in-block mask: the §17 rule is
    ``kp <= p and (p - kp < window or kp < sinks)``.

Quantized pools (DESIGN.md §14) add a **fused dequant-on-block-load**: the
per-group fp16 scales ride in as two extra block-mapped operands whose
BlockSpec index_map reads the SAME ``table[b, j]`` entry as the code
blocks, so scale DMA is paged exactly like the codes; the affine is applied
in-register (int4 nibbles unpacked first) before the scores dot, and the
online-softmax carry is untouched.

On CPU containers the kernel runs in interpret mode (the repo-wide kernel
contract, DESIGN.md §3); on TPU it lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.kv import dequant_codes, unpack_int4

NEG_INF = -1e30


def _kernel(table_ref, pos_ref, fl_ref, q_ref, k_ref, v_ref, *rest,
            block_size: int, blocks: int,
            kv_heads: int, groups: int, window: int | None,
            sinks: int, softcap: float | None, scale: float,
            head_dim: int, group_size: int = 0, bits: int = 8):
    if group_size:  # quantized: two scale operands precede the output
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p = pos_ref[b]
    start = j * block_size
    run = (start <= p) & (table_ref[b, j] >= 0)
    if window is not None:
        in_win = p - (start + block_size - 1) < window
        if sinks:
            in_win = jnp.logical_or(in_win, start < sinks)
        run = jnp.logical_and(run, in_win)
        # mirror the index_map's dead-block routing: j < fl[b] never ran DMA
        run = jnp.logical_and(
            run, jnp.logical_or(j >= fl_ref[b], start < sinks))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (KV*G, hd)
        if group_size:
            kc, vc = k_ref[0], v_ref[0]           # (bs, KV, packed)
            if bits == 4:
                kc = unpack_int4(kc, head_dim)
                vc = unpack_int4(vc, head_dim)
            # fused dequant in-register: (bs, KV, ng, G) * scale
            k = dequant_codes(kc, ks_ref[0], head_dim, group_size)
            v = dequant_codes(vc, vs_ref[0], head_dim, group_size)
        else:
            k = k_ref[0].astype(jnp.float32)      # (bs, KV, hd)
            v = v_ref[0].astype(jnp.float32)      # (bs, KV, hd)
        qr = q.reshape(kv_heads, groups, q.shape[-1])
        # batched over the KV head axis: (KV, G, hd) x (bs, KV, hd)
        s = jax.lax.dot_general(
            qr, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (KV, G, bs)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        cols = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        mask = cols <= p
        if window is not None:
            in_win = (p - cols) < window
            if sinks:
                in_win |= cols < sinks
            mask &= in_win
        s = jnp.where(mask, s, NEG_INF).reshape(kv_heads * groups, -1)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1)[:, None])
        pexp = jnp.exp(s - m_new)                  # (KV*G, bs)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(pexp, axis=1)[:, None]
        pv = jax.lax.dot_general(
            pexp.reshape(kv_heads, groups, -1), v,
            (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )                                          # (KV, G, hd)
        acc_scr[...] = acc_scr[...] * alpha + pv.reshape(acc_scr.shape)
        m_scr[...] = m_new

    @pl.when(j == blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, block_table, pos, *,
                           window: int | None = None,
                           sinks: int = 0,
                           softcap: float | None = None,
                           interpret: bool = True,
                           k_scale=None, v_scale=None):
    """q: (B, KV, G, hd); pools: (num_blocks, bs, KV, hd) float or
    (num_blocks, bs, KV, packed_head) codes + ``k_scale``/``v_scale``
    (num_blocks, bs, KV, num_groups) fp16 per-group scales;
    block_table: (B, max_blocks); pos: (B,). Returns (B, KV, G, hd).

    ``window``/``sinks`` (both static) enable the §17 block-sparse walk:
    the per-slot first-live-block index is derived from ``pos`` here and
    scalar-prefetched so dead blocks are never loaded (module docstring)."""
    b, kvh, g, hd = q.shape
    bs = k_pool.shape[1]
    mb = block_table.shape[1]
    hdp = k_pool.shape[-1]
    quant = k_scale is not None
    if quant:
        ng = k_scale.shape[-1]
        group_size = hd // ng
        bits = 8 if k_pool.dtype == jnp.int8 else 4
        assert ng * group_size == hd, (hd, ng)
    else:
        ng, group_size, bits = 0, 0, 8
    qf = q.reshape(b, kvh * g, hd)
    sink_blocks = -(-sinks // bs)
    if window is not None:
        # first block the sliding window still reaches; sink blocks pinned
        fl = jnp.maximum((pos - window + 1) // bs,
                         sink_blocks).astype(jnp.int32)
    else:
        fl = jnp.zeros_like(pos, dtype=jnp.int32)

    def table_map(bi, j, tbl, ps, fl):
        live = (j >= fl[bi]) | (j < sink_blocks)
        return (jnp.where(live, jnp.maximum(tbl[bi, j], 0), 0), 0, 0, 0)

    def row_map(bi, j, tbl, ps, fl):
        return (bi, 0, 0)

    in_specs = [
        pl.BlockSpec((1, kvh * g, hd), row_map),
        pl.BlockSpec((1, bs, kvh, hdp), table_map),
        pl.BlockSpec((1, bs, kvh, hdp), table_map),
    ]
    operands = [qf, k_pool, v_pool]
    if quant:
        # scale blocks page through the SAME table entry as the codes
        in_specs += [pl.BlockSpec((1, bs, kvh, ng), table_map)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kvh * g, hd), row_map),
        scratch_shapes=[
            pltpu.VMEM((kvh * g, 1), jnp.float32),   # running max m
            pltpu.VMEM((kvh * g, 1), jnp.float32),   # running denom l
            pltpu.VMEM((kvh * g, hd), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel, block_size=bs, blocks=mb, kv_heads=kvh, groups=g,
            window=window, sinks=sinks, softcap=softcap, scale=hd ** -0.5,
            head_dim=hd, group_size=group_size, bits=bits,
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh * g, hd), jnp.float32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(block_table, pos, fl, *operands)
    return out.reshape(b, kvh, g, hd)
