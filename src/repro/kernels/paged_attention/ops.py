"""Jit'd dispatch wrapper for paged decode attention (ref / Pallas)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .paged_attention import paged_attention_pallas
from .ref import paged_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("window", "sinks", "softcap", "use_pallas", "interpret"))
def paged_attention_op(q, k_pool, v_pool, block_table, pos, *,
                       window: int | None = None,
                       sinks: int = 0,
                       softcap: float | None = None,
                       use_pallas: bool = False,
                       interpret: bool = True,
                       k_scale=None, v_scale=None):
    """q: (B, KV, G, hd); pools: (num_blocks, bs, KV, hd) float — or integer
    codes with ``k_scale``/``v_scale`` (num_blocks, bs, KV, num_groups) fp16
    group scales for the fused-dequant path (DESIGN.md §14);
    block_table: (B, max_blocks) int32; pos: (B,) int32 → (B, KV, G, hd) f32.

    ``k_scale``/``v_scale`` are traced operands: their presence changes the
    argument pytree, so float and quantized pools get separate jit
    specializations without a static flag.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if use_pallas:
        return paged_attention_pallas(
            q, k_pool, v_pool, block_table, pos,
            window=window, sinks=sinks, softcap=softcap, interpret=interpret,
            k_scale=k_scale, v_scale=v_scale)
    return paged_attention_ref(
        q, k_pool, v_pool, block_table, pos, window=window, sinks=sinks,
        softcap=softcap, k_scale=k_scale, v_scale=v_scale)
