"""Pure-jnp oracle: causal (optionally sliding-window) attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None):
    """q/k/v: (B, H, S, D) fp32 -> (B, H, S, D)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= (qi - ki) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
