"""Pallas TPU kernel: blockwise causal flash attention (online softmax).

FlashAttention (arXiv:2205.14135) re-thought for the TPU memory hierarchy:
Q/K/V tiles stream HBM->VMEM; the (block_q x block_k) score tile lives
entirely in VMEM/VREG; softmax statistics (running max m, denominator l) and
the output accumulator are VMEM scratch carried across the kv grid dimension.
MXU does both GEMMs; the causal structure prunes upper-triangular kv blocks
via ``pl.when`` (skipping ~half the FLOPs without dynamic shapes).

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost so scratch carries
are local; 128-aligned block sizes for the MXU.

Supports the model zoo's needs: causal, sliding-window (mixtral/gemma2
local layers), and gemma2's attention softcap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, kv_steps: int,
            causal: bool, window: int | None, softcap: float | None,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # causal block pruning: skip blocks entirely above the diagonal
    run = True
    if causal:
        run = (k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128, block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q/k/v: (B, H, S, D) -> (B, H, S, D). S % block sizes handled by cdiv."""
    b, h, s, d = q.shape
    bq, bk = min(block_q, s), min(block_k, s)
    kv_steps = pl.cdiv(s, bk)
    grid = (b * h, pl.cdiv(s, bq), kv_steps)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=bq, block_k=bk, kv_steps=kv_steps,
            causal=causal, window=window, softcap=softcap, scale=d**-0.5,
        ),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
