"""Jit'd wrapper for flash attention with GQA layout handling."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "use_pallas", "interpret"),
)
def flash_attention_op(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    hq, hkv = q.shape[1], k.shape[1]
    if hkv != hq:
        reps = hq // hkv
        k = jnp.repeat(k, reps, axis=1)
        v = jnp.repeat(v, reps, axis=1)
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=interpret,
        )
    return attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=causal, window=window, softcap=softcap,
    ).astype(q.dtype)
