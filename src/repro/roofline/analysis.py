"""Roofline analysis from compiled dry-run artifacts (deliverable (g)).

Three terms per (arch x shape x mesh), from the PER-DEVICE compiled module
(XLA's cost/memory analyses describe the post-SPMD per-device program):

    compute    = flops_per_device / peak_flops_per_chip
    memory     = bytes_accessed_per_device / hbm_bw_per_chip
    collective = collective_bytes_per_device / ici_bw_per_chip

Hardware constants (TPU v5e, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

``collective_bytes`` is NOT in cost_analysis: we parse the compiled HLO text
and sum the operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[64,32]{1,0}" or "bf16[8,128]" or "(f32[2], f32[4,4])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\]\{\},.\d]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the per-device HLO.

    Counting the RESULT shape (between ``=`` and the op name; tuples for
    multi-operand reduces) measures the data each device receives — the
    standard per-device traffic proxy. Fusions never contain collectives, so
    a line scan is sufficient. Async ``-start``/``-done`` pairs count once.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["start_ops"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        if phase == "-start":
            out["start_ops"] += 1
        out[op] += _shape_bytes(shapes)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(rec: dict) -> dict:
    """Three roofline terms (seconds) + dominant + useful-flops ratio."""
    pd = rec["per_device"]
    flops = pd.get("flops") or 0.0
    byts = pd.get("bytes_accessed") or 0.0
    coll = (pd.get("collective_bytes") or {}).get("total", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total_flops_all_chips = flops * rec.get("chips", 1)
    useful = None
    mfu_bound = None
    if rec.get("model_flops_global"):
        useful = rec["model_flops_global"] / max(total_flops_all_chips, 1.0)
        # roofline fraction: model flops at peak / roofline-bound step time
        ideal_s = rec["model_flops_global"] / (rec["chips"] * PEAK_FLOPS)
        mfu_bound = ideal_s / max(bound, 1e-12)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_step_s": bound,
        "useful_flops_ratio": useful,
        "roofline_fraction": mfu_bound,
    }
