"""ShardingPlan: FSDP x TP (x EP) placement rules for every architecture.

Mesh axes (launch/mesh.py): single-pod ``(data=16, model=16)``; multi-pod
``(pod=2, data=16, model=16)``. Rules (DESIGN.md §4):

  * Weights: TP dim over ``model`` (attention/MLP output features, vocab,
    expert dim when divisible); the other large dim FSDP-sharded over
    ``data`` (+``pod``). GSPMD inserts the per-layer all-gathers and gradient
    reduce-scatters (MaxText-style "automatic FSDP").
  * Feature-dim TP for attention: q/k/v/o projections shard the *fused*
    (heads*head_dim) feature axis — divisible by 16 for every assigned arch,
    sidestepping head-count divisibility (gemma2 8H, arctic 56H,
    recurrentgemma 10H). Attention activations shard heads over ``model``
    only when the head count divides; otherwise Q-sequence sharding with
    gathered KV.
  * Activations: batch over (``pod``,)``data``; batch=1 decode shards the
    cache sequence axis over all axes instead.
  * MoE: expert-parallel over ``model`` when n_experts divides (arctic
    128/16); otherwise TP inside the expert FFN (mixtral).
  * Scalars / norms / gates / ranges / probes: replicated.

The plan degrades to no-ops without a mesh, so model code is unchanged on a
single device.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _divisible(n: int, k: int) -> bool:
    return n > 0 and n % k == 0


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    cfg: ModelConfig
    batch_axes: tuple[str, ...]   # ('data',) or ('pod', 'data')
    model_axis: str = "model"
    seq_shard_batch1: bool = False  # long_500k: shard cache seq instead of batch
    serve_resident: bool = False    # serving: TP-only weights, no FSDP gathers

    # ---- derived -----------------------------------------------------------
    @property
    def fsdp(self):
        return self.batch_axes if len(self.batch_axes) == 1 else self.batch_axes

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def heads_shardable(self) -> bool:
        return _divisible(self.cfg.n_heads, self.tp_size)

    @property
    def experts_shardable(self) -> bool:
        return _divisible(self.cfg.n_experts, self.tp_size)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _c(self, x, spec: P):
        return jax.lax.with_sharding_constraint(x, self.named(spec))

    # ---- parameter placement -------------------------------------------------
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """Sharding spec for a parameter, by name convention + shape."""
        fsdp = None if self.serve_resident else self.fsdp
        m = self.model_axis
        last = path.split("/")[-1]
        if len(shape) <= 1:
            return P()  # norms, biases, scalars: replicated
        if last == "embed":
            # vocab over model; d replicated — the mask-psum lookup
            # (launch/steps.sharded_embed_lookup) needs whole rows per shard
            return P(m, None)
        if last == "head":
            return P(fsdp, m)
        if last in ("wq", "wk", "wv", "wx", "wy", "gate_a", "gate_x",
                    "in_proj", "w_gate", "w_up", "w_in"):
            if len(shape) == 3:  # stacked (R, in, out)
                return P(None, fsdp, m)
            return P(fsdp, m)
        if last in ("wo", "w_down", "w_out", "out_proj"):
            if len(shape) == 3:
                return P(None, m, fsdp)
            return P(m, fsdp)
        if last == "router":
            return P(None, fsdp, None) if len(shape) == 3 else P(fsdp, None)
        # conv filters, lambdas, other small tensors: replicated
        return P()

    def moe_spec(self, path: str, shape: tuple[int, ...]) -> P | None:
        """Expert-weight placement; returns None if not an expert tensor."""
        last = path.split("/")[-1]
        if last not in ("w_gate", "w_up", "w_down"):
            return None
        # expert tensors have an E dim: (E, a, b) or stacked (R, E, a, b)
        if len(shape) not in (3, 4):
            return None
        e_idx = 0 if len(shape) == 3 else 1
        if shape[e_idx] != self.cfg.n_experts or not self.cfg.n_experts:
            return None
        m = self.model_axis
        fsdp = None if self.serve_resident else self.fsdp
        lead = (None,) * e_idx
        if self.experts_shardable:
            return P(*lead, m, fsdp, None)
        # TP inside expert: shard d_ff; w_down's ff is dim -2
        if last == "w_down":
            return P(*lead, None, m, fsdp)
        return P(*lead, None, fsdp, m)

    def params_shardings(self, params: Any) -> Any:
        """NamedSharding pytree matching a params pytree."""

        def _one(path, leaf):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            spec = self.moe_spec(pstr, leaf.shape)
            if spec is None:
                spec = self.param_spec(pstr, leaf.shape)
            return self.named(spec)

        return jax.tree_util.tree_map_with_path(_one, params)

    def replicated(self, tree: Any) -> Any:
        return jax.tree.map(lambda _: self.named(P()), tree)

    # ---- activation constraints (called from model code) ----------------------
    def shard_hidden(self, h):
        """Block-boundary residual stream: batch over data, seq over model
        (Megatron-style sequence parallelism — shrinks the scan backward
        carries by tp_size; attention/MLP internals re-shard as needed)."""
        if h.ndim != 3:
            return h
        b, s, _ = h.shape
        bspec = self.batch_axes if b > 1 else None
        sspec = self.model_axis if (s > 1 and s % self.tp_size == 0) else None
        return self._c(h, P(bspec, sspec, None))

    def shard_attn_qkv(self, q, k, v):
        bspec = self.batch_axes if q.shape[0] > 1 else None
        if self.heads_shardable:
            spec = P(bspec, None, self.model_axis, None)
        else:
            # Q-sequence sharding; KV gathered by GSPMD at the einsum
            spec = P(bspec, self.model_axis, None, None)
        return self._c(q, spec), self._c(k, spec if self.heads_shardable else
                                         P(bspec, None, None, None)), \
            self._c(v, spec if self.heads_shardable else
                    P(bspec, None, None, None))

    def cache_spec(self, kind_shape: tuple[int, ...]) -> P:
        """KV-cache (B, slots, KV, hd): batch over data, slots over model;
        batch=1 shards slots over every axis."""
        b = kind_shape[0]
        if b == 1:
            axes = tuple(self.batch_axes) + (self.model_axis,)
            return P(None, axes, None, None)
        return P(self.batch_axes, self.model_axis, None, None)

    def shard_cache(self, c):
        if c.ndim != 4:
            return c
        return self._c(c, self.cache_spec(c.shape))

    def pool_spec(self) -> P:
        """Paged KV pool (num_blocks, bs, KV, hd): blocks over every axis —
        the paged analogue of split-KV decode (a block is a sequence range,
        like the slots axis of the contiguous cache; DESIGN.md §10)."""
        axes = tuple(self.batch_axes) + (self.model_axis,)
        return P(axes, None, None, None)

    def shard_pool(self, c):
        if c.ndim != 4:
            return c
        return self._c(c, self.pool_spec())

    def shard_moe(self, t):
        """(ng, E, C, d) dispatch tensors."""
        if t.ndim != 4:
            return t
        espec = self.model_axis if self.experts_shardable else None
        return self._c(t, P(self.batch_axes, espec, None, None))

    # ---- io specs ---------------------------------------------------------------
    def batch_spec(self, shape: tuple[int, ...]) -> P:
        if shape[0] == 1:
            return P(*((None,) * len(shape)))
        return P(self.batch_axes, *((None,) * (len(shape) - 1)))

    def data_shardings(self, tree: Any) -> Any:
        return jax.tree.map(
            lambda leaf: self.named(self.batch_spec(leaf.shape)), tree
        )

    def batch_dict_shardings(self, batch: dict) -> dict:
        """Key-aware input shardings (mrope is (3, B, S): batch at dim 1)."""
        out = {}
        for k, v in batch.items():
            if k == "mrope":
                spec = (P(None, self.batch_axes, None) if v.shape[1] > 1
                        else P(None, None, None))
            else:
                spec = self.batch_spec(v.shape)
            out[k] = self.named(spec)
        return out

    def cache_shardings(self, cache: Any) -> Any:
        """Shardings for the decode cache pytree (keyed by cache kind)."""
        m = self.model_axis
        dp = self.dp_size

        def _one(path, leaf):
            shp = leaf.shape
            keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
            kind = keys[-1]
            if kind in ("k", "v"):
                # (R, B, slots, KV, hd) stacked or (B, slots, KV, hd)
                if len(shp) == 5:
                    return self.named(P(None, *self.cache_spec(shp[1:])))
                return self.named(self.cache_spec(shp))
            if kind == "pos":
                return self.named(P())
            # recurrent states: (R?, B, ...feature dims...)
            bdim = 1 if len(shp) >= 3 and kind in ("conv", "ssm", "h") and \
                shp[0] != shp[1] and len(shp) >= 4 else 0
            # stacked when the pytree level above was stacked: detect via a
            # leading dim equal among siblings is fragile; use ndim heuristic
            # per kind instead:
            nd = {"conv": 3, "ssm": 4, "h": 2}.get(kind)
            bdim = len(shp) - nd if nd else 0
            spec = [None] * len(shp)
            if shp[bdim] > 1 and shp[bdim] % dp == 0:
                spec[bdim] = self.batch_axes
            # shard the widest feature dim over model when divisible
            feat = max(range(bdim + 1, len(shp)), key=lambda i: shp[i],
                       default=None) if len(shp) > bdim + 1 else None
            if feat is not None and shp[feat] % self.tp_size == 0 and \
                    shp[feat] >= self.tp_size:
                spec[feat] = m
            return self.named(P(*spec))

        return jax.tree_util.tree_map_with_path(_one, cache)
