"""Fault tolerance: supervised training loop, straggler detection, preemption.

``TrainSupervisor`` wraps a step function with the failure model of a
large fleet:

  * crash / node-failure recovery — every exception inside the step loop
    triggers restore-from-last-checkpoint and replay; a failure injector
    (``inject_failure_at``) exercises the path in tests;
  * preemption — SIGTERM/SIGINT set a flag; the loop checkpoints at the next
    step boundary and exits cleanly (maintenance-event behavior on TPU pods);
  * straggler mitigation — per-step wall times feed an EWMA + MAD detector;
    a step slower than ``straggler_z`` deviations is logged and counted, and
    a pluggable callback lets the launcher trade the slow host out (on a real
    fleet: re-slice; here: the hook is tested with a synthetic delay);
  * elastic scaling — on restore the checkpoint re-shards onto whatever mesh
    the restarted job has (Checkpointer handles topology changes).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    straggler_z: float = 4.0
    straggler_window: int = 32
    handle_signals: bool = False  # opt-in: tests drive preemption directly


class StragglerDetector:
    """EWMA/MAD step-time anomaly detector.

    Shared across the training and serving failure models: the training
    supervisor feeds it optimizer-step times, ``serving.faults.
    ServingSupervisor`` feeds it engine-tick times (DESIGN.md §13) — one
    detector, one definition of "anomalously slow"."""

    def __init__(self, window: int = 32, z: float = 4.0):
        self.times: list[float] = []
        self.window = window
        self.z = z
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
        if dt > med + self.z * 1.4826 * mad and dt > 1.2 * med:
            self.flagged.append((step, dt))
            return True
        return False


class TrainSupervisor:
    def __init__(self, cfg: SupervisorConfig, *,
                 on_straggler: Callable[[int, float], None] | None = None,
                 log: Callable[[str], None] = print):
        self.cfg = cfg
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep=cfg.keep)
        self.detector = StragglerDetector(cfg.straggler_window, cfg.straggler_z)
        self.on_straggler = on_straggler
        self.log = log
        self.preempted = False
        self.restarts = 0
        self.inject_failure_at: int | None = None  # test hook
        if cfg.handle_signals:
            signal.signal(signal.SIGTERM, self._handle)
            signal.signal(signal.SIGINT, self._handle)

    def _handle(self, signum, frame):
        self.log(f"[supervisor] received signal {signum}: preempting")
        self.preempted = True

    def preempt(self):
        """Programmatic preemption (what the SIGTERM handler sets)."""
        self.preempted = True

    # ------------------------------------------------------------------
    def run(self, state: Any, step_fn: Callable, batches, *,
            start_step: int = 0, shardings: Any = None,
            metrics_cb: Callable | None = None):
        """Supervised loop. ``batches`` is an indexable step -> batch source
        (replayable, so restarts resume deterministically)."""
        step = start_step
        # resume if a checkpoint exists
        if self.ckpt.latest_step() is not None:
            state, step, _ = self.ckpt.restore(
                jax.eval_shape(lambda: state), shardings=shardings)
            self.log(f"[supervisor] resumed from step {step}")

        while True:
            if self.preempted:
                self.ckpt.save(step, state, blocking=True,
                               extra={"reason": "preempt"})
                self.log(f"[supervisor] checkpointed step {step} on "
                         "preemption; exiting")
                return state, step, "preempted"
            batch = batches(step)
            if batch is None:
                self.ckpt.save(step, state, blocking=True,
                               extra={"reason": "final"})
                return state, step, "done"
            t0 = time.time()
            try:
                if self.inject_failure_at is not None and \
                        step == self.inject_failure_at:
                    self.inject_failure_at = None
                    raise RuntimeError("injected node failure")
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics)
            except Exception as e:  # noqa: BLE001 — fleet failure model
                self.restarts += 1
                self.log(f"[supervisor] step {step} failed ({e}); "
                         f"restart {self.restarts}/{self.cfg.max_restarts}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                if self.ckpt.latest_step() is not None:
                    state, step, _ = self.ckpt.restore(
                        jax.eval_shape(lambda: state), shardings=shardings)
                    self.log(f"[supervisor] restored step {step}")
                continue
            dt = time.time() - t0
            if self.detector.observe(step, dt):
                self.log(f"[supervisor] straggler at step {step}: {dt:.3f}s")
                if self.on_straggler is not None:
                    self.on_straggler(step, dt)
            step += 1
            if metrics_cb is not None:
                metrics_cb(step, metrics)
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state)  # async
