"""Adam with optional block-quantized (8-bit) moment states.

Optax-style (init_fn, update_fn) pair, self-contained (no optax dependency in
this environment). The 8-bit variant stores both moments as int8 codes with
per-block fp32 absmax scales (block = 256 flattened elements) — a
quantization-themed distributed-training feature: it is what lets the
480B-parameter arctic config fit 16 GiB/chip on the single-pod mesh
(fp32 m/v would need 22.5 GB/chip; see DESIGN.md §4). Dequant -> fp32 Adam
math -> requant per step keeps the update numerically close to fp32 Adam
(validated in tests/test_optim.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_bits: int = 32  # 32 (fp32 moments) or 8 (block-quantized moments)
    grad_clip_norm: float | None = None


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


# ---- row-wise int8 quantization helpers ------------------------------------
#
# Codes keep the parameter's EXACT shape (int8), scales are per-row along the
# last axis. No flatten/reshape: under GSPMD the moment state inherits the
# parameter's sharding verbatim — a flattened block layout would cross shard
# boundaries and force full rematerialization of multi-hundred-GB buffers
# (observed on the arctic-480B dry-run before this design).


def _q8(x: jnp.ndarray) -> dict:
    if x.ndim == 0:
        x = x[None]
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
        return {"codes": jnp.round(x / scale).astype(jnp.int8)[0],
                "scale": scale.astype(jnp.float32)[0]}
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.round(x / scale).astype(jnp.int8)
    return {"codes": codes, "scale": scale.astype(jnp.float32)}


def _dq8(q: dict, shape) -> jnp.ndarray:
    out = q["codes"].astype(jnp.float32) * q["scale"]
    return out.reshape(shape)


# ---- optimizer --------------------------------------------------------------


def adam(cfg: AdamConfig):
    def init_fn(params):
        if cfg.state_bits == 8:
            zeros = jax.tree.map(lambda p: _q8(jnp.zeros_like(p, jnp.float32)), params)
            zeros2 = jax.tree.map(lambda p: _q8(jnp.zeros_like(p, jnp.float32)), params)
            return AdamState(jnp.zeros((), jnp.int32), zeros, zeros2)
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), z,
                         jax.tree.map(jnp.zeros_like, z))

    def update_fn(grads, state, params):
        step = state.step + 1
        if cfg.grad_clip_norm is not None:
            gn = jnp.sqrt(
                sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)) + 1e-12
            )
            scale = jnp.minimum(1.0, cfg.grad_clip_norm / gn)
            grads = jax.tree.map(lambda g: g * scale, grads)

        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        is_q = cfg.state_bits == 8

        def _leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m_f = _dq8(m, p.shape) if is_q else m
            # v is stored in sqrt-space when quantized: the second moment has
            # a squared dynamic range, and linear int8 would crush small
            # entries (exploding m/sqrt(v)); sqrt-space halves the exponent
            # range, the same trick bitsandbytes' dynamic map approximates.
            v_f = jnp.square(_dq8(v, p.shape)) if is_q else v
            m_f = cfg.b1 * m_f + (1.0 - cfg.b1) * g
            v_f = cfg.b2 * v_f + (1.0 - cfg.b2) * g * g
            upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
            if cfg.weight_decay:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_m = _q8(m_f) if is_q else m_f
            new_v = _q8(jnp.sqrt(v_f)) if is_q else v_f
            return (-cfg.lr * upd).astype(p.dtype), new_m, new_v

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_m = treedef.flatten_up_to(state.m)
        leaves_v = treedef.flatten_up_to(state.v)
        leaves_p = treedef.flatten_up_to(params)
        out = [
            _leaf(g, m, v, p)
            for g, m, v, p in zip(leaves_g, leaves_m, leaves_v, leaves_p)
        ]
        updates = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return updates, AdamState(step, new_m, new_v)

    return init_fn, update_fn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr: float):
    """Plain SGD without momentum — the paper's gate optimizer."""

    def init_fn(params):
        return ()

    def update_fn(grads, state, params):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return init_fn, update_fn
