"""int8 error-feedback gradient compression for the cross-pod reduction.

Distributed-optimization trick (DESIGN.md §4): inside a pod, gradients
reduce over the high-bandwidth ICI mesh in full precision; BETWEEN pods the
links are the scarce resource, so the pod-axis all-reduce runs on int8
block-quantized tensors with an error-feedback (EF-SGD / 1-bit-Adam family)
residual so compression error does not bias convergence:

    send    = quantize8(grad_pod_partial + residual)
    residual' = (grad + residual) - dequant(send)
    grad_out = psum_over_pods(dequant(send)) / n_pods

Implemented with ``shard_map`` over the ``pod`` axis only — the int8 payload
is what crosses pods, visible as an 8-bit collective in the dry-run HLO
(4x fewer inter-pod bytes than fp32, 2x fewer than bf16). The wire format
itself (blockwise symmetric int8) lives with every other integer
storage/wire format in ``repro.quant`` (DESIGN.md §11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.quant import blockwise_int8_decode, blockwise_int8_encode

BLOCK = 256


def _q8_flat(x):
    return blockwise_int8_encode(x, BLOCK)


def _dq8_flat(codes, scale, shape):
    return blockwise_int8_decode(codes, scale, shape)


def compressed_psum_leaf(g, resid, axis: str):
    """One leaf: int8 EF-compressed mean over ``axis``. Returns (g', resid').

    Wire format: all_gather of the int8 codes (+ tiny fp32 block scales),
    then local dequant-accumulate — exact for any per-pod scales, and the
    inter-pod payload is the int8 tensor (4x smaller than fp32 psum traffic).
    """
    comp_in = g.astype(jnp.float32) + resid
    codes, scale = _q8_flat(comp_in)
    deq = _dq8_flat(codes, scale, g.shape)
    new_resid = comp_in - deq
    codes_g = jax.lax.all_gather(codes, axis)       # (npods, nblk, B) int8
    scale_g = jax.lax.all_gather(scale, axis)       # (npods, nblk, 1) fp32
    npods = codes_g.shape[0]
    summed = jnp.einsum(
        "pnb,pnk->nb", codes_g.astype(jnp.float32), scale_g
    )  # dequantized block sums
    n = 1
    for d in g.shape:
        n *= d
    total = summed.reshape(-1)[:n].reshape(g.shape) / npods
    return total.astype(g.dtype), new_resid


def make_compressed_pod_psum(mesh, *, pod_axis: str = "pod"):
    """Returns f(grads, residuals) -> (grads', residuals') using shard_map
    over the pod axis (other axes untouched; apply AFTER intra-pod
    reduction)."""

    def _one(g, r):
        def _local(gl, rl):
            return compressed_psum_leaf(gl, rl, pod_axis)

        # grads replicated over pod at this point of the pipeline
        return shard_map(
            _local, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False,
        )(g, r)

    def apply(grads, residuals):
        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_r = td.flatten_up_to(residuals)
        outs = [_one(g, r) for g, r in zip(flat_g, flat_r)]
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))

    return apply


def init_residuals(grads_template):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)
