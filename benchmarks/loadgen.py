"""Seeded trace generation + replay for serving load tests and benchmarks.

The load harness behind the ``continuous_batching`` bench row and the
trace-replay determinism tests (DESIGN.md §15). Three pieces:

  * ``make_trace`` — a fully seeded open-loop workload: ragged Poisson
    arrivals (exponential inter-arrival times), mixed prompt-length
    buckets, optional prefix-shared bursts (a few common prompt prefixes
    reused by a fraction of requests, exercising the §10 prefix cache
    under load), and a mix of greedy and seeded stochastic sampling.
    Every request carries an EXPLICIT sampling seed, so its token stream
    is a function of the trace alone — slot placement, admission order,
    chunk size and preemption cannot perturb it.
  * ``TickClock`` — an injectable virtual clock for the engine's
    ``clock=`` seam: time only moves when the driver calls ``advance``,
    so a replay is a deterministic function of (trace, engine config) and
    two runs produce identical SLO stamps, not just identical streams.
  * ``replay`` — the open-loop driver: submit each request when the clock
    reaches its arrival, tick the engine between arrivals, fast-forward
    across idle gaps (virtual mode) or sleep them off (wall mode).

The same trace replayed against engines with different ``slots``,
``prefill_chunk_tokens`` or pool sizes must yield identical per-request
streams and finish reasons — that is the stream-equivalence property the
tests pin, and what makes the bench row's throughput numbers comparable
across scheduler configurations.
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival in a load trace. ``arrival_s`` is seconds from trace
    start; ``temperature=0`` rows decode greedily, stochastic rows carry
    their own ``seed`` so replays are reproducible by construction."""

    rid: int
    arrival_s: float
    prompt: np.ndarray
    max_new: int
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int | None = None


class TickClock:
    """Deterministic virtual clock for the engine's injectable ``clock=``
    seam: reading it never advances time — the replay driver moves it by
    ``tick_s`` per engine tick (and across idle gaps). All SLO stamps
    taken against it are exact functions of the trace."""

    def __init__(self, tick_s: float = 1e-3, start: float = 0.0):
        self.tick_s = tick_s
        self.now = start

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float | None = None) -> None:
        self.now += self.tick_s if dt is None else dt


def make_trace(seed: int, n_requests: int, vocab_size: int, *,
               mean_iat_s: float = 0.002,
               plen_buckets=(4, 12, 24, 48),
               bucket_weights=None,
               prefix_groups: int = 3,
               prefix_len: int = 12,
               prefix_fraction: float = 0.25,
               max_new=(2, 12),
               sampled_fraction: float = 0.5,
               temperature: float = 0.8,
               top_p: float = 0.9) -> list[TraceRequest]:
    """Build a seeded open-loop trace of ``n_requests`` arrivals.

    Inter-arrival times are exponential with mean ``mean_iat_s`` (Poisson
    arrivals — the ragged pattern continuous batching exists for). Prompt
    lengths draw from ``plen_buckets`` (uniform unless ``bucket_weights``);
    a ``prefix_fraction`` of requests share one of ``prefix_groups`` common
    prefixes of ``prefix_len`` tokens followed by a random tail.
    ``max_new`` is an inclusive (lo, hi) range; ``sampled_fraction`` of
    requests use seeded stochastic sampling, the rest greedy argmax.
    """
    rng = np.random.default_rng(seed)
    iat = rng.exponential(mean_iat_s, size=n_requests)
    arrivals = np.cumsum(iat)
    weights = None
    if bucket_weights is not None:
        w = np.asarray(bucket_weights, np.float64)
        weights = w / w.sum()
    prefixes = [rng.integers(0, vocab_size, size=prefix_len).astype(np.int32)
                for _ in range(prefix_groups)]
    trace = []
    for rid in range(n_requests):
        plen = int(rng.choice(np.asarray(plen_buckets), p=weights))
        if prefix_groups and rng.random() < prefix_fraction:
            tail = rng.integers(0, vocab_size,
                                size=max(plen - prefix_len, 1))
            prompt = np.concatenate(
                [prefixes[int(rng.integers(prefix_groups))],
                 tail.astype(np.int32)])
        else:
            prompt = rng.integers(0, vocab_size, size=plen).astype(np.int32)
        sampled = rng.random() < sampled_fraction
        trace.append(TraceRequest(
            rid=rid,
            arrival_s=float(arrivals[rid]),
            prompt=prompt,
            max_new=int(rng.integers(max_new[0], max_new[1] + 1)),
            temperature=temperature if sampled else 0.0,
            top_p=top_p if sampled else 1.0,
            seed=int(rng.integers(2 ** 31 - 1))))
    return trace


def _to_request(t: TraceRequest):
    from repro.serving import Request, SamplingParams

    return Request(rid=t.rid, prompt=np.asarray(t.prompt, np.int32),
                   params=SamplingParams(max_new=t.max_new,
                                         temperature=t.temperature,
                                         top_p=t.top_p, seed=t.seed))


def replay(eng, trace, *, clock: TickClock | None = None,
           max_ticks: int = 100_000) -> dict:
    """Open-loop replay of ``trace`` against a ``ServingEngine``.

    With ``clock`` (the SAME ``TickClock`` the engine was constructed
    with) the replay is fully deterministic: each tick advances the clock
    by ``tick_s`` and idle gaps fast-forward to the next arrival. Without
    it, arrivals are paced against the engine's own (wall) clock —
    sleeping through idle gaps — and the SLO stamps measure real latency.

    Returns ``{"requests": {rid: Request}, "ticks", "submitted"}``; drive
    results (tokens / finish reasons) live on the returned requests.
    """
    order = sorted(trace, key=lambda t: (t.arrival_s, t.rid))
    base = clock.now if clock is not None else eng._clock()
    reqs: dict = {}
    i = 0
    ticks = 0
    while True:
        now = (clock.now if clock is not None else eng._clock()) - base
        while i < len(order) and order[i].arrival_s <= now:
            t = order[i]
            reqs[t.rid] = eng.submit(_to_request(t))
            i += 1
        busy = eng.waiting or any(r is not None for r in eng.slot_req)
        if not busy:
            if i >= len(order):
                break
            # engine drained ahead of the trace: jump the idle gap
            gap = order[i].arrival_s - now
            if clock is not None:
                clock.advance(gap)
            else:
                time.sleep(max(gap, 0.0))
            continue
        eng.step()
        if clock is not None:
            clock.advance()
        ticks += 1
        if ticks >= max_ticks:
            raise RuntimeError(
                f"trace replay still running after {max_ticks} ticks "
                f"({i}/{len(order)} submitted)")
    return {"requests": reqs, "ticks": ticks, "submitted": i}


def stream_summary(result: dict) -> dict:
    """Collapse a replay result to comparable per-request terminal state:
    ``{rid: (tokens tuple, finish_reason)}`` — the object two runs of the
    same trace must agree on bit-for-bit."""
    return {rid: (tuple(r.output), r.finish_reason)
            for rid, r in result["requests"].items()}
