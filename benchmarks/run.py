"""Benchmark harness entry point — one function per paper table + kernel
micro-benchmarks + the roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
metric) and writes full tables under artifacts/tables/.

    PYTHONPATH=src python -m benchmarks.run [--tier smoke|quick|paper]
                                            [--skip-tables]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _time(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# ---------------------------------------------------------------------------
# Paper tables (Table 1 / 2 / 3)
# ---------------------------------------------------------------------------


def bench_table1(tier: str):
    """Paper Table 1: CGMQ (dir x granularity) vs FP32 at bound 0.40%."""
    from benchmarks.repro_tables import save_rows, table1

    t0 = time.time()
    rows = table1(tier=tier, log=lambda s: print("   ", s))
    path = save_rows(rows, f"table1_{tier}")
    dt = (time.time() - t0) * 1e6
    best = max((r for r in rows if r.method == "CGMQ"), key=lambda r: r.acc)
    print(f"table1_{tier},{dt:.0f},best_acc={best.acc:.4f}@rbop="
          f"{best.rgbop*100:.3f}%")
    return rows, path


def bench_table_bounds(tier: str, gran: str, tableno: int):
    """Paper Tables 2/3: dir x bound sweeps (layer / indiv gates)."""
    from benchmarks.repro_tables import save_rows, table_bounds

    t0 = time.time()
    rows = table_bounds(gran, tier=tier, log=lambda s: print("   ", s))
    path = save_rows(rows, f"table{tableno}_{tier}")
    dt = (time.time() - t0) * 1e6
    sat = sum(r.satisfied for r in rows)
    print(f"table{tableno}_{tier},{dt:.0f},satisfied={sat}/{len(rows)}")
    return rows, path


# ---------------------------------------------------------------------------
# Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing)
# ---------------------------------------------------------------------------


def bench_fake_quant():
    """Fused fake-quant vs the unfused 5-level residual chain (XLA path).

    On CPU we time the jnp reference paths; the derived metric is the
    bytes-moved ratio the fusion eliminates (the kernel's raison d'etre).
    """
    from repro.core.gates import gated_fake_quant, residual_fake_quant

    x = jnp.asarray(np.random.default_rng(0).normal(size=(2048, 2048)),
                    jnp.float32)
    g = jnp.asarray(2.5)
    b = jnp.asarray(1.0)
    fused = jax.jit(lambda x: gated_fake_quant(x, g, b, True))
    unfused = jax.jit(lambda x: residual_fake_quant(x, g, b, True))
    t_f = _time(fused, x)
    t_u = _time(unfused, x)
    print(f"kernel_fake_quant_fused,{t_f:.0f},speedup_vs_residual="
          f"{t_u/t_f:.2f}x")


def bench_quant_matmul():
    """int8 dequant GEMM (jnp path) vs fp32 GEMM — weight-bytes ratio."""
    from repro.core.quantizer import quantize_to_int
    from repro.kernels.quant_matmul.ref import quant_matmul_ref

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2048, 2048)), jnp.float32)
    codes, scale, bias = quantize_to_int(w, 8, jnp.max(jnp.abs(w), axis=0), True)
    qmm = jax.jit(lambda x: quant_matmul_ref(x, codes, scale, bias))
    mm = jax.jit(lambda x: x @ w)
    t_q = _time(qmm, x)
    t_m = _time(mm, x)
    print(f"kernel_quant_matmul,{t_q:.0f},weight_bytes_ratio=0.25"
          f";fp32_ref_us={t_m:.0f}")


def bench_flash_attention():
    """Interpret-mode flash attention vs dense reference (correctness run)."""
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.flash_attention.ref import attention_ref

    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    t_ref = _time(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v,
                  iters=3, warmup=1)
    got = flash_attention_op(q, k, v)
    want = attention_ref(q, k, v)
    err = float(jnp.abs(got - want).max())
    print(f"kernel_flash_attention,{t_ref:.0f},interpret_max_err={err:.2e}")


# ---------------------------------------------------------------------------
# Roofline summary (reads dry-run artifacts)
# ---------------------------------------------------------------------------


def bench_roofline():
    from benchmarks.roofline_report import load_records

    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    if not ok:
        print("roofline,0,no_dryrun_artifacts")
        return
    fracs = [r["roofline"]["roofline_fraction"] for r in ok
             if r["roofline"].get("roofline_fraction")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    med = float(np.median(fracs)) if fracs else 0.0
    print(f"roofline,{len(ok)},cells_ok={len(ok)}/{len(recs)};"
          f"median_train_roofline_frac={med*100:.1f}%;dominants={doms}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="smoke",
                    choices=["smoke", "quick", "paper"])
    ap.add_argument("--skip-tables", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    bench_fake_quant()
    bench_quant_matmul()
    bench_flash_attention()
    if not args.skip_tables:
        bench_table1(args.tier)
        bench_table_bounds(args.tier, "layer", 2)
        bench_table_bounds(args.tier, "indiv", 3)
    bench_roofline()


if __name__ == "__main__":
    main()
