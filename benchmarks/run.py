"""Benchmark harness entry point — one function per paper table + kernel
micro-benchmarks + serving throughput + the roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
metric) and writes full tables under artifacts/tables/. With ``--json``,
serving throughput (prefill/decode tok/s, time-to-first-token, prefill
forward counts vs the seed scan-of-decode-steps) and the kernel micro-bench
numbers are written to ``BENCH_serving.json``, and training-engine
throughput (steps/s, host syncs per epoch, scan vs python-loop speedup) to
``BENCH_training.json``, so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--tier smoke|quick|paper]
                                            [--skip-tables] [--json [PATH]]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _time(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# ---------------------------------------------------------------------------
# Paper tables (Table 1 / 2 / 3)
# ---------------------------------------------------------------------------


def bench_table1(tier: str):
    """Paper Table 1: CGMQ (dir x granularity) vs FP32 at bound 0.40%."""
    from benchmarks.repro_tables import save_rows, table1

    t0 = time.time()
    rows = table1(tier=tier, log=lambda s: print("   ", s))
    path = save_rows(rows, f"table1_{tier}")
    dt = (time.time() - t0) * 1e6
    best = max((r for r in rows if r.method == "CGMQ"), key=lambda r: r.acc)
    print(f"table1_{tier},{dt:.0f},best_acc={best.acc:.4f}@rbop="
          f"{best.rgbop*100:.3f}%")
    return rows, path


def bench_table_bounds(tier: str, gran: str, tableno: int):
    """Paper Tables 2/3: dir x bound sweeps (layer / indiv gates)."""
    from benchmarks.repro_tables import save_rows, table_bounds

    t0 = time.time()
    rows = table_bounds(gran, tier=tier, log=lambda s: print("   ", s))
    path = save_rows(rows, f"table{tableno}_{tier}")
    dt = (time.time() - t0) * 1e6
    sat = sum(r.satisfied for r in rows)
    print(f"table{tableno}_{tier},{dt:.0f},satisfied={sat}/{len(rows)}")
    return rows, path


# ---------------------------------------------------------------------------
# Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing)
# ---------------------------------------------------------------------------


def bench_fake_quant():
    """Fused fake-quant vs the unfused 5-level residual chain (XLA path).

    On CPU we time the jnp reference paths; the derived metric is the
    bytes-moved ratio the fusion eliminates (the kernel's raison d'etre).
    """
    from repro.core.gates import gated_fake_quant, residual_fake_quant

    x = jnp.asarray(np.random.default_rng(0).normal(size=(2048, 2048)),
                    jnp.float32)
    g = jnp.asarray(2.5)
    b = jnp.asarray(1.0)
    fused = jax.jit(lambda x: gated_fake_quant(x, g, b, True))
    unfused = jax.jit(lambda x: residual_fake_quant(x, g, b, True))
    t_f = _time(fused, x)
    t_u = _time(unfused, x)
    print(f"kernel_fake_quant_fused,{t_f:.0f},speedup_vs_residual="
          f"{t_u/t_f:.2f}x")
    return {"fused_us": t_f, "residual_us": t_u, "speedup_x": t_u / t_f}


def bench_quant_matmul():
    """int8 dequant GEMM (jnp path) vs fp32 GEMM — weight-bytes ratio."""
    from repro.core.quantizer import quantize_to_int
    from repro.kernels.quant_matmul.ref import quant_matmul_ref

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2048, 2048)), jnp.float32)
    codes, scale, bias = quantize_to_int(w, 8, jnp.max(jnp.abs(w), axis=0), True)
    qmm = jax.jit(lambda x: quant_matmul_ref(x, codes, scale, bias))
    mm = jax.jit(lambda x: x @ w)
    t_q = _time(qmm, x)
    t_m = _time(mm, x)
    print(f"kernel_quant_matmul,{t_q:.0f},weight_bytes_ratio=0.25"
          f";fp32_ref_us={t_m:.0f}")
    return {"int8_ref_us": t_q, "fp32_us": t_m, "weight_bytes_ratio": 0.25}


def bench_flash_attention():
    """Interpret-mode flash attention vs dense reference (correctness run)."""
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.flash_attention.ref import attention_ref

    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    t_ref = _time(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, v,
                  iters=3, warmup=1)
    got = flash_attention_op(q, k, v)
    want = attention_ref(q, k, v)
    err = float(jnp.abs(got - want).max())
    print(f"kernel_flash_attention,{t_ref:.0f},interpret_max_err={err:.2e}")
    return {"dense_ref_us": t_ref, "interpret_max_err": err}


# ---------------------------------------------------------------------------
# Serving throughput (prefill / decode / TTFT)
# ---------------------------------------------------------------------------


def _serving_run(cfg, params, *, quant_state=None, slots=4, plen=12,
                 max_new=16, nreq=8, kv_layout="auto", same_prefix=False,
                 max_seq=64, sample=None, kv_dtype="bf16", act_bits=None):
    """One measured engine pass. Compiles on a throwaway request first so the
    numbers reflect steady-state serving, not jit tracing. With
    ``same_prefix`` every request reuses ONE prompt, exercising the paged
    prefix cache (N admissions ~ 1 prefill, DESIGN.md §10). ``sample``
    (e.g. ``dict(temperature=0.8, top_p=0.9)``) runs the in-tick stochastic
    sampling path instead of greedy argmax (DESIGN.md §12); per-request
    seeds keep the run reproducible."""
    from repro.serving import Request, SamplingParams, ServingEngine

    eng = ServingEngine(cfg, params, slots=slots, max_seq=max_seq,
                        quant_state=quant_state, kv_layout=kv_layout,
                        kv_dtype=kv_dtype, act_bits=act_bits)
    rng = np.random.default_rng(7)
    warm_sp = SamplingParams(max_new=2, **(sample or {}))
    eng.generate([rng.integers(0, cfg.vocab_size, (plen,))], warm_sp)
    eng.finished.clear()
    eng.stats = {k: 0 if isinstance(v, int) else 0.0
                 for k, v in eng.stats.items()}

    shared_prompt = rng.integers(0, cfg.vocab_size, (plen,))

    def _prompt():
        return (shared_prompt if same_prefix
                else rng.integers(0, cfg.vocab_size, (plen,)))

    def _params(i):
        return SamplingParams(max_new=max_new, seed=i, **(sample or {}))

    for i in range(nreq):
        eng.submit(Request(rid=i, prompt=_prompt(), params=_params(i)))
    blocks_hwm = 0
    ticks = 0
    while (eng.waiting or any(r is not None for r in eng.slot_req)) \
            and ticks < 1000:  # same bound as run_to_completion
        if not eng.step():
            break
        ticks += 1
        if eng.paged and eng.stats["decode_ticks"] == 1:
            blocks_hwm = eng.pool_stats()["blocks_in_use"]
    fin = eng.finished
    assert len(fin) == nreq
    st = eng.stats
    # SLO latencies from per-request arrival stamps (DESIGN.md §15): each
    # TTFT runs from ITS OWN submit, not engine start, so queue wait is in
    # the number and percentiles stay meaningful under ragged admission
    slo = eng.slo_stats()
    decode_tokens = st["generated_tokens"] - nreq
    # every model forward an admission costs: the batched prefill(s) plus
    # teacher-forced steps (prefix-shared sub-block replays) and SSM tail
    # forwards — dividing by prefills alone would overstate the reduction
    # on the prefix-sharing workload
    admission_forwards = (st["prefill_forwards"] + st["teacher_steps"]
                          + st["tail_forwards"])
    out = {
        "slots": slots,
        "requests": nreq,
        "prompt_len": plen,
        "max_new": max_new,
        "kv_layout": eng.kv_layout,
        "sampling": sample or "argmax",
        # the §8/§12 ledger: the tick must cost exactly ONE host transfer,
        # sampling enabled or not (CI-asserted from BENCH_serving.json)
        "host_syncs_per_tick":
            st["tick_syncs"] / max(st["decode_ticks"], 1),
        "ttft_s": slo["ttft_s"]["mean"],
        "slo": slo,
        "prefill_tok_s": st["prompt_tokens"] / max(st["prefill_time_s"], 1e-9),
        "decode_tok_s": decode_tokens / max(st["decode_time_s"], 1e-9),
        "prefill_forwards": st["prefill_forwards"],
        "seed_equiv_forwards": st["seed_equiv_forwards"],
        # seed prefill ran one decode forward per prompt token, each `slots`
        # wide; the batched path runs ONE single-row forward per admission.
        "admission_forwards": admission_forwards,
        "model_forward_reduction_x":
            st["seed_equiv_forwards"] / max(admission_forwards, 1),
        "slot_forward_reduction_x":
            st["seed_equiv_forwards"] * slots / max(admission_forwards, 1),
        "int8_sites": len(eng.qweights),
    }
    if eng.export_ledger is not None:
        # bytes/BOPs ledger of the artifact this run actually served
        out["quant_report"] = eng.quant_report()
    if eng.kv_spec is not None:
        # §14 KV-cache footprint: ceil-packed bytes/cached-token vs the
        # bf16 and fp32 float pools of the same geometry
        out["kv_report"] = eng.kv_report()
    if eng.paged:
        ps = eng.pool_stats()
        out.update({
            "block_size": ps["block_size"],
            "num_blocks": ps["num_blocks"],
            "blocks_in_use_early": blocks_hwm,
            "prefix_hit_rate": ps["prefix_hit_rate"],
            "shared_admissions": st["shared_admissions"],
            "cow_copies": st["cow_copies"],
        })
    return out


def _chaos_run(cfg, params, *, slots=4, plen=12, max_new=24, nreq=4,
               extra=2):
    """Serving-under-pressure smoke (DESIGN.md §13): the same seeded
    workload is run once solo-per-request on an ample pool (the reference
    streams) and once on a pool too small for the offered load with a
    bounded queue. The pressured run must preempt, resume every victim to
    a BIT-IDENTICAL stream, bounce the over-capacity submissions with
    ``FINISHED_REJECTED``, and keep the tick at one host sync."""
    from repro.serving import (FINISHED_LENGTH, FINISHED_REJECTED,
                               AdmissionConfig, Request, SamplingParams,
                               ServingEngine)

    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (plen,))
               for _ in range(nreq + extra)]
    sps = [SamplingParams(temperature=0.8, top_p=0.9, seed=100 + i,
                          max_new=max_new) for i in range(nreq + extra)]

    solo = ServingEngine(cfg, params, slots=2, max_seq=64)
    ref = []
    for i in range(nreq):
        r = solo.submit(Request(rid=i, prompt=prompts[i], params=sps[i]))
        while not r.done:
            solo.step()
        ref.append(list(r.output))

    # 14 blocks can't back 4 slots at max_seq=64 (needs 33): preemption
    # auto-enables; queue capacity nreq bounces the extra submissions
    eng = ServingEngine(cfg, params, slots=slots, max_seq=64, num_blocks=14,
                        admission=AdmissionConfig(queue_capacity=nreq,
                                                  on_full="reject"))
    reqs = [eng.submit(Request(rid=i, prompt=prompts[i], params=sps[i]))
            for i in range(nreq + extra)]
    ticks = 0
    while (eng.waiting or any(r is not None for r in eng.slot_req)) \
            and ticks < 2000:
        eng.step()
        ticks += 1
    st = eng.stats
    served = [r for r in reqs if r.finish_reason == FINISHED_LENGTH]
    assert len(served) == nreq and all(r.done for r in reqs)
    return {
        "requests": nreq + extra,
        "num_blocks": 14,
        "preemptions": st["preemptions"],
        "resumed_admissions": st["resumed_admissions"],
        "preempted_stream_equal": bool(all(
            list(r.output) == ref[i] for i, r in enumerate(reqs[:nreq]))),
        "rejected_requests": st["rejected_requests"],
        "rejected_expected": sum(
            r.finish_reason == FINISHED_REJECTED for r in reqs),
        "host_syncs_per_tick":
            st["tick_syncs"] / max(st["decode_ticks"], 1),
        "blocks_leaked": eng.pool_stats()["blocks_in_use"],
    }


def _continuous_batching_run(cfg, params, *, slots=40, n_requests=48,
                             max_seq=64, chunk=8):
    """Continuous batching under trace-replay load (DESIGN.md §15): a
    seeded open-loop trace — ragged Poisson arrivals, mixed prompt-length
    buckets, prefix-shared bursts, mixed greedy/seeded-stochastic sampling
    — replayed against the chunked-prefill scheduler at 10x the smoke
    wave geometry's slot count. SLO latencies (TTFT/TPOT p50/p95/p99) come
    from per-request arrival stamps via ``slo_stats``; CI asserts the one-
    sync-per-tick ledger, a drained pool and a TTFT p95 smoke bound off
    this row."""
    from benchmarks.loadgen import make_trace, replay
    from repro.serving import SamplingParams, ServingEngine

    eng = ServingEngine(cfg, params, slots=slots, max_seq=max_seq,
                        prefill_chunk_tokens=chunk)
    rng = np.random.default_rng(21)
    # warm the jit caches (chunk prefill, armed decode, admission sync) so
    # the replay measures steady-state serving, not tracing
    eng.generate([rng.integers(0, cfg.vocab_size, (17,))],
                 SamplingParams(max_new=3, temperature=0.8, seed=1))
    eng.finished.clear()
    eng.stats = {k: 0 if isinstance(v, int) else 0.0
                 for k, v in eng.stats.items()}

    trace = make_trace(33, n_requests, cfg.vocab_size, mean_iat_s=0.003,
                       plen_buckets=(4, 12, 24, 48),
                       bucket_weights=(1, 3, 3, 1),
                       prefix_groups=3, prefix_len=12, prefix_fraction=0.25,
                       max_new=(2, 12), sampled_fraction=0.5)
    t0 = time.perf_counter()
    res = replay(eng, trace)
    wall = time.perf_counter() - t0
    reqs = list(res["requests"].values())
    assert len(reqs) == n_requests and all(r.done for r in reqs)
    st = eng.stats
    slo = eng.slo_stats()
    ps = eng.pool_stats()
    return {
        "slots": slots,
        "requests": n_requests,
        "max_seq": max_seq,
        "prefill_chunk_tokens": chunk,
        "tick_token_budget": eng.tick_token_budget,
        "prefill_chunks": st["prefill_chunks"],
        "ticks": res["ticks"],
        "wall_s": wall,
        "generated_tokens": st["generated_tokens"],
        "decode_tok_s": (st["generated_tokens"] - len(reqs))
        / max(st["decode_time_s"], 1e-9),
        "prefill_tok_s":
            st["prompt_tokens"] / max(st["prefill_time_s"], 1e-9),
        "host_syncs_per_tick":
            st["tick_syncs"] / max(st["decode_ticks"], 1),
        "ttft_s": slo["ttft_s"],
        "tpot_s": slo["tpot_s"],
        "preemptions": st["preemptions"],
        "prefix_hit_rate": ps["prefix_hit_rate"],
        "blocks_leaked": ps["blocks_in_use"] - ps["retained_blocks"],
    }


def _long_context_run(cfg, params, *, prompt_tokens=32_768, window=1024,
                      sink_blocks=1, block_size=8, chunk=512, max_new=8):
    """Long-context serving on a window-sized pool (DESIGN.md §17): one
    32k-token synthetic prompt decodes through a pool holding only the
    window demand — ~1/25th of the block-table width — because chunked
    prefill evicts out-of-window KV blocks in-tick as it streams forward.
    ``peak_blocks_in_use`` is sampled every engine step (prefill ticks
    included, where residency peaks at live-set + one chunk); CI asserts
    ``peak <= bound``, the one-sync-per-tick ledger and a drained pool."""
    from repro.serving import (SamplingParams, ServingEngine, WindowSpec,
                               window_demand_blocks)
    from repro.serving.engine import Request

    spec = WindowSpec(window=window, sink_blocks=sink_blocks)
    max_seq = prompt_tokens + max_new + block_size
    max_blocks = -(-max_seq // block_size)
    demand = window_demand_blocks(spec.bind(block_size), max_blocks,
                                  chunk, block_size)
    num_blocks = demand + 1  # + garbage block: the engine's floor exactly
    eng = ServingEngine(cfg, params, slots=1, max_seq=max_seq,
                        block_size=block_size, num_blocks=num_blocks,
                        prefill_chunk_tokens=chunk,
                        attention_window=spec)
    rng = np.random.default_rng(29)
    prompt = rng.integers(0, cfg.vocab_size, (prompt_tokens,))
    eng.submit(Request(rid=0, prompt=prompt,
                       params=SamplingParams(temperature=0.0,
                                             max_new=max_new)))
    t0 = time.perf_counter()
    peak = 0
    steps = 0
    # drive tick-by-tick so residency is sampled DURING chunked prefill,
    # where the §17 peak (live set + one chunk) actually occurs
    while eng.waiting or any(r is not None for r in eng.slot_req):
        eng.step()
        peak = max(peak, eng.pool_stats()["blocks_in_use"])
        steps += 1
        assert steps < 10_000
    wall = time.perf_counter() - t0
    st = eng.stats
    ps = eng.pool_stats()
    req = eng.finished[-1]
    assert len(req.output) == max_new, req.finish_reason
    return {
        "prompt_tokens": prompt_tokens,
        "window": window,
        "sink_blocks": sink_blocks,
        "num_blocks": num_blocks,
        "table_blocks": max_blocks,
        "peak_blocks_in_use": peak,
        "bound": demand,
        "window_report": ps["window"],
        "prefill_chunks": st["prefill_chunks"],
        "wall_s": wall,
        "decode_tok_s": (st["generated_tokens"] - 1)
        / max(st["decode_time_s"], 1e-9),
        "prefill_tok_s":
            st["prompt_tokens"] / max(st["prefill_time_s"], 1e-9),
        "host_syncs_per_tick":
            st["tick_syncs"] / max(st["decode_ticks"], 1),
        "blocks_leaked": ps["blocks_in_use"] - ps["retained_blocks"],
    }


def _kv_oracle_err(cfg, params, kv_dtype, plen=9, steps=4):
    """Max |logit| gap of a teacher-forced paged decode under quantized KV
    vs the fp32 float-pool oracle — same tokens, same block geometry, so
    the gap isolates KV storage error (DESIGN.md §14)."""
    import math

    from repro.core.sites import QuantContext
    from repro.models import transformer as tfm
    from repro.quant import KVQuantSpec
    from repro.serving import kv_pool

    spec = KVQuantSpec(bits=8 if kv_dtype == "int8" else 4,
                       group_size=math.gcd(cfg.head_dim, 32),
                       head_dim=cfg.head_dim)
    qc = QuantContext(mode="off")
    bs, max_seq = 8, 32
    x = jax.random.randint(jax.random.PRNGKey(1), (1, plen), 0,
                           cfg.vocab_size)
    rng = np.random.default_rng(2)
    toks = [int(rng.integers(0, cfg.vocab_size)) for _ in range(steps)]
    outs = []
    for kv_spec in (None, spec):
        mb = max_seq // bs
        cache = tfm.init_paged_cache(
            cfg, 1, mb + 1, bs,
            kv_dtype=jnp.float32 if kv_spec is None else jnp.bfloat16,
            kv_spec=kv_spec)
        alloc = kv_pool.init_alloc(mb + 1, 1, mb)
        alloc = kv_pool.alloc_range(alloc, 0, 0, -(-plen // bs))
        lg, cache = tfm.prefill_slot(qc, params, x, plen, cache, 0, cfg,
                                     block_table=alloc["table"])
        rows = [np.asarray(lg[0, plen - 1, : cfg.vocab_size])]
        adv = jnp.ones((1,), jnp.int32)
        for t in toks:
            alloc = kv_pool.tick_alloc(alloc, cache["pos"], adv, bs)
            lg, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([t], jnp.int32), cfg,
                                        advance=adv,
                                        block_table=alloc["table"])
            rows.append(np.asarray(lg[0, 0, : cfg.vocab_size]))
        outs.append(np.stack(rows))
    return float(np.abs(outs[0] - outs[1]).max())


def bench_serving(tier: str):
    """Serving engine throughput on the smoke LM: fp32 and int8 paths."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import make_uniform_quant_state

    nreq = {"smoke": 8, "quick": 16, "paper": 32}.get(tier, 8)
    cfg = get_smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))

    t0 = time.time()
    fp32 = _serving_run(cfg, params, nreq=nreq)
    print(f"serving_fp32,{fp32['decode_tok_s']:.0f},ttft_ms="
          f"{fp32['ttft_s']*1e3:.1f};prefill_tok_s="
          f"{fp32['prefill_tok_s']:.0f};forward_reduction="
          f"{fp32['model_forward_reduction_x']:.1f}x")
    # ring baseline on the same workload: the paged layout pays block-table
    # gather/scatter overhead on unshared traffic (bought back by prefix
    # sharing + block-granular memory); tracking both keeps the §8 perf
    # trajectory honest about that tradeoff.
    ring = _serving_run(cfg, params, nreq=nreq, kv_layout="ring")
    print(f"serving_fp32_ring,{ring['decode_tok_s']:.0f},ttft_ms="
          f"{ring['ttft_s']*1e3:.1f};paged_vs_ring_decode="
          f"{fp32['decode_tok_s']/max(ring['decode_tok_s'],1e-9):.2f}x")

    qs = make_uniform_quant_state(cfg, params)  # T(2.2) = 8 bits
    int8 = _serving_run(cfg, params, quant_state=qs, nreq=nreq)
    print(f"serving_int8,{int8['decode_tok_s']:.0f},ttft_ms="
          f"{int8['ttft_s']*1e3:.1f};int8_sites={int8['int8_sites']}")

    # fully-integer decode (DESIGN.md §16): calibrated per-tensor ``.in``
    # activation specs route every exported site through the int8×int8
    # integer-accumulation GEMM. CI asserts from BENCH_serving.json that the
    # row exists, the tick still costs exactly ONE host sync, and the BOP
    # certificate covers every activation site (acts.covered == acts.total).
    intgemm = _serving_run(cfg, params, quant_state=qs, nreq=nreq,
                           act_bits=8)
    acts = intgemm["quant_report"]["acts"]
    intgemm["bops_vs_int_weight_fp32_act"] = (
        intgemm["quant_report"]["bops"]["model"]
        / max(int8["quant_report"]["bops"]["model"], 1e-9))
    print(f"serving_int_gemm_decode,{intgemm['decode_tok_s']:.0f},"
          f"vs_fp32_act={intgemm['decode_tok_s']/max(int8['decode_tok_s'],1e-9):.2f}x;"
          f"act_sites={acts['covered']}/{acts['total']};"
          f"bops_model={intgemm['quant_report']['bops']['model']:.3g};"
          f"host_syncs_per_tick={intgemm['host_syncs_per_tick']:.2f}")

    # mixed 2/4/8-bit export: packed sub-byte storage (DESIGN.md §11). The
    # quant_report ledger in BENCH_serving.json is CI-asserted: packed
    # bytes/weight must land strictly below the uniform-int8 baseline.
    from repro.serving.engine import make_mixed_quant_state

    qs_mixed = make_mixed_quant_state(cfg, params)
    mixed = _serving_run(cfg, params, quant_state=qs_mixed, nreq=nreq)
    t = mixed["quant_report"]["totals"]
    print(f"serving_mixed_sub_byte,{mixed['decode_tok_s']:.0f},"
          f"bytes_per_weight={t['bytes_per_weight']:.3f};"
          f"vs_int8={t['bytes_per_weight']/t['uniform_int8_bytes_per_weight']:.2f}x;"
          f"rbop={mixed['quant_report']['bops']['rbop']*100:.2f}%")

    # sampled decode (DESIGN.md §12): the in-tick temperature/top-p path vs
    # the argmax baseline above, same workload. host_syncs_per_tick must
    # stay at exactly 1.0 in both (CI-asserted) — sampling lives inside the
    # jitted tick, it is not allowed to buy tokens with extra host traffic.
    sampled = _serving_run(cfg, params, nreq=nreq,
                           sample=dict(temperature=0.8, top_p=0.9))
    print(f"serving_sampled_decode,{sampled['decode_tok_s']:.0f},"
          f"vs_argmax={sampled['decode_tok_s']/max(fp32['decode_tok_s'],1e-9):.2f}x;"
          f"host_syncs_per_tick={sampled['host_syncs_per_tick']:.2f}")

    # paged-KV additions (DESIGN.md §10): decode throughput at a high slot
    # count, and same-prefix admission cost through the prefix cache.
    hi_slots = {"smoke": 16, "quick": 24, "paper": 32}.get(tier, 16)
    high = _serving_run(cfg, params, slots=hi_slots, nreq=2 * hi_slots,
                        max_new=8)
    print(f"serving_paged_high_slots,{high['decode_tok_s']:.0f},slots="
          f"{hi_slots};blocks_in_use={high['blocks_in_use_early']}")
    prefix = _serving_run(cfg, params, slots=8, nreq=nreq, plen=16,
                          same_prefix=True)
    print(f"serving_prefix_sharing,{prefix['decode_tok_s']:.0f},"
          f"prefills_for_{nreq}_same_prefix_reqs="
          f"{prefix['prefill_forwards']};hit_rate="
          f"{prefix['prefix_hit_rate']:.2f}")
    # quantized KV blocks (DESIGN.md §14): int8 (and packed int4) group-wise
    # codes with fused dequant in the paged-attention kernel. kv_report
    # gives ceil-packed bytes/cached-token; slots_at_bf16_pool_bytes is how
    # many concurrent slots the SAME pool byte budget backs vs bf16; the
    # logits error is a teacher-forced paged decode vs the fp32 float-pool
    # oracle. CI asserts the bytes ratio, the error bound, and one host
    # sync per tick from BENCH_serving.json.
    kv_rows = {}
    for name, kvd in (("kv_int8", "int8"), ("kv_int4", "int4")):
        row = _serving_run(cfg, params, nreq=nreq, kv_dtype=kvd)
        rep = row["kv_report"]
        row["bytes_per_cached_token"] = rep["bytes_per_cached_token"]
        row["slots_at_bf16_pool_bytes"] = int(
            row["slots"] / max(rep["vs_bf16"], 1e-9))
        row["logits_max_abs_err"] = _kv_oracle_err(cfg, params, kvd)
        print(f"serving_{name},{row['decode_tok_s']:.0f},"
              f"bytes_per_cached_token={rep['bytes_per_cached_token']};"
              f"vs_bf16={rep['vs_bf16']:.3f};vs_fp32={rep['vs_fp32']:.3f};"
              f"slots_at_bf16_pool_bytes={row['slots_at_bf16_pool_bytes']};"
              f"logits_max_abs_err={row['logits_max_abs_err']:.2e};"
              f"host_syncs_per_tick={row['host_syncs_per_tick']:.2f}")
        kv_rows[name] = row

    # serving under pressure (DESIGN.md §13): undersized pool + bounded
    # queue; preemption must happen, every resumed stream must be
    # bit-identical to its solo reference, overflow must bounce as typed
    # rejections, and the tick stays at ONE host sync (CI-asserted).
    chaos = _chaos_run(cfg, params)
    print(f"serving_chaos,{chaos['preemptions']},"
          f"stream_equal={chaos['preempted_stream_equal']};"
          f"rejected={chaos['rejected_requests']};"
          f"host_syncs_per_tick={chaos['host_syncs_per_tick']:.2f}")

    # continuous batching under trace-replay load (DESIGN.md §15): chunked
    # prefill interleaved with decode at 10x the smoke wave geometry.
    cont = _continuous_batching_run(cfg, params)
    print(f"serving_continuous_batching,{cont['decode_tok_s']:.0f},"
          f"slots={cont['slots']};requests={cont['requests']};"
          f"prefill_chunks={cont['prefill_chunks']};"
          f"ttft_p95_ms={cont['ttft_s']['p95']*1e3:.1f};"
          f"tpot_p95_ms={cont['tpot_s']['p95']*1e3:.1f};"
          f"host_syncs_per_tick={cont['host_syncs_per_tick']:.2f};"
          f"blocks_leaked={cont['blocks_leaked']}")
    # long-context serving (DESIGN.md §17): a 32k-token prompt decodes on a
    # pool sized for the attention window — in-tick out-of-window eviction
    # keeps residency O(window) while the block table spans the full prompt.
    # CI asserts peak_blocks_in_use <= bound, one host sync per tick, and a
    # drained pool from BENCH_serving.json.
    longctx = _long_context_run(cfg, params)
    print(f"serving_long_context,{longctx['decode_tok_s']:.0f},"
          f"prompt_tokens={longctx['prompt_tokens']};"
          f"window={longctx['window']};"
          f"peak_blocks_in_use={longctx['peak_blocks_in_use']}"
          f"/{longctx['bound']};"
          f"table_blocks={longctx['table_blocks']};"
          f"prefill_tok_s={longctx['prefill_tok_s']:.0f};"
          f"host_syncs_per_tick={longctx['host_syncs_per_tick']:.2f};"
          f"blocks_leaked={longctx['blocks_leaked']}")
    total_reqs = (5 * nreq + 2 * hi_slots + nreq + chaos["requests"]
                  + cont["requests"] + 1)
    print(f"serving_total,{(time.time()-t0)*1e6:.0f},"
          f"requests={total_reqs}")
    return {"fp32": fp32, "fp32_ring": ring, "int8": int8,
            "int_gemm_decode": intgemm,
            "mixed_sub_byte": mixed, "sampled_decode": sampled,
            "paged_high_slots": high, "prefix_sharing": prefix,
            **kv_rows, "chaos": chaos, "continuous_batching": cont,
            "long_context": longctx}


# ---------------------------------------------------------------------------
# Training engine throughput (scan epochs vs python-loop reference)
# ---------------------------------------------------------------------------


def bench_training(tier: str):
    """CGMQ stage-4 throughput on LeNet: jitted-scan epochs vs the per-batch
    python dispatch reference. Same staging + step functions, so the speedup
    is pure dispatch/host-sync overhead removed by the scan engine. Two
    regimes: the tier's batch size (compute-bound: the scan win is small on
    CPU and grows with dispatch cost) and a small-batch dispatch-bound config
    where the scan advantage dominates."""
    from benchmarks.repro_tables import _data, _pcfg, get_bundle
    from repro.core import bop as bop_lib
    from repro.core.controller import CGMQConfig
    from repro.core.pipeline import steps_per_epoch
    from repro.models import lenet
    from repro.train import EngineConfig, TrainEngine

    epochs = {"smoke": 6, "quick": 8, "paper": 10}.get(tier, 6)
    bundle = get_bundle(tier, "layer", log=lambda s: None)
    train, test = _data(tier)
    pcfg = _pcfg(tier, log=lambda s: None)

    def _measure(batch_size):
        spe = steps_per_epoch(train[0].shape[0], batch_size)
        ccfg = CGMQConfig(budget_rbop=0.02, direction="dir1", gate_lr=0.01,
                          check_every=spe)
        res = {"steps_per_epoch": spe, "batch_size": batch_size,
               "epochs": epochs}
        for loop in ("scan", "python"):
            eng = TrainEngine(
                lenet.forward,
                EngineConfig(batch_size=batch_size, lr=pcfg.lr,
                             eval_every=epochs, loop=loop,
                             log=lambda s: None),
                qcfg=bundle.qcfg)
            eng.bind_sites(bundle.sites, bundle.signed)
            eng.bind_controller(ccfg,
                                bop_lib.budget_from_rbop(bundle.sites, 0.02))
            state = eng.init_quant_state(bundle.params, bundle.betas,
                                         bundle.gates, bundle.probes, seed=0)
            state, _ = eng.run_stage(state, "cgmq", train, 1)  # compile warmup
            syncs0 = eng.host_syncs
            t0 = time.perf_counter()
            state, _ = eng.run_stage(state, "cgmq", train, 1 + epochs,
                                     start_epoch=1)
            dt = time.perf_counter() - t0
            res[loop] = {
                "seconds": dt,
                "steps_per_s": epochs * spe / dt,
                "host_syncs_per_epoch": (eng.host_syncs - syncs0) / epochs,
            }
        res["scan_speedup_x"] = (res["scan"]["steps_per_s"]
                                 / res["python"]["steps_per_s"])
        return res

    out = {
        "compute_bound": _measure(pcfg.batch_size),
        "dispatch_bound": _measure(8),
    }
    for name, res in out.items():
        print(f"training_scan_{name},"
              f"{res['scan']['seconds']/epochs/res['steps_per_epoch']*1e6:.0f},"
              f"steps_per_s={res['scan']['steps_per_s']:.1f};"
              f"speedup_vs_python_loop={res['scan_speedup_x']:.2f}x;"
              f"host_syncs_per_epoch={res['scan']['host_syncs_per_epoch']:.2f}")
    return out


# ---------------------------------------------------------------------------
# Roofline summary (reads dry-run artifacts)
# ---------------------------------------------------------------------------


def bench_roofline():
    from benchmarks.roofline_report import load_records

    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    if not ok:
        print("roofline,0,no_dryrun_artifacts")
        return
    fracs = [r["roofline"]["roofline_fraction"] for r in ok
             if r["roofline"].get("roofline_fraction")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    med = float(np.median(fracs)) if fracs else 0.0
    print(f"roofline,{len(ok)},cells_ok={len(ok)}/{len(recs)};"
          f"median_train_roofline_frac={med*100:.1f}%;dominants={doms}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="smoke",
                    choices=["smoke", "quick", "paper"])
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--json", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="write serving + kernel numbers to PATH "
                         "(default BENCH_serving.json)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    kernels = {
        "fake_quant": bench_fake_quant(),
        "quant_matmul": bench_quant_matmul(),
        "flash_attention": bench_flash_attention(),
    }
    serving = bench_serving(args.tier)
    training = bench_training(args.tier)
    if not args.skip_tables:
        bench_table1(args.tier)
        bench_table_bounds(args.tier, "layer", 2)
        bench_table_bounds(args.tier, "indiv", 3)
    bench_roofline()

    if args.json:
        import json

        payload = {
            "schema": 1,
            "tier": args.tier,
            "backend": jax.default_backend(),
            "serving": serving,
            "kernels": kernels,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")

        tpath = "BENCH_training.json"
        tpayload = {
            "schema": 1,
            "tier": args.tier,
            "backend": jax.default_backend(),
            "training": training,
        }
        with open(tpath, "w") as f:
            json.dump(tpayload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
