"""§Perf hillclimbing driver: run named variants of selected cells and
print the roofline deltas (hypothesis -> change -> before -> after).

    PYTHONPATH=src python -m benchmarks.hillclimb --cell tinyllama-train
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Each variant: (name, run_cell kwargs). 'base' comes from the main sweep.
CELLS = {
    # memory-bound dense train cell; the fake-quant fusion story
    "tinyllama-train": dict(
        arch="tinyllama-1.1b", shape="train_4k", multi_pod=False,
        variants=[
            ("paperfaithful", dict(quant_impl="residual")),
            ("noquant", dict(recipe_overrides=dict(quant_enabled=False))),
            ("gatherbf16", dict(recipe_overrides=dict(
                gather_dtype="bfloat16"))),
        ],
    ),
    # worst roofline fraction + most collective-bound cell
    "arctic-train": dict(
        arch="arctic-480b", shape="train_4k", multi_pod=False,
        variants=[
            ("paperfaithful", dict(quant_impl="residual")),
            ("gatherbf16", dict(recipe_overrides=dict(
                gather_dtype="bfloat16"))),
            ("gatherbf16mb8", dict(recipe_overrides=dict(
                gather_dtype="bfloat16", microbatches=8))),
        ],
    ),
    # decode cell: most collective-bound serving case (whole-model FSDP
    # gather per token); levers: bf16 weights, TP-resident placement
    "qwen110b-decode": dict(
        arch="qwen1.5-110b", shape="decode_32k", multi_pod=False,
        variants=[
            ("servebf16", dict(serve_dtype="bfloat16")),
            ("servebf16resident", dict(
                serve_dtype="bfloat16",
                plan_overrides=dict(serve_resident=True))),
        ],
    ),
}


def run(cell_key: str, only: str | None = None):
    from repro.launch.dryrun import ART, run_cell

    cell = CELLS[cell_key]
    base_name = (f"{cell['arch']}__{cell['shape']}__"
                 f"{'pod2x16x16' if cell['multi_pod'] else 'pod16x16'}__base.json")
    base_path = os.path.join(ART, base_name)
    base = json.load(open(base_path)) if os.path.exists(base_path) else None

    rows = []
    if base:
        rows.append(("base", base))
    for name, kw in cell["variants"]:
        if only and name != only:
            continue
        print(f"--- variant {name} ---", flush=True)
        rec = run_cell(cell["arch"], cell["shape"], cell["multi_pod"],
                       variant=name, **kw)
        rows.append((name, rec))

    print(f"\n=== {cell_key} ===")
    print(f"{'variant':16s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'dominant':>10s} {'peakGiB':>8s}")
    for name, rec in rows:
        rf = rec["roofline"]
        print(f"{name:16s} {rf['compute_s']:10.3f} {rf['memory_s']:10.3f} "
              f"{rf['collective_s']:10.3f} {rf['dominant']:>10s} "
              f"{rec['per_device']['peak_hint_bytes']/2**30:8.1f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--alt-plan", action="store_true")
    args = ap.parse_args()
    run(args.cell, args.variant)


if __name__ == "__main__":
    main()
