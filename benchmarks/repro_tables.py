"""Shared driver for the paper's experimental tables (MNIST / LeNet-5).

Table 1: CGMQ (dir_1..3 x {layer, indiv}) vs FP32 at bound 0.40% RBOP.
Table 2: dir_1..3, layer gates, bounds {0.40, 0.90, 1.40, 2.00, 5.00}%.
Table 3: dir_1..3, indiv gates, same bounds.

The FP32 pretrained model and the learned quantization ranges are shared
across all CGMQ variants, exactly as in the paper ("All different choices of
CGMQ start with the same pre-trained model and the same learned quantization
ranges"). Bundles are cached under artifacts/bundles/.

Data is the deterministic synthetic digit set (MNIST stand-in — no dataset
downloads in this environment; see DESIGN.md §7). Scale tiers:

  quick : CI-sized smoke (minutes)        — run.py default
  paper : paper-shaped epoch counts (hours on 1 CPU core) — --tier paper
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.controller import CGMQConfig  # noqa: E402
from repro.core.pipeline import (  # noqa: E402
    PipelineConfig,
    PretrainedBundle,
    prepare_bundle,
    run_cgmq_stage,
)
from repro.core.sites import PER_TENSOR, PER_WEIGHT, QuantConfig  # noqa: E402
from repro.data.synthetic import digits  # noqa: E402
from repro.models import lenet  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

TIERS = {
    # (ntrain, ntest, pretrain, range, cgmq epochs, batch)
    "smoke": (600, 200, 4, 2, 8, 64),
    "quick": (2000, 500, 12, 3, 25, 128),
    "paper": (10000, 2000, 250, 20, 250, 128),
}

GATE_LR = {"dir1": 0.01, "dir2": 0.01, "dir3": 0.001, "dir4": 0.01}
GRAN = {"layer": PER_TENSOR, "indiv": PER_WEIGHT}
BOUNDS = (0.004, 0.009, 0.014, 0.020, 0.050)


@dataclasses.dataclass
class Row:
    method: str
    hyperpar: str
    acc: float
    rgbop: float
    bound: float
    satisfied: bool
    seconds: float

    def fmt(self):
        return (
            f"{self.method:6s} {self.hyperpar:14s} acc={self.acc*100:6.2f}% "
            f"RGBOP={self.rgbop*100:6.3f}% bound={self.bound*100:5.2f}% "
            f"sat={'Y' if self.satisfied else 'N'} ({self.seconds:.0f}s)"
        )

    def csv(self):
        return (
            f"{self.method},{self.hyperpar},{self.acc:.4f},{self.rgbop:.6f},"
            f"{self.bound:.4f},{int(self.satisfied)},{self.seconds:.1f}"
        )


def _data(tier):
    ntr, nte, *_ = TIERS[tier]
    xtr, ytr = digits(ntr, split="train")
    xte, yte = digits(nte, split="test")
    return (
        (jnp.asarray(xtr), jnp.asarray(ytr)),
        (jnp.asarray(xte), jnp.asarray(yte)),
    )


def _pcfg(tier, log=print, loop="scan", cgmq_epochs=None):
    ntr, nte, pe, re, ce, bs = TIERS[tier]
    return PipelineConfig(
        pretrain_epochs=pe, range_epochs=re,
        cgmq_epochs=ce if cgmq_epochs is None else cgmq_epochs,
        batch_size=bs, eval_every=max(1, ce // 3), loop=loop, log=log,
    )


def get_bundle(tier: str, gran: str, *, log=print, cache=True) -> PretrainedBundle:
    os.makedirs(os.path.join(ART, "bundles"), exist_ok=True)
    path = os.path.join(ART, "bundles", f"lenet_{tier}_{gran}.pkl")
    if cache and os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    train, test = _data(tier)
    params = lenet.init_params(jax.random.PRNGKey(0))
    # share FP32 pretraining across granularities via its own cache
    fp_path = os.path.join(ART, "bundles", f"lenet_{tier}_fp32.pkl")
    pretrained = None
    if cache and os.path.exists(fp_path):
        with open(fp_path, "rb") as f:
            pretrained = pickle.load(f)
    bundle = prepare_bundle(
        lenet.forward, lenet.weight_lookup, params, train, test,
        QuantConfig(granularity=GRAN[gran]), _pcfg(tier, log),
        pretrained_params=pretrained,
    )
    if cache:
        with open(fp_path, "wb") as f:
            pickle.dump(jax.device_get(bundle.params), f)
        with open(path, "wb") as f:
            pickle.dump(jax.device_get(bundle), f)
    return bundle


def run_variant(
    tier: str,
    direction: str,
    gran: str,
    bound: float,
    *,
    log=lambda s: None,
    loop: str = "scan",
    ckpt_dir: str | None = None,
    resume: bool = False,
    cgmq_epochs: int | None = None,
) -> Row:
    bundle = get_bundle(tier, gran, log=log)
    train, test = _data(tier)
    t0 = time.time()
    res = run_cgmq_stage(
        lenet.forward, bundle, train, test,
        CGMQConfig(budget_rbop=bound, direction=direction,
                   gate_lr=GATE_LR[direction]),
        _pcfg(tier, log, loop, cgmq_epochs),
        ckpt_dir=ckpt_dir, resume=resume,
    )
    return Row(
        method="CGMQ",
        hyperpar=f"{direction}, {gran}",
        acc=res.final_test_acc,
        rgbop=res.final_rbop,
        bound=bound,
        satisfied=res.satisfied,
        seconds=time.time() - t0,
    )


def fp32_row(tier: str) -> Row:
    bundle = get_bundle(tier, "layer", log=lambda s: None)
    return Row("FP32", "-", bundle.fp32_test_acc, 1.0, 1.0, True, 0.0)


def table1(tier="quick", directions=("dir1", "dir2", "dir3"), log=print):
    rows = [fp32_row(tier)]
    for gran in ("layer", "indiv"):
        for d in directions:
            rows.append(run_variant(tier, d, gran, 0.004))
            log(rows[-1].fmt())
    return rows


def table_bounds(gran: str, tier="quick", directions=("dir1", "dir2", "dir3"),
                 bounds=BOUNDS, log=print):
    rows = []
    for bound in bounds:
        for d in directions:
            rows.append(run_variant(tier, d, gran, bound))
            log(rows[-1].fmt())
    return rows


def save_rows(rows, name):
    os.makedirs(os.path.join(ART, "tables"), exist_ok=True)
    path = os.path.join(ART, "tables", f"{name}.csv")
    with open(path, "w") as f:
        f.write("method,hyperpar,acc,rgbop,bound,satisfied,seconds\n")
        for r in rows:
            f.write(r.csv() + "\n")
    return path
