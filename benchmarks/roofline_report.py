"""Render the §Roofline table from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--variant base]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(variant="base", out_dir=ART):
    recs = []
    if not os.path.isdir(out_dir):
        return recs
    for name in sorted(os.listdir(out_dir)):
        if not name.endswith(f"__{variant}.json"):
            continue
        with open(os.path.join(out_dir, name)) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED: "
                f"{r.get('error','?')[:40]} | | | | | |")
    rf = r["roofline"]
    uf = rf.get("useful_flops_ratio")
    frac = rf.get("roofline_fraction")
    peak = r["per_device"]["peak_hint_bytes"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {rf['compute_s']*1e3:9.1f} | {rf['memory_s']*1e3:9.1f} "
        f"| {rf['collective_s']*1e3:9.1f} | {rf['dominant']:10s} "
        f"| {'' if uf is None else f'{uf:.2f}'} "
        f"| {'' if frac is None else f'{frac*100:.1f}%'} "
        f"| {peak:6.1f} |"
    )


HEADER = (
    "| arch | shape | mesh | compute ms | memory ms | collective ms | "
    "dominant | useful-flops | roofline-frac | peak GiB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="base")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.variant)
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_flops,roofline_frac,peak_gib")
        for r in recs:
            if not r.get("ok"):
                print(f"{r['arch']},{r['shape']},{r['mesh']},,,,FAILED,,,")
                continue
            rf = r["roofline"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{rf['compute_s']:.4g},{rf['memory_s']:.4g},"
                  f"{rf['collective_s']:.4g},{rf['dominant']},"
                  f"{rf.get('useful_flops_ratio') or ''},"
                  f"{rf.get('roofline_fraction') or ''},"
                  f"{r['per_device']['peak_hint_bytes']/2**30:.2f}")
        return
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    oks = [r for r in recs if r.get("ok")]
    print(f"\n{len(oks)}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
