"""Tests for gate variables: T / G_b (Eq. 4) and the residual form (Eq. 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

from repro.core.gates import (
    GATE_INIT,
    GATE_MIN,
    clamp_gate,
    gate_fn,
    gate_to_bits,
    gated_fake_quant,
    residual_fake_quant,
    transform,
)
from repro.core.quantizer import quantize


def test_transform_table():
    """Spot-check T(g) against the paper's Eq. 4 table."""
    g = jnp.asarray([-1.0, 0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5, 5.5])
    expect = [0, 0, 2, 2, 4, 4, 8, 8, 16, 16, 32, 32]
    np.testing.assert_array_equal(np.asarray(transform(g)), expect)


def test_paper_example_g_1_5():
    """Paper: g = 1.5 -> G2 = G4 = 1, G8 = G16 = G32 = 0."""
    g = jnp.asarray(1.5)
    assert float(gate_fn(g, 2)) == 1.0
    assert float(gate_fn(g, 4)) == 1.0
    assert float(gate_fn(g, 8)) == 0.0
    assert float(gate_fn(g, 16)) == 0.0
    assert float(gate_fn(g, 32)) == 0.0


def test_gate_init_is_32bit():
    assert float(gate_to_bits(jnp.asarray(GATE_INIT))) == 32.0


def test_clamp_no_pruning():
    assert float(gate_to_bits(clamp_gate(jnp.asarray(-3.0)))) == 2.0
    assert float(clamp_gate(jnp.asarray(0.1))) == GATE_MIN


@settings(max_examples=80, deadline=None)
@given(
    g=st.floats(-2.0, 6.0),
    beta=st.floats(0.2, 4.0),
    signed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_residual_equals_direct(g, beta, signed, seed):
    """Paper Eq. 3 (residual chain) telescopes to Q(x, T(g)) exactly."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * beta)
    gv = jnp.asarray(g, jnp.float32)
    r = residual_fake_quant(x, gv, jnp.asarray(beta), signed)
    d = gated_fake_quant(x, gv, jnp.asarray(beta), signed)
    np.testing.assert_allclose(np.asarray(r), np.asarray(d), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("g,bits", [(0.7, 2), (1.5, 4), (2.5, 8), (3.5, 16), (5.5, 32)])
def test_gated_matches_fixed_bits(g, bits):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    got = gated_fake_quant(x, jnp.asarray(g), jnp.asarray(1.0), True)
    want = quantize(x, bits, jnp.asarray(1.0), True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_gate_has_no_gradient():
    """The gate's true gradient is zero (hence the direction machinery)."""

    def f(g):
        x = jnp.linspace(-1, 1, 32)
        return gated_fake_quant(x, g, jnp.asarray(1.0), True).sum()

    g = jax.grad(f)(jnp.asarray(1.5))
    assert float(g) == 0.0


def test_per_element_gates():
    x = jnp.full((4,), 0.3, jnp.float32)
    g = jnp.asarray([0.7, 1.5, 2.5, 5.5])
    q = np.asarray(gated_fake_quant(x, g, jnp.asarray(1.0), True))
    w2 = float(quantize(jnp.asarray(0.3), 2, 1.0, True))
    w4 = float(quantize(jnp.asarray(0.3), 4, 1.0, True))
    w8 = float(quantize(jnp.asarray(0.3), 8, 1.0, True))
    np.testing.assert_allclose(q, [w2, w4, w8, 0.3], atol=1e-6)
