"""QuantSpec / packing / export-ledger tests (DESIGN.md §11).

Property tests (hypothesis, deterministic-replay fallback shim without it):
  * pack/unpack round-trip at 2/4 bits — odd K, ragged groups, leading
    stack dims, signed/unsigned code ranges, byte-count accounting;
  * QuantizedTensor grid: dequantize lands on the Eq. 1 quantizer grid and
    packed codes equal the unpacked int8 layout bit-for-bit.

Plus direct tests for the gate→bits→storage-class constructor, the export
ledger (fallback visibility), the bytes/BOPs report, and the LeNet export
path sharing the same machinery.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

from repro.core.quantizer import quantize
from repro.quant import (QuantSpec, QuantizedTensor, pack_codes,
                         quant_report, specs_from_state, unpack_codes)
from repro.quant.pack import CODES_PER_BYTE, packed_rows
from repro.quant.spec import storage_class_for


# ---------------------------------------------------------------------------
# pack/unpack round-trip properties
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(k=st.integers(min_value=1, max_value=41),
       n=st.integers(min_value=1, max_value=9),
       bits=st.sampled_from([2, 4]),
       stacked=st.booleans(),
       unsigned_rng=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**16))
def test_pack_unpack_roundtrip(k, n, bits, stacked, unsigned_rng, seed):
    """unpack(pack(c)) == c for every K (odd/ragged included), every stack
    layout, and both halves of the signed code range; the packed array is
    uint8 with exactly ceil(K/per) rows."""
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if unsigned_rng:      # unsigned grids center into the non-negative half
        lo = 0
    shape = ((3, k, n) if stacked else (k, n))
    codes = jnp.asarray(rng.integers(lo, hi + 1, shape), jnp.int8)
    packed = pack_codes(codes, bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape[-2] == packed_rows(k, bits) == -(-k // (8 // bits))
    assert packed.shape[:-2] == codes.shape[:-2]
    out = unpack_codes(packed, bits, k)
    assert out.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


@settings(max_examples=25)
@given(k=st.integers(min_value=1, max_value=33),
       n=st.integers(min_value=1, max_value=8),
       storage=st.sampled_from([2, 4, 8]),
       signed=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**16))
def test_quantized_tensor_grid_and_packing_lossless(k, n, storage, signed,
                                                    seed):
    """from_float at mixed per-channel bits: dequantize() agrees with the
    Eq. 1 quantizer grid, and the packed layout carries the SAME codes as
    the pack=False int8 oracle layout — packing is pure storage."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    levels = [b for b in (2, 4, 8) if b <= storage]
    bits = jnp.asarray(rng.choice(levels, size=(n,)).astype(np.float32))
    beta = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-3)
    qt = QuantizedTensor.from_float(w, bits[None, :], beta[None, :], signed,
                                    storage_bits=storage)
    oracle = QuantizedTensor.from_float(w, bits[None, :], beta[None, :],
                                        signed, storage_bits=storage,
                                        pack=False)
    np.testing.assert_array_equal(np.asarray(qt.int8_codes()),
                                  np.asarray(oracle.codes))
    fq = quantize(w, bits[None, :], beta[None, :], signed)
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(fq),
                               atol=1e-5)
    # ceil(bits/8)-packed byte accounting
    per = CODES_PER_BYTE[qt.storage_bits]
    assert qt.codes_bytes() == -(-k // per) * n
    assert qt.weight_count() == k * n


# ---------------------------------------------------------------------------
# QuantSpec: the one gate→bits→storage-class constructor
# ---------------------------------------------------------------------------


def test_spec_from_gate_storage_class():
    """T(g) thresholds map to storage classes; > 8 bits has none (fp
    fallback) — the clamp-to-[2,8] decision, in its single home."""
    for gate, bits, storage in [(0.2, 2, 2), (0.8, 2, 2), (1.5, 4, 4),
                                (2.5, 8, 8), (3.5, 16, None),
                                (4.5, 32, None)]:
        spec = QuantSpec.from_gate(jnp.asarray(gate), jnp.asarray(1.0), True)
        assert spec.max_bits() == bits, gate
        assert spec.storage_bits() == storage, gate
    # mixed per-channel gates: the site's class is set by its widest channel
    spec = QuantSpec.from_gate(jnp.asarray([0.8, 1.5]), jnp.ones((2,)), True)
    assert spec.max_bits() == 4 and spec.storage_bits() == 4
    assert storage_class_for(3) == 4 and storage_class_for(9) is None


def test_specs_from_state_is_a_pytree():
    """Specs thread through jit/scan like the gate arrays they replace."""
    specs = specs_from_state(
        {"a.w": jnp.asarray([2.5, 0.8])},
        {"a.w": jnp.asarray([1.0, 2.0])},
        {"a.w": True})
    leaves = jax.tree_util.tree_leaves(specs)
    assert len(leaves) == 2
    sliced = jax.tree.map(lambda x: x[0], specs)
    assert float(sliced["a.w"].bits) == 8.0
    assert sliced["a.w"].signed is True


# ---------------------------------------------------------------------------
# Export ledger + quant_report (LeNet path: same machinery as the LLM)
# ---------------------------------------------------------------------------


def _lenet_state(granularity="per_tensor", gate_init=2.5):
    from repro.core.sites import (QuantConfig, collect_sites, init_gates,
                                  init_ranges_from_weights,
                                  split_learnable_ranges)
    from repro.models import lenet

    params = lenet.init_params(jax.random.PRNGKey(0))
    qcfg = QuantConfig(granularity=granularity)
    sites = collect_sites(lenet.forward, params,
                          jnp.zeros((1, 28, 28, 1), jnp.float32), cfg=qcfg)
    gates = init_gates(sites, qcfg, init=gate_init)
    betas, signed = split_learnable_ranges(
        init_ranges_from_weights(sites, qcfg,
                                 lenet.weight_lookup(params)))
    return params, qcfg, sites, gates, betas, signed


def test_lenet_export_ledgers_conv_fallbacks_and_packs_fc():
    from repro.models import lenet

    params, qcfg, sites, gates, betas, signed = _lenet_state()
    # certify the fc sites at 2 bits, leave convs at 8
    for key in list(gates):
        if key.startswith("fc") and key.endswith(".w"):
            gates[key] = jnp.full_like(gates[key], 0.8)
    qw, ledger = lenet.export_qweights(params, gates, betas, signed)
    assert {"fc1.w", "fc2.w", "fc3.w"} <= set(qw)
    assert all(qw[f"fc{i}.w"].storage_bits == 2 for i in (1, 2, 3))
    fb = ledger.fallbacks()
    assert {"conv1.w", "conv2.w"} == set(fb)
    assert all(e["reason"] == "shape" for e in fb.values())

    rep = quant_report(ledger, gates)
    t = rep["totals"]
    assert t["fallback_sites"] == 2 and t["exported_sites"] == 3
    # fc codes at 2 bits: a quarter byte per weight (fan-ins divide by 4),
    # plus the per-tensor fp32 scale + bias (4 bytes each)
    for i in (1, 2, 3):
        e = rep["per_site"][f"fc{i}.w"]
        assert e["bytes"] == e["weight_count"] // 4 + 8
    assert t["bytes_device"] < t["bytes_fp32"]


def test_lenet_serve_mode_uses_frozen_codes():
    """LeNet serve-mode forward: fc sites read the dequantized frozen codes
    (bit-identical to dequantize()), convs fall back to spec fake-quant, and
    the logits match the train-mode fake-quant reference."""
    from repro.core.sites import QuantContext, merge_ranges
    from repro.models import lenet

    params, qcfg, sites, gates, betas, signed = _lenet_state()
    qw, _ = lenet.export_qweights(params, gates, betas, signed)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 28, 28, 1)),
                    jnp.float32)
    qc_t = QuantContext(mode="train", cfg=qcfg, gates=gates,
                        ranges=merge_ranges(betas, signed), probes={})
    lt = lenet.forward(qc_t, params, x)
    qc_s = QuantContext(mode="serve", cfg=qcfg,
                        specs=specs_from_state(gates, betas, signed),
                        qweights=qw)
    ls = lenet.forward(qc_s, params, x)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lt),
                               rtol=1e-4, atol=1e-4)


def test_per_weight_granularity_ledgered_not_exported():
    from repro.models import lenet

    params, qcfg, sites, gates, betas, signed = _lenet_state(
        granularity="per_weight")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # per-weight fallback must NOT warn
        qw, ledger = lenet.export_qweights(params, gates, betas, signed)
    assert qw == {}
    assert all(e["reason"] in ("granularity", "shape")
               for e in ledger.fallbacks().values())


def test_ungated_site_ledgered_and_warns():
    """A captured site the quant_state knows nothing about (config /
    checkpoint mismatch) serves full precision — it must land in the ledger
    as reason='ungated' and trigger the not-fully-quantized warning, not
    silently vanish."""
    from repro.models import lenet

    params, qcfg, sites, gates, betas, signed = _lenet_state()
    del gates["fc2.w"]
    with pytest.warns(UserWarning, match="NOT fully integer-quantized"):
        qw, ledger = lenet.export_qweights(params, gates, betas, signed)
    assert "fc2.w" not in qw
    e = ledger.entries["fc2.w"]
    assert e["reason"] == "ungated" and e["served"] == "fake_quant"
    assert e["bits"] is None and e["fp_bytes"] == 4 * e["weight_count"]
    rep = quant_report(ledger, gates)
    assert rep["per_site"]["fc2.w"]["reason"] == "ungated"


def test_blockwise_int8_roundtrip_error_bounded():
    """The gradient-compression wire format now lives in quant.pack."""
    from repro.quant import blockwise_int8_decode, blockwise_int8_encode

    x = jnp.asarray(np.random.default_rng(5).normal(size=(130,)) * 3.0,
                    jnp.float32)
    codes, scale = blockwise_int8_encode(x, 64)
    assert codes.dtype == jnp.int8 and codes.shape == (3, 64)
    back = blockwise_int8_decode(codes, scale, (130,))
    assert back.shape == (130,)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    assert err <= float(scale.max()) / 2 + 1e-7


def test_quant_report_bytes_accounting():
    """quant_report totals: packed < int8 < fp32 on a mixed export, and the
    per-site bytes follow the storage-class packing exactly."""
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import export_int_model, make_mixed_quant_state

    cfg = get_smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    qs = make_mixed_quant_state(cfg, params)
    qw, ledger = export_int_model(params, cfg, qs)
    rep = quant_report(ledger, qs["gates"])
    t = rep["totals"]
    assert t["bytes_device"] < t["bytes_uniform_int8"] < t["bytes_fp32"]
    assert t["bytes_per_weight"] < t["uniform_int8_bytes_per_weight"]
    # the headline metric counts EVERYTHING resident on device: codes AND
    # the fp32 affine terms (same aux rides in the int8 baseline)
    assert t["bytes_device"] == t["bytes_packed"] + t["bytes_aux"]
    assert t["uniform_int8_bytes_per_weight"] > 1.0  # int8 codes + fp32 aux
    for key, qt in qw.items():
        per = CODES_PER_BYTE[qt.storage_bits]
        assert rep["per_site"][key]["bytes"] == (qt.codes_bytes()
                                                + qt.aux_bytes())
        # packed rows follow ceil(K / per) per stacked copy
        assert qt.codes.shape[-2] == -(-qt.k // per)
    assert rep["bops"]["model"] <= rep["bops"]["uniform_int8"]
    assert 0 < rep["bops"]["rbop"] < 1
