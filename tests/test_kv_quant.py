"""KV-cache codec property tests (DESIGN.md §14).

Property tests (hypothesis, deterministic-replay fallback shim without it):
  * round-trip error bounded by scale/2 per group — every bits/group_size
    combination, odd/ragged head_dim tails included;
  * exact idempotence: quantize(dequantize(x)) returns the SAME codes and
    scales bit-for-bit (what makes CoW copy codes+aux verbatim and
    preemption-resume bit-identical);
  * int4 packing round-trips through the pool byte layout.

Plus direct tests for spec accounting (ceil-packed bytes/vector,
bytes-per-cached-token report) and structural spec recovery from a cache
entry.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

from repro.quant import KVQuantSpec, dequantize_kv, quantize_kv
from repro.quant.kv import (SCALE_DTYPE, bytes_per_cached_token,
                            dequant_codes, kv_cache_report, spec_from_cache,
                            unpack_int4)


def _sample(seed, lead, head_dim, scale_pow):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(lead + (head_dim,)) * (10.0 ** scale_pow)
    # sprinkle exact zeros and a per-vector outlier channel
    x[..., 0] = 0.0
    if head_dim > 1:
        x[..., -1] *= 50.0
    return jnp.asarray(x, jnp.float32)


# ---------------------------------------------------------------------------
# round-trip error bound
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(head_dim=st.integers(min_value=1, max_value=37),
       group_size=st.integers(min_value=1, max_value=16),
       bits=st.sampled_from([4, 8]),
       scale_pow=st.integers(min_value=-3, max_value=2),
       seed=st.integers(min_value=0, max_value=2**16))
def test_roundtrip_error_bounded_per_group(head_dim, group_size, bits,
                                           scale_pow, seed):
    """|x - dequant(quant(x))| <= scale/2 within every group, for every
    head_dim (ragged group tails included) and both storage classes."""
    spec = KVQuantSpec(bits=bits, group_size=group_size, head_dim=head_dim)
    x = _sample(seed, (3, 2), head_dim, scale_pow)
    codes, scale = quantize_kv(x, spec)
    assert codes.dtype == spec.code_dtype
    assert codes.shape == x.shape[:-1] + (spec.packed_head,)
    assert scale.dtype == SCALE_DTYPE
    assert scale.shape == x.shape[:-1] + (spec.num_groups,)
    y = dequantize_kv(codes, scale, spec)
    err = jnp.abs(y - x)
    pad = spec.padded_head - head_dim
    if pad:
        err = jnp.pad(err, [(0, 0)] * (err.ndim - 1) + [(0, pad)])
    err_g = err.reshape(err.shape[:-1] + (spec.num_groups, group_size))
    bound = scale.astype(jnp.float32) * (0.5 + 1e-3) + 1e-7
    assert bool(jnp.all(jnp.max(err_g, axis=-1) <= bound)), (
        float(jnp.max(err_g)), float(jnp.min(scale)))


@settings(max_examples=40)
@given(head_dim=st.integers(min_value=1, max_value=37),
       group_size=st.integers(min_value=1, max_value=16),
       bits=st.sampled_from([4, 8]),
       scale_pow=st.integers(min_value=-3, max_value=2),
       seed=st.integers(min_value=0, max_value=2**16))
def test_quantize_dequantize_idempotent(head_dim, group_size, bits,
                                        scale_pow, seed):
    """quantize(dequantize(x)) == (codes, scale) EXACTLY: the fp16 scale
    floor puts requantization back on the identical grid, so re-encoding a
    decoded block is a bit-for-bit no-op."""
    spec = KVQuantSpec(bits=bits, group_size=group_size, head_dim=head_dim)
    x = _sample(seed, (2,), head_dim, scale_pow)
    codes, scale = quantize_kv(x, spec)
    codes2, scale2 = quantize_kv(dequantize_kv(codes, scale, spec), spec)
    assert bool(jnp.all(codes2 == codes))
    assert bool(jnp.all(scale2 == scale))
    # and a second decode lands on the same floats
    y = dequantize_kv(codes, scale, spec)
    y2 = dequantize_kv(codes2, scale2, spec)
    assert bool(jnp.all(y == y2))


@settings(max_examples=30)
@given(head_dim=st.integers(min_value=1, max_value=33),
       seed=st.integers(min_value=0, max_value=2**16))
def test_int4_pack_roundtrip_matches_int8_codes(head_dim, seed):
    """The packed int4 pool layout decodes to the same centered codes the
    int8 path would clip to the 4-bit range (nibble order = pack.py's)."""
    spec4 = KVQuantSpec(bits=4, group_size=8, head_dim=head_dim)
    x = _sample(seed, (4,), head_dim, 0)
    codes, scale = quantize_kv(x, spec4)
    assert codes.dtype == jnp.uint8
    assert codes.shape[-1] == spec4.packed_head == -(-head_dim // 2)
    unpacked = unpack_int4(codes, head_dim)
    assert bool(jnp.all(unpacked <= 7)) and bool(jnp.all(unpacked >= -7))
    # dequant via the generic code path agrees with dequantize_kv
    y = dequant_codes(unpacked, scale, head_dim, spec4.group_size)
    assert bool(jnp.all(y == dequantize_kv(codes, scale, spec4)))


def test_all_zero_vectors_code_to_zero():
    spec = KVQuantSpec(bits=8, group_size=4, head_dim=12)
    codes, scale = quantize_kv(jnp.zeros((2, 12)), spec)
    assert bool(jnp.all(codes == 0))
    assert bool(jnp.all(dequantize_kv(codes, scale, spec) == 0.0))


# ---------------------------------------------------------------------------
# spec accounting + structural recovery
# ---------------------------------------------------------------------------


def test_spec_accounting_ceil_packed():
    s8 = KVQuantSpec(bits=8, group_size=16, head_dim=16)
    assert s8.packed_head == 16 and s8.num_groups == 1
    assert s8.bytes_per_vector() == 16 + 2          # codes + one fp16 scale
    s4 = KVQuantSpec(bits=4, group_size=8, head_dim=17)
    assert s4.packed_head == 9                      # ceil(17/2) bytes
    assert s4.num_groups == 3                       # ragged tail group
    assert s4.bytes_per_vector() == 9 + 3 * 2
    with pytest.raises(ValueError):
        KVQuantSpec(bits=3, group_size=8, head_dim=16)


def test_bytes_per_cached_token_and_report():
    spec = KVQuantSpec(bits=8, group_size=16, head_dim=16)
    q = bytes_per_cached_token(2, 16, spec=spec)
    assert q == 2 * 2 * (16 + 2)                    # K+V, 2 heads
    bf16 = bytes_per_cached_token(2, 16, dtype=jnp.bfloat16)
    fp32 = bytes_per_cached_token(2, 16, dtype=jnp.float32)
    assert bf16 == 2 * 2 * 16 * 2 and fp32 == 2 * bf16
    rep = kv_cache_report(["global", "mlp", "local"], 2, 16, spec=spec,
                          kv_dtype="int8")
    assert rep["attention_layers"] == 2
    assert rep["bytes_per_cached_token"] == 2 * q
    assert rep["fp32_bytes_per_cached_token"] == 2 * fp32
    assert rep["vs_fp32"] == pytest.approx(q / fp32)
    # the §14 headline: int8 + fp16 group scales lands under 0.3x fp32
    assert rep["vs_fp32"] <= 0.3


def test_spec_recovered_structurally_from_cache_entry():
    spec = KVQuantSpec(bits=8, group_size=8, head_dim=16)
    x = jnp.ones((3, 4, 2, 16))
    k, ks = quantize_kv(x, spec)
    entry = {"k": k, "v": k, "k_scale": ks, "v_scale": ks}
    assert spec_from_cache(entry, 16) == spec
    assert spec_from_cache({"k": x, "v": x}, 16) is None
    s4 = KVQuantSpec(bits=4, group_size=8, head_dim=16)
    k4, ks4 = quantize_kv(x, s4)
    assert spec_from_cache({"k": k4, "v": k4, "k_scale": ks4,
                            "v_scale": ks4}, 16) == s4
