"""Serving fast-path tests: batched prefill, scheduler, quantized decode.

Covers the three legs of the serving hot path (DESIGN.md §8/§11):
  * batched prefill ≡ the seed's scan-of-decode-steps (logits equivalence),
  * continuous-batching scheduler invariants (slot isolation, FIFO
    admission, retirement/reuse),
  * the mixed-precision integer decode path: fused-dequant GEMMs vs the
    fake-quant train-mode reference, and the packed sub-byte storage path
    vs the unpacked int8 oracle — bit-for-bit, on every transformer config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.sites import QuantContext
from repro.models import transformer as tfm
from repro.quant import specs_from_state
from repro.serving.engine import (Request, ServingEngine, export_int_model,
                                  make_mixed_quant_state,
                                  make_uniform_quant_state)

ARCH = "tinyllama-1.1b"


def _model(seed=0, arch=ARCH):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _quant_state(cfg, params, gate_init=2.2, granularity="per_channel"):
    return make_uniform_quant_state(cfg, params, gate_init=gate_init,
                                    granularity=granularity)


def _serve_qc(qs, qw, matmul_impl="ref"):
    return QuantContext(
        mode="serve", cfg=qs["qcfg"],
        specs=specs_from_state(qs["gates"], qs["betas"], qs["signed"]),
        qweights=qw, matmul_impl=matmul_impl)


# ---------------------------------------------------------------------------
# Batched prefill ≡ scan of decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plen", [3, 7])
def test_prefill_slot_matches_scan_of_decode_steps(plen):
    """One causal forward per slot == the seed's token-by-token prefill."""
    cfg, params = _model()
    prompt = np.arange(1, plen + 1, dtype=np.int32)
    qc = QuantContext(mode="off")

    # seed path: scan decode_step over the prompt on a fresh cache
    cache_ref = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits_ref, cache_ref = tfm.decode_step(
            qc, params, cache_ref, jnp.asarray([t], jnp.int32), cfg)

    # new path: right-padded single forward into slot 0
    spad = 16
    toks = np.zeros((1, spad), np.int32)
    toks[0, :plen] = prompt
    cache_new = tfm.init_cache(cfg, 1, 32)
    logits_new, cache_new = tfm.prefill_slot(
        qc, params, jnp.asarray(toks), plen, cache_new, 0, cfg)

    np.testing.assert_allclose(
        np.asarray(logits_new[0, plen - 1, : cfg.vocab_size]),
        np.asarray(logits_ref[0, 0, : cfg.vocab_size]),
        rtol=2e-2, atol=2e-2)
    assert int(cache_new["pos"][0]) == plen == int(cache_ref["pos"][0])

    # and the caches are interchangeable: decode diverges by bf16 noise only
    nxt = jnp.asarray([5], jnp.int32)
    l1, _ = tfm.decode_step(qc, params, cache_ref, nxt, cfg)
    l2, _ = tfm.decode_step(qc, params, cache_new, nxt, cfg)
    np.testing.assert_allclose(
        np.asarray(l1[..., : cfg.vocab_size]),
        np.asarray(l2[..., : cfg.vocab_size]), rtol=2e-2, atol=2e-2)


def test_prefill_slot_counts_one_forward(capsys):
    """Engine accounting: one batched forward per admission, vs plen
    decode-step forwards (each ``slots`` wide) in the seed path."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=4, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, (9,)),
                           max_new=2))
    eng.run_to_completion()
    st = eng.stats
    assert st["prefill_forwards"] == 4
    assert st["seed_equiv_forwards"] == 4 * 9
    # slot-forward ratio: (plen * slots) seed slot-forwards vs 1 per admission
    ratio = st["seed_equiv_forwards"] * eng.slots / st["prefill_forwards"]
    assert ratio >= eng.slots


@pytest.mark.parametrize("arch,plen", [
    ("mamba2-1.3b", 11),        # ssm_chunk=8: chunk-aligned prefix + 3-token
    ("mamba2-1.3b", 6),         #   teacher-forced tail / pure exact length
    ("recurrentgemma-2b", 9),   # rglru + local ring: exact-length prefill
])
def test_recurrent_arch_prefill_matches_scan_of_decode(arch, plen):
    """Recurrent-state archs must not bake padding into the slot state:
    engine output == manual scan-of-decode-steps greedy, even with another
    request mid-generation in the neighboring slot (teacher-forced tail
    steps must not touch other slots' recurrent state)."""
    cfg, params = _model(arch=arch)
    rng = np.random.default_rng(plen)
    prompt = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=2, max_seq=32)
    # occupy slot 0 first so the probed request admits mid-flight
    eng.submit(Request(rid=9, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                       max_new=8))
    eng.step()
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    fin = {r.rid: r.output for r in eng.run_to_completion()}

    qc = QuantContext(mode="off")
    cache = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([t], jnp.int32), cfg)
    outs = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
    for _ in range(3):
        logits, cache = tfm.decode_step(
            qc, params, cache, jnp.asarray([outs[-1]], jnp.int32), cfg)
        outs.append(int(jnp.argmax(logits[0, 0, : cfg.vocab_size])))
    assert fin[0] == outs


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------


def test_slot_isolation_prefill_does_not_corrupt_neighbors():
    """A request's output is identical whether it shares the engine with
    other requests (admitted mid-flight, forcing interleaved prefills) or
    runs alone — i.e. one slot's prefill never corrupts another slot's KV."""
    cfg, params = _model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (int(p),))
               for p in (5, 9, 4, 11, 6)]

    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6))
    shared = {r.rid: r.output for r in eng.run_to_completion()}

    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, slots=1, max_seq=64)
        solo.submit(Request(rid=i, prompt=p, max_new=6))
        out = solo.run_to_completion()[0].output
        assert shared[i] == out, f"slot sharing changed request {i}"


def test_admission_and_retirement_ordering():
    """FIFO admission; retired slots immediately rehost the next waiter."""
    cfg, params = _model()
    rng = np.random.default_rng(2)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    # staggered lengths force slot 0 to retire before slot 1
    lens = [2, 5, 3, 4]
    for i, n in enumerate(lens):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (4,)),
                           max_new=n))

    eng._admit()
    assert [r.rid for r in eng.slot_req] == [0, 1]  # FIFO admission
    assert [r.rid for r in eng.waiting] == [2, 3]

    fin = eng.run_to_completion()
    rids = [r.rid for r in fin]
    assert sorted(rids) == [0, 1, 2, 3]
    assert rids.index(0) < rids.index(1)  # fewer tokens -> retires first
    assert rids.index(0) < rids.index(2)  # 2 rehosts 0's slot after it frees
    assert all(len(r.output) == n for r, n in
               zip(sorted(fin, key=lambda r: r.rid), lens))
    assert eng.slot_req == [None, None] and not eng.waiting


def test_max_new_one_retires_at_admission():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new=1))
    fin = eng.run_to_completion()
    assert len(fin) == 1 and len(fin[0].output) == 1 and fin[0].done


def test_device_resident_state_one_sync_shapes():
    """The tick's host transfer is three (slots,)-vectors; outputs accrue
    only for slots that were active when the tick ran."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=3, max_seq=32)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new=3))
    eng.step()
    # slots 1/2 idle: state must keep them inactive with no output
    active = np.asarray(jax.device_get(eng.state["active"]))
    assert active.tolist() == [True, False, False]
    assert len(eng.slot_req[0].output) == 2  # prefill token + one tick


# ---------------------------------------------------------------------------
# Int8 decode path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel"])
def test_int8_decode_matches_fake_quant_reference(granularity):
    """Serve-mode logits (fused-dequant GEMM off int codes) match the
    train-mode fake-quant fp32 reference within bf16 matmul tolerance."""
    cfg, params = _model()
    qs = _quant_state(cfg, params, granularity=granularity)
    qw, ledger = export_int_model(params, cfg, qs)
    assert qw, "no sites exported"
    assert all(b <= 8 for b in ledger.max_bits().values())

    toks = jnp.asarray([3, 7], jnp.int32)
    cache = tfm.init_cache(cfg, 2, 16)
    from repro.core.sites import merge_ranges
    qc_train = QuantContext(mode="train", cfg=qs["qcfg"], gates=qs["gates"],
                            ranges=merge_ranges(qs["betas"], qs["signed"]),
                            probes={})
    lt, _ = tfm.decode_step(qc_train, params, cache, toks, cfg)
    ls, _ = tfm.decode_step(_serve_qc(qs, qw), params, cache, toks, cfg)
    lt = np.asarray(lt[..., : cfg.vocab_size])
    ls = np.asarray(ls[..., : cfg.vocab_size])
    np.testing.assert_allclose(ls, lt, rtol=5e-2, atol=2e-2)


def test_int8_pallas_interpret_matches_ref_path():
    """The Pallas kernel (interpret) and the jnp reference produce the same
    serve-mode logits — kernel validation at the model level."""
    cfg, params = _model()
    qs = _quant_state(cfg, params)
    qw, _ = export_int_model(params, cfg, qs)
    toks = jnp.asarray([11], jnp.int32)
    cache = tfm.init_cache(cfg, 1, 16)
    outs = []
    for impl in ("ref", "pallas_interpret"):
        l, _ = tfm.decode_step(_serve_qc(qs, qw, impl), params, cache, toks,
                               cfg)
        outs.append(np.asarray(l[..., : cfg.vocab_size]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


def test_int8_engine_serves_end_to_end():
    """Full engine pass in serve mode: tokens come off the int8 hot path."""
    cfg, params = _model()
    qs = _quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, quant_state=qs,
                        matmul_impl="ref")
    assert len(eng.qweights) >= 8  # attn q/k/v/o + mlp gate/up/down + head
    rng = np.random.default_rng(4)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                           max_new=4))
    fin = eng.run_to_completion()
    assert len(fin) == 3
    for r in fin:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_export_skips_high_bit_sites_and_ledgers_them():
    """Sites whose gate maps above 8 bits are not exported (they'd lose
    their grid in int8) and serve via the fake-quant fallback — and that
    fallback is no longer silent: every rejected site lands in the export
    ledger with its reason, and the export warns once."""
    cfg, params = _model()
    qs = _quant_state(cfg, params, gate_init=4.5)  # T(4.5) = 32 bits
    with pytest.warns(UserWarning, match="NOT fully integer-quantized"):
        qw, ledger = export_int_model(params, cfg, qs)
    assert qw == {} and ledger.max_bits() == {}
    fb = ledger.fallbacks()
    assert fb and all(e["reason"] == "bits>8" for e in fb.values())
    assert all(e["bits"] == 32 for e in fb.values())
    # engine still runs on the fallback path
    with pytest.warns(UserWarning, match="NOT fully integer-quantized"):
        eng = ServingEngine(cfg, params, slots=1, max_seq=32, quant_state=qs)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new=2))
    fin = eng.run_to_completion()
    assert len(fin) == 1 and len(fin[0].output) == 2


# ---------------------------------------------------------------------------
# Packed sub-byte decode: bit-for-bit against the int8 oracle, every config
# ---------------------------------------------------------------------------


def _decode_inputs(cfg, rng):
    if cfg.embed_input:
        return jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)), jnp.int32)
    return jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), jnp.float32) * 0.3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_packed_decode_matches_int8_oracle_every_config(arch):
    """The §11 acceptance gate: a mixed 2/4/8-bit export served from PACKED
    sub-byte storage produces decode logits bit-for-bit identical to the
    same export in the unpacked int8 oracle layout, for every architecture.
    Packing must be pure storage — zero numerics."""
    cfg, params = _model(arch=arch)
    qs = make_mixed_quant_state(cfg, params)
    qw_packed, ledger = export_int_model(params, cfg, qs)
    qw_oracle, _ = export_int_model(params, cfg, qs, pack=False)
    assert qw_packed, f"{arch}: no sites exported"
    assert any(qt.storage_bits < 8 for qt in qw_packed.values()), \
        f"{arch}: mixed state exported no sub-byte site"
    # packed device bytes follow the ceil(bits/8) accounting exactly
    for key, qt in qw_packed.items():
        per = 8 // qt.storage_bits
        want_rows = -(-qt.k // per)
        assert qt.codes.shape[-2] == want_rows, key
        assert qt.codes_bytes() < qw_oracle[key].codes_bytes() \
            or qt.storage_bits == 8, key

    rng = np.random.default_rng(7)
    toks = _decode_inputs(cfg, rng)
    cache = tfm.init_cache(cfg, 2, 16)
    lp, _ = tfm.decode_step(_serve_qc(qs, qw_packed), params, cache, toks,
                            cfg)
    lo, _ = tfm.decode_step(_serve_qc(qs, qw_oracle), params, cache, toks,
                            cfg)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lo),
                                  err_msg=f"{arch}: packed != int8 oracle")


def test_engine_serves_packed_sub_byte_end_to_end():
    """Engine pass on a mixed 2/4/8-bit export: tokens come off the packed
    kernels, and the quant_report ledger shows sub-byte device bytes."""
    cfg, params = _model()
    qs = make_mixed_quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, quant_state=qs,
                        matmul_impl="ref")
    assert any(qt.storage_bits < 8 for qt in eng.qweights.values())
    rng = np.random.default_rng(11)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                           max_new=4))
    fin = eng.run_to_completion()
    assert len(fin) == 3 and all(len(r.output) == 4 for r in fin)
    rep = eng.quant_report()
    t = rep["totals"]
    assert t["bytes_per_weight"] < t["uniform_int8_bytes_per_weight"]
    assert t["bytes_device"] < t["bytes_uniform_int8"] < t["bytes_fp32"]
    assert t["fallback_sites"] == 0
    assert rep["bops"]["model"] < rep["bops"]["uniform_int8"]
