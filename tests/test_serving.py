"""Serving fast-path tests: batched prefill, scheduler, quantized decode.

Covers the serving hot path (DESIGN.md §8/§11) and the request-lifecycle
API (§12):
  * batched prefill ≡ the seed's scan-of-decode-steps (logits equivalence),
  * continuous-batching scheduler invariants (slot isolation, FIFO
    admission, retirement/reuse),
  * the mixed-precision integer decode path: fused-dequant GEMMs vs the
    fake-quant train-mode reference, and the packed sub-byte storage path
    vs the unpacked int8 oracle — bit-for-bit, on every transformer config,
  * SamplingParams + in-tick sampling: the temperature=0 facade is
    bit-identical to the argmax oracle on every servable arch and layout,
    seeded streams are invariant to slot placement / admission order / KV
    layout, stop tokens retire in-tick, and the host-sync ledger stays at
    one sync per tick with sampling enabled.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.sites import QuantContext
from repro.models import transformer as tfm
from repro.quant import specs_from_state
from repro.serving import (Request, SamplingParams, ServingEngine,
                           TokenEvent, export_int_model,
                           make_mixed_quant_state, make_uniform_quant_state)
from repro.serving.sampling import mask_logits, sample_tokens

ARCH = "tinyllama-1.1b"


def _model(seed=0, arch=ARCH):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _quant_state(cfg, params, gate_init=2.2, granularity="per_channel"):
    return make_uniform_quant_state(cfg, params, gate_init=gate_init,
                                    granularity=granularity)


def _serve_qc(qs, qw, matmul_impl="ref"):
    return QuantContext(
        mode="serve", cfg=qs["qcfg"],
        specs=specs_from_state(qs["gates"], qs["betas"], qs["signed"]),
        qweights=qw, matmul_impl=matmul_impl)


# ---------------------------------------------------------------------------
# Batched prefill ≡ scan of decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plen", [3, 7])
def test_prefill_slot_matches_scan_of_decode_steps(plen):
    """One causal forward per slot == the seed's token-by-token prefill."""
    cfg, params = _model()
    prompt = np.arange(1, plen + 1, dtype=np.int32)
    qc = QuantContext(mode="off")

    # seed path: scan decode_step over the prompt on a fresh cache
    cache_ref = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits_ref, cache_ref = tfm.decode_step(
            qc, params, cache_ref, jnp.asarray([t], jnp.int32), cfg)

    # new path: right-padded single forward into slot 0
    spad = 16
    toks = np.zeros((1, spad), np.int32)
    toks[0, :plen] = prompt
    cache_new = tfm.init_cache(cfg, 1, 32)
    logits_new, cache_new = tfm.prefill_slot(
        qc, params, jnp.asarray(toks), plen, cache_new, 0, cfg)

    np.testing.assert_allclose(
        np.asarray(logits_new[0, plen - 1, : cfg.vocab_size]),
        np.asarray(logits_ref[0, 0, : cfg.vocab_size]),
        rtol=2e-2, atol=2e-2)
    assert int(cache_new["pos"][0]) == plen == int(cache_ref["pos"][0])

    # and the caches are interchangeable: decode diverges by bf16 noise only
    nxt = jnp.asarray([5], jnp.int32)
    l1, _ = tfm.decode_step(qc, params, cache_ref, nxt, cfg)
    l2, _ = tfm.decode_step(qc, params, cache_new, nxt, cfg)
    np.testing.assert_allclose(
        np.asarray(l1[..., : cfg.vocab_size]),
        np.asarray(l2[..., : cfg.vocab_size]), rtol=2e-2, atol=2e-2)


def test_prefill_slot_counts_one_forward(capsys):
    """Engine accounting: one batched forward per admission, vs plen
    decode-step forwards (each ``slots`` wide) in the seed path."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=4, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, (9,)),
                           max_new=2))
    eng.run_to_completion()
    st = eng.stats
    assert st["prefill_forwards"] == 4
    assert st["seed_equiv_forwards"] == 4 * 9
    # slot-forward ratio: (plen * slots) seed slot-forwards vs 1 per admission
    ratio = st["seed_equiv_forwards"] * eng.slots / st["prefill_forwards"]
    assert ratio >= eng.slots


@pytest.mark.parametrize("arch,plen", [
    ("mamba2-1.3b", 11),        # ssm_chunk=8: chunk-aligned prefix + 3-token
    ("mamba2-1.3b", 6),         #   teacher-forced tail / pure exact length
    ("recurrentgemma-2b", 9),   # rglru + local ring: exact-length prefill
])
def test_recurrent_arch_prefill_matches_scan_of_decode(arch, plen):
    """Recurrent-state archs must not bake padding into the slot state:
    engine output == manual scan-of-decode-steps greedy, even with another
    request mid-generation in the neighboring slot (teacher-forced tail
    steps must not touch other slots' recurrent state)."""
    cfg, params = _model(arch=arch)
    rng = np.random.default_rng(plen)
    prompt = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=2, max_seq=32)
    # occupy slot 0 first so the probed request admits mid-flight
    eng.submit(Request(rid=9, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                       max_new=8))
    eng.step()
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    fin = {r.rid: r.output for r in eng.run_to_completion()}

    qc = QuantContext(mode="off")
    cache = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([t], jnp.int32), cfg)
    outs = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
    for _ in range(3):
        logits, cache = tfm.decode_step(
            qc, params, cache, jnp.asarray([outs[-1]], jnp.int32), cfg)
        outs.append(int(jnp.argmax(logits[0, 0, : cfg.vocab_size])))
    assert fin[0] == outs


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------


def test_slot_isolation_prefill_does_not_corrupt_neighbors():
    """A request's output is identical whether it shares the engine with
    other requests (admitted mid-flight, forcing interleaved prefills) or
    runs alone — i.e. one slot's prefill never corrupts another slot's KV."""
    cfg, params = _model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (int(p),))
               for p in (5, 9, 4, 11, 6)]

    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6))
    shared = {r.rid: r.output for r in eng.run_to_completion()}

    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, slots=1, max_seq=64)
        solo.submit(Request(rid=i, prompt=p, max_new=6))
        out = solo.run_to_completion()[0].output
        assert shared[i] == out, f"slot sharing changed request {i}"


def test_admission_and_retirement_ordering():
    """FIFO admission; retired slots immediately rehost the next waiter."""
    cfg, params = _model()
    rng = np.random.default_rng(2)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    # staggered lengths force slot 0 to retire before slot 1
    lens = [2, 5, 3, 4]
    for i, n in enumerate(lens):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (4,)),
                           max_new=n))

    eng._admit()
    assert [r.rid for r in eng.slot_req] == [0, 1]  # FIFO admission
    assert [r.rid for r in eng.waiting] == [2, 3]

    fin = eng.run_to_completion()
    rids = [r.rid for r in fin]
    assert sorted(rids) == [0, 1, 2, 3]
    assert rids.index(0) < rids.index(1)  # fewer tokens -> retires first
    assert rids.index(0) < rids.index(2)  # 2 rehosts 0's slot after it frees
    assert all(len(r.output) == n for r, n in
               zip(sorted(fin, key=lambda r: r.rid), lens))
    assert eng.slot_req == [None, None] and not eng.waiting


def test_max_new_one_retires_at_admission():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new=1))
    fin = eng.run_to_completion()
    assert len(fin) == 1 and len(fin[0].output) == 1 and fin[0].done


def test_device_resident_state_one_sync_shapes():
    """The tick's host transfer is three (slots,)-vectors; outputs accrue
    only for slots that were active when the tick ran."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=3, max_seq=32)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new=3))
    eng.step()
    # slots 1/2 idle: state must keep them inactive with no output
    active = np.asarray(jax.device_get(eng.state["active"]))
    assert active.tolist() == [True, False, False]
    assert len(eng.slot_req[0].output) == 2  # prefill token + one tick


# ---------------------------------------------------------------------------
# Int8 decode path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel"])
def test_int8_decode_matches_fake_quant_reference(granularity):
    """Serve-mode logits (fused-dequant GEMM off int codes) match the
    train-mode fake-quant fp32 reference within bf16 matmul tolerance."""
    cfg, params = _model()
    qs = _quant_state(cfg, params, granularity=granularity)
    qw, ledger = export_int_model(params, cfg, qs)
    assert qw, "no sites exported"
    assert all(b <= 8 for b in ledger.max_bits().values())

    toks = jnp.asarray([3, 7], jnp.int32)
    cache = tfm.init_cache(cfg, 2, 16)
    from repro.core.sites import merge_ranges
    qc_train = QuantContext(mode="train", cfg=qs["qcfg"], gates=qs["gates"],
                            ranges=merge_ranges(qs["betas"], qs["signed"]),
                            probes={})
    lt, _ = tfm.decode_step(qc_train, params, cache, toks, cfg)
    ls, _ = tfm.decode_step(_serve_qc(qs, qw), params, cache, toks, cfg)
    lt = np.asarray(lt[..., : cfg.vocab_size])
    ls = np.asarray(ls[..., : cfg.vocab_size])
    np.testing.assert_allclose(ls, lt, rtol=5e-2, atol=2e-2)


def test_int8_pallas_interpret_matches_ref_path():
    """The Pallas kernel (interpret) and the jnp reference produce the same
    serve-mode logits — kernel validation at the model level."""
    cfg, params = _model()
    qs = _quant_state(cfg, params)
    qw, _ = export_int_model(params, cfg, qs)
    toks = jnp.asarray([11], jnp.int32)
    cache = tfm.init_cache(cfg, 1, 16)
    outs = []
    for impl in ("ref", "pallas_interpret"):
        l, _ = tfm.decode_step(_serve_qc(qs, qw, impl), params, cache, toks,
                               cfg)
        outs.append(np.asarray(l[..., : cfg.vocab_size]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


def test_int8_engine_serves_end_to_end():
    """Full engine pass in serve mode: tokens come off the int8 hot path."""
    cfg, params = _model()
    qs = _quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, quant_state=qs,
                        matmul_impl="ref")
    assert len(eng.qweights) >= 8  # attn q/k/v/o + mlp gate/up/down + head
    rng = np.random.default_rng(4)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                           max_new=4))
    fin = eng.run_to_completion()
    assert len(fin) == 3
    for r in fin:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_export_skips_high_bit_sites_and_ledgers_them():
    """Sites whose gate maps above 8 bits are not exported (they'd lose
    their grid in int8) and serve via the fake-quant fallback — and that
    fallback is no longer silent: every rejected site lands in the export
    ledger with its reason, and the export warns once."""
    cfg, params = _model()
    qs = _quant_state(cfg, params, gate_init=4.5)  # T(4.5) = 32 bits
    with pytest.warns(UserWarning, match="NOT fully integer-quantized"):
        qw, ledger = export_int_model(params, cfg, qs)
    assert qw == {} and ledger.max_bits() == {}
    fb = ledger.fallbacks()
    assert fb and all(e["reason"] == "bits>8" for e in fb.values())
    assert all(e["bits"] == 32 for e in fb.values())
    # engine still runs on the fallback path
    with pytest.warns(UserWarning, match="NOT fully integer-quantized"):
        eng = ServingEngine(cfg, params, slots=1, max_seq=32, quant_state=qs)
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new=2))
    fin = eng.run_to_completion()
    assert len(fin) == 1 and len(fin[0].output) == 2


# ---------------------------------------------------------------------------
# Packed sub-byte decode: bit-for-bit against the int8 oracle, every config
# ---------------------------------------------------------------------------


def _decode_inputs(cfg, rng):
    if cfg.embed_input:
        return jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)), jnp.int32)
    return jnp.asarray(rng.normal(size=(2, 1, cfg.d_model)), jnp.float32) * 0.3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_packed_decode_matches_int8_oracle_every_config(arch):
    """The §11 acceptance gate: a mixed 2/4/8-bit export served from PACKED
    sub-byte storage produces decode logits bit-for-bit identical to the
    same export in the unpacked int8 oracle layout, for every architecture.
    Packing must be pure storage — zero numerics."""
    cfg, params = _model(arch=arch)
    qs = make_mixed_quant_state(cfg, params)
    qw_packed, ledger = export_int_model(params, cfg, qs)
    qw_oracle, _ = export_int_model(params, cfg, qs, pack=False)
    assert qw_packed, f"{arch}: no sites exported"
    assert any(qt.storage_bits < 8 for qt in qw_packed.values()), \
        f"{arch}: mixed state exported no sub-byte site"
    # packed device bytes follow the ceil(bits/8) accounting exactly
    for key, qt in qw_packed.items():
        per = 8 // qt.storage_bits
        want_rows = -(-qt.k // per)
        assert qt.codes.shape[-2] == want_rows, key
        assert qt.codes_bytes() < qw_oracle[key].codes_bytes() \
            or qt.storage_bits == 8, key

    rng = np.random.default_rng(7)
    toks = _decode_inputs(cfg, rng)
    cache = tfm.init_cache(cfg, 2, 16)
    lp, _ = tfm.decode_step(_serve_qc(qs, qw_packed), params, cache, toks,
                            cfg)
    lo, _ = tfm.decode_step(_serve_qc(qs, qw_oracle), params, cache, toks,
                            cfg)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lo),
                                  err_msg=f"{arch}: packed != int8 oracle")


def test_engine_serves_packed_sub_byte_end_to_end():
    """Engine pass on a mixed 2/4/8-bit export: tokens come off the packed
    kernels, and the quant_report ledger shows sub-byte device bytes."""
    cfg, params = _model()
    qs = make_mixed_quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, quant_state=qs,
                        matmul_impl="ref")
    assert any(qt.storage_bits < 8 for qt in eng.qweights.values())
    rng = np.random.default_rng(11)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                           max_new=4))
    fin = eng.run_to_completion()
    assert len(fin) == 3 and all(len(r.output) == 4 for r in fin)
    rep = eng.quant_report()
    t = rep["totals"]
    assert t["bytes_per_weight"] < t["uniform_int8_bytes_per_weight"]
    assert t["bytes_device"] < t["bytes_uniform_int8"] < t["bytes_fp32"]
    assert t["fallback_sites"] == 0
    assert rep["bops"]["model"] < rep["bops"]["uniform_int8"]


# ---------------------------------------------------------------------------
# Request lifecycle: SamplingParams + in-tick sampling (DESIGN.md §12)
# ---------------------------------------------------------------------------

# every arch the engine can serve from token prompts (the two modality
# stubs take embeddings, not tokens, and have no request-level entry)
TOKEN_ARCHS = [a for a in ALL_ARCHS if get_smoke_config(a).embed_input]


def test_sampling_params_validation():
    p = SamplingParams(temperature=0.7, top_k=5, top_p=0.9, seed=1,
                      stop=(3, 7), max_new=4)
    assert not p.greedy and p.stop == (3, 7)
    assert SamplingParams().greedy
    for bad in (dict(temperature=-1.0), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(max_new=0), dict(stop=(-2,))):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_mask_logits_top_k_top_p_support():
    """top-k bounds the kept set by rank; top-p keeps the smallest head of
    the sorted distribution whose mass reaches p (first token always kept);
    disabled knobs (0 / 1.0) keep everything."""
    rng = np.random.default_rng(0)
    l = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    off = mask_logits(l, jnp.zeros((4,), jnp.int32), jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(off), np.asarray(l))

    k = jnp.asarray([1, 3, 8, 0], jnp.int32)
    kept = (np.asarray(mask_logits(l, k, jnp.ones((4,)))) > -1e30).sum(-1)
    assert kept.tolist() == [1, 3, 8, 64]
    # the kept lanes are exactly the top-k by value
    m = np.asarray(mask_logits(l, k, jnp.ones((4,))))
    for r in range(3):
        top = set(np.argsort(-np.asarray(l[r]))[: int(k[r])])
        assert set(np.nonzero(m[r] > -1e30)[0]) == top

    tiny = mask_logits(l, jnp.zeros((4,), jnp.int32),
                       jnp.full((4,), 1e-6, jnp.float32))
    kept = (np.asarray(tiny) > -1e30).sum(-1)
    assert kept.tolist() == [1, 1, 1, 1]  # first sorted token always kept
    p = jnp.asarray([0.5, 0.9, 1.0, 0.99], jnp.float32)
    m = np.asarray(mask_logits(l, jnp.zeros((4,), jnp.int32), p))
    for r in range(4):
        probs = np.exp(np.asarray(l[r])) / np.exp(np.asarray(l[r])).sum()
        order = np.argsort(-probs)
        csum = np.cumsum(probs[order])
        want = order[: int(np.searchsorted(csum, float(p[r])) + 1)]
        assert set(np.nonzero(m[r] > -1e30)[0]) == set(want), r


def test_sample_tokens_greedy_rows_bit_exact_and_support():
    """temperature<=0 rows return exactly argmax; sampled rows only ever
    draw from their top-k support."""
    rng = np.random.default_rng(1)
    l = jnp.asarray(rng.normal(size=(3, 32)) * 3, jnp.float32)
    greedy = np.asarray(jnp.argmax(l, -1))
    for trial in range(20):
        keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3) + 3 * trial)
        toks = np.asarray(sample_tokens(
            l, keys, jnp.asarray([0.0, 1.5, 0.0]),
            jnp.asarray([0, 3, 0], jnp.int32), jnp.ones((3,))))
        assert toks[0] == greedy[0] and toks[2] == greedy[2]
        assert toks[1] in set(np.argsort(-np.asarray(l[1]))[:3])


@pytest.mark.parametrize("arch", TOKEN_ARCHS)
def test_generate_argmax_matches_manual_greedy_every_arch(arch):
    """The §12 acceptance gate: temperature=0 generation through the
    ``generate()`` facade is identical to the manual scan-of-decode-steps
    argmax oracle — the pre-redesign greedy path — on every servable arch,
    in the ring layout AND (where the arch has attention) the paged one."""
    cfg, params = _model(arch=arch)
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)

    qc = QuantContext(mode="off")
    cache = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([int(t)], jnp.int32), cfg)
    want = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
    for _ in range(2):
        logits, cache = tfm.decode_step(
            qc, params, cache, jnp.asarray([want[-1]], jnp.int32), cfg)
        want.append(int(jnp.argmax(logits[0, 0, : cfg.vocab_size])))

    kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
    layouts = ["ring"]
    if any(k in ("global", "local") for k in kinds):
        layouts.append("paged")
    for layout in layouts:
        eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                            kv_layout=layout)
        res = eng.generate([prompt], SamplingParams(max_new=3))
        assert res[0].tokens == want, (arch, layout)
        assert res[0].finish_reason == "length"


def test_seed_determinism_across_placement_order_and_layout():
    """Identical ``SamplingParams(seed=...)`` produce identical token
    streams no matter which slot hosts the request, what was admitted
    before it, or which KV layout backs the cache — the token stream is a
    pure function of (prompt, params)."""
    cfg, params = _model()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (9,))
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=123,
                        max_new=6)
    streams = []
    for layout in ("ring", "paged"):
        solo = ServingEngine(cfg, params, slots=3, max_seq=64,
                             kv_layout=layout)
        streams.append((layout, "solo", solo.generate([prompt], sp)[0].tokens))
        # crowded: two sampled decoys admitted first push the probe into
        # slot 2, and it admits mid-flight
        eng = ServingEngine(cfg, params, slots=3, max_seq=64,
                            kv_layout=layout)
        for i in (50, 51):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                params=SamplingParams(temperature=0.5, seed=i, max_new=9)))
        eng.step()
        streams.append((layout, "crowded", eng.generate([prompt], sp)[0].tokens))
    want = streams[0][2]
    assert len(set(want)) > 1
    for layout, mode, got in streams[1:]:
        assert got == want, f"{layout}/{mode} diverged: {got} vs {want}"


def test_seeded_stream_survives_prefix_shared_admission():
    """A fully prefix-shared (teacher-forced, zero-prefill) admission of the
    same prompt+params reproduces the registrant's sampled stream: the key
    chain is positioned by tokens emitted, not by admission path."""
    cfg, params = _model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (11,))
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=7, max_new=5)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    a, b = eng.generate([prompt, prompt], [sp, sp])
    assert eng.stats["shared_admissions"] == 1
    assert a.tokens == b.tokens
    assert len(set(a.tokens)) > 1


def test_host_sync_ledger_one_sync_per_tick_with_sampling():
    """§8's one-host-sync-per-tick contract survives in-tick sampling: the
    ledger shows exactly one transfer per decode tick, and none from the
    sampling math itself."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)) for _ in range(3)]
    eng.generate(prompts, SamplingParams(temperature=1.0, top_p=0.9, seed=3,
                                         max_new=5))
    st = eng.stats
    assert st["decode_ticks"] > 0
    assert st["tick_syncs"] == st["decode_ticks"]
    # admission first-tokens are fetched ONE batched transfer per wave:
    # 3 requests through 2 slots = 2 waves (no prefix-registration reads,
    # the 6-token prompts hold no full block)
    assert st["admit_syncs"] == 2
    assert st["stat_syncs"] == 0


def test_generate_stream_yields_per_tick_deltas():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (4,)),
               rng.integers(0, cfg.vocab_size, (7,))]
    events = list(eng.generate_stream(prompts, SamplingParams(max_new=4)))
    assert all(isinstance(ev, TokenEvent) for ev in events)
    by_rid = {}
    for ev in events:
        by_rid.setdefault(ev.rid, []).append(ev)
    assert len(by_rid) == 2
    for evs in by_rid.values():
        assert [e.index for e in evs] == [0, 1, 2, 3]
        assert [e.done for e in evs] == [False, False, False, True]
        assert evs[-1].finish_reason == "length"
        assert all(e.finish_reason is None for e in evs[:-1])


def test_generate_on_token_callback_matches_results():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (5,)) for _ in range(2)]
    seen = []
    res = eng.generate(prompts, SamplingParams(max_new=3),
                       on_token=lambda ev: seen.append(ev))
    streamed = {}
    for ev in seen:
        streamed.setdefault(ev.rid, []).append(ev.token)
    assert {r.rid: r.tokens for r in res} == streamed


def test_stop_token_truncates_stream_and_sets_reason():
    """Stop tokens end the request in the tick that emits them — including
    a stop hit on the very first (prefill-sampled) token — and the slot
    rehosts the next request cleanly."""
    cfg, params = _model()
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, (7,))
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    base = eng.generate([prompt], SamplingParams(max_new=8))[0].tokens

    mid = base[3]
    k = base.index(mid)
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    res = eng.generate([prompt], SamplingParams(max_new=8, stop=(mid,)))[0]
    assert res.tokens == base[: k + 1]
    assert res.finish_reason == "stop"

    # first-token stop: retires at admission, zero decode ticks for it,
    # and the deactivated slot serves the next request unperturbed
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    r0, r1 = eng.generate([prompt, prompt],
                          [SamplingParams(max_new=8, stop=(base[0],)),
                           SamplingParams(max_new=8)])
    assert r0.tokens == [base[0]] and r0.finish_reason == "stop"
    assert r1.tokens == base


def test_request_legacy_max_new_folds_into_params():
    req = Request(rid=0, prompt=np.asarray([1, 2], np.int32), max_new=9)
    assert req.params.max_new == 9 and req.params.greedy
    req = Request(rid=1, prompt=np.asarray([1], np.int32),
                  params=SamplingParams(max_new=3))
    assert req.max_new == 3


def test_too_many_stop_tokens_rejected_at_submit():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=1, max_seq=32, max_stop=2)
    with pytest.raises(ValueError, match="stop tokens"):
        eng.submit(Request(rid=0, prompt=np.asarray([1], np.int32),
                           params=SamplingParams(stop=(1, 2, 3))))
    # a bad batch member must not orphan earlier members in the queue
    with pytest.raises(ValueError, match="stop tokens"):
        eng.generate([np.asarray([1], np.int32), np.asarray([2], np.int32)],
                     [SamplingParams(), SamplingParams(stop=(1, 2, 3))])
    assert not eng.waiting


def test_generate_finishing_on_final_permitted_tick_returns():
    """max_ticks boundary: a batch that completes on the last allowed tick
    must return its results, not raise."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    res = eng.generate([np.asarray([1, 2], np.int32)],
                       SamplingParams(max_new=3), max_ticks=2)
    assert res[0].tokens and res[0].finish_reason == "length"
    evs = list(eng.generate_stream([np.asarray([3, 4], np.int32)],
                                   SamplingParams(max_new=3), max_ticks=2))
    assert len(evs) == 3 and evs[-1].done
    with pytest.raises(RuntimeError, match="still running"):
        eng.generate([np.asarray([5], np.int32)],
                     SamplingParams(max_new=8), max_ticks=2)


def test_generate_stream_submits_eagerly():
    """The batch must be in the queue before the stream is first advanced,
    so other engine traffic can pick it up either way."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    stream = eng.generate_stream([np.asarray([1, 2, 3], np.int32)],
                                 SamplingParams(max_new=2))
    assert len(eng.waiting) == 1
    assert len(list(stream)) == 2


# ---------------------------------------------------------------------------
# Quantized KV cache: int8 blocks vs the float-pool oracle (DESIGN.md §14)
# ---------------------------------------------------------------------------

import math  # noqa: E402

from repro.quant import KVQuantSpec  # noqa: E402
from repro.serving import kv_pool  # noqa: E402

KV_BS = 8
KV_MAX_SEQ = 32


def _kv_spec(cfg, bits=8):
    # same alignment rule as the engine: largest power-of-two group <= 32
    # that divides head_dim, so the fused kernel never sees a ragged group
    return KVQuantSpec(bits=bits, group_size=math.gcd(cfg.head_dim, 32),
                       head_dim=cfg.head_dim)


def _kv_inputs(cfg, plen, key=1):
    k = jax.random.PRNGKey(key)
    if cfg.embed_input:
        return jax.random.randint(k, (1, plen), 0, cfg.vocab_size)
    return jax.random.normal(k, (1, plen, cfg.d_model), jnp.float32) * 0.3


def _kv_mrope(cfg, s):
    if cfg.mrope_sections is None:
        return None
    return jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))


def _kv_decode_logits(cfg, params, layout, kv_spec):
    """Teacher-forced prefill + 4 decode steps; per-step logit rows."""
    qc = QuantContext(mode="off")
    plen = 9
    x = _kv_inputs(cfg, plen)
    kv_dtype = jnp.float32 if kv_spec is None else jnp.bfloat16
    if layout == "ring":
        cache = tfm.init_cache(cfg, 1, KV_MAX_SEQ, kv_dtype=kv_dtype,
                               kv_spec=kv_spec)
        alloc = None
    else:
        mb = KV_MAX_SEQ // KV_BS
        cache = tfm.init_paged_cache(cfg, 1, mb + 1, KV_BS,
                                     kv_dtype=kv_dtype, kv_spec=kv_spec)
        alloc = kv_pool.init_alloc(mb + 1, 1, mb)
        alloc = kv_pool.alloc_range(alloc, 0, 0, -(-plen // KV_BS))
    table = None if alloc is None else alloc["table"]
    lg, cache = tfm.prefill_slot(qc, params, x, plen, cache, 0, cfg,
                                 mrope_pos=_kv_mrope(cfg, plen),
                                 block_table=table)
    rows = [np.asarray(lg[0, plen - 1, : cfg.vocab_size])]
    adv = jnp.ones((1,), jnp.int32)
    rng = np.random.default_rng(2)
    for t in range(4):
        if cfg.embed_input:
            tok = jnp.asarray([int(rng.integers(0, cfg.vocab_size))],
                              jnp.int32)
        else:
            tok = jax.random.normal(jax.random.PRNGKey(10 + t),
                                    (1, 1, cfg.d_model), jnp.float32) * 0.3
        if alloc is not None:
            alloc = kv_pool.tick_alloc(alloc, cache["pos"], adv, KV_BS)
        lg, cache = tfm.decode_step(
            qc, params, cache, tok, cfg, advance=adv,
            block_table=None if alloc is None else alloc["table"])
        rows.append(np.asarray(lg[0, 0, : cfg.vocab_size]))
    return rows


# Measured headroom: every arch stays under 0.013 max-abs-err except
# arctic-480b, whose expert router sits on a near-tie at one step of this
# seed — KV rounding flips the expert pick and shifts ~40% of that step's
# logits by ~0.13. That's router sensitivity, not codec error, so it gets
# its own documented bound instead of loosening the gate for everyone.
KV_INT8_ATOL = {"arctic-480b": 0.2}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_int8_kv_decode_logits_near_float_pool_oracle(arch):
    """§14 acceptance gate: int8 group-wise KV decode logits stay within a
    tested tolerance of the fp32 float-pool oracle on every attention arch,
    in BOTH the ring and paged layouts, step after step."""
    cfg = get_smoke_config(arch)
    kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
    if not any(k in ("global", "local") for k in kinds):
        pytest.skip("attention-free arch: no KV cache to quantize")
    cfg, params = _model(arch=arch)
    spec = _kv_spec(cfg)
    atol = KV_INT8_ATOL.get(arch, 2e-2)
    for layout in ("ring", "paged"):
        oracle = _kv_decode_logits(cfg, params, layout, None)
        quant = _kv_decode_logits(cfg, params, layout, spec)
        for t, (o, q) in enumerate(zip(oracle, quant)):
            np.testing.assert_allclose(
                q, o, rtol=2e-2, atol=atol,
                err_msg=f"{arch} {layout} step {t}")


def test_int8_kv_preempted_streams_identical_to_solo():
    """Preemption + resume over quantized blocks: steal/requantize-free
    restore must leave every stream identical to an unpressured solo run
    with the same int8 KV storage."""
    cfg, params = _model()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (12,)) for _ in range(4)]
    sps = [SamplingParams(temperature=0.8, top_p=0.9, seed=100 + i,
                          max_new=24) for i in range(4)]
    solo = []
    for p, sp in zip(prompts, sps):
        e = ServingEngine(cfg, params, slots=1, max_seq=64, kv_dtype="int8")
        solo.append(e.generate([p], [sp])[0].tokens)
    eng = ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=14,
                        kv_dtype="int8")
    assert eng.preemption  # the pool is undersized on purpose
    outs = eng.generate(prompts, sps)
    st = eng.stats
    assert st["preemptions"] > 0
    assert st["resumed_admissions"] > 0
    for o, s in zip(outs, solo):
        assert o.tokens == s
    assert eng.pool_stats()["blocks_in_use"] == 0


def test_int8_kv_prefix_shared_admission_streams_identical():
    """Prefix sharing + CoW over quantized blocks: a fully shared admission
    reproduces both the registrant's stream and an unshared solo run."""
    cfg, params = _model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (16,))
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=7, max_new=6)
    solo_eng = ServingEngine(cfg, params, slots=1, max_seq=64,
                             kv_dtype="int8")
    solo = solo_eng.generate([prompt], [sp])[0].tokens
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, kv_dtype="int8")
    a, b = eng.generate([prompt, prompt], [sp, sp])
    assert eng.stats["shared_admissions"] == 1
    assert a.tokens == b.tokens == solo
    assert len(set(a.tokens)) > 1


# ---------------------------------------------------------------------------
# Chunked prefill ≡ whole-prompt prefill (DESIGN.md §15)
# ---------------------------------------------------------------------------


def _has_attention(cfg):
    kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
    return any(k in ("global", "local") for k in kinds)


def _chunk_run(cfg, params, layout, kv_dtype, chunk):
    """One greedy + one seeded-sampled request through an engine with the
    given ``prefill_chunk_tokens``; returns comparable terminal streams."""
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, (13,)),
               rng.integers(0, cfg.vocab_size, (21,))]
    sps = [SamplingParams(max_new=4),
           SamplingParams(max_new=4, temperature=0.8, top_k=13, seed=5)]
    eng = ServingEngine(cfg, params, slots=2, max_seq=32, kv_layout=layout,
                        kv_dtype=kv_dtype, prefill_chunk_tokens=chunk)
    res = eng.generate(prompts, sps)
    st = eng.stats
    # the one-host-sync-per-tick ledger survives chunked admission
    assert st["tick_syncs"] == st["decode_ticks"]
    if chunk is not None and chunk < 13:
        assert st["prefill_chunks"] > len(prompts)  # prompts really split
    return [(r.tokens, r.finish_reason) for r in res]


@pytest.mark.parametrize("arch", TOKEN_ARCHS)
def test_chunked_prefill_streams_bit_identical_every_arch(arch):
    """The §15 acceptance gate: token streams are BIT-IDENTICAL under
    ``prefill_chunk_tokens`` ∈ {one KV block, ragged, ∞} on every
    token-servable arch, in the ring layout AND (where the arch has
    attention) the paged one, greedy and seeded-sampled alike.

    bf16 KV compares every chunk setting against the legacy whole-prompt
    engine (``prefill_chunk_tokens=None``): the bf16 round-trip is the
    identity, so chunked and legacy attends see the same key bits. int8 KV
    compares chunk settings against the ∞-chunk run instead — the legacy
    prefill attends over fresh (non-round-tripped) K/V, while every chunked
    attend reads storage-dtype codes, which is its own (chunk-invariant)
    numeric contract."""
    cfg, params = _model(arch=arch)
    layouts = ["ring"] + (["paged"] if _has_attention(cfg) else [])
    for layout in layouts:
        want = _chunk_run(cfg, params, layout, "bf16", None)
        for chunk in (8, 3, 1000):
            got = _chunk_run(cfg, params, layout, "bf16", chunk)
            assert got == want, (arch, layout, "bf16", chunk)
        if not _has_attention(cfg):
            continue  # attention-free arch: no KV codes to quantize
        want = _chunk_run(cfg, params, layout, "int8", 1000)
        for chunk in (8, 3):
            got = _chunk_run(cfg, params, layout, "int8", chunk)
            assert got == want, (arch, layout, "int8", chunk)
