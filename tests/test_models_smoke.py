"""Per-architecture smoke tests (reduced configs): fwd / train step / decode.

Required deliverable (f): every assigned architecture instantiates at reduced
scale and runs one forward/train step on CPU with finite outputs; decode is
checked for logits-consistency against the full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.sites import QuantConfig, QuantContext, collect_sites, init_gates
from repro.models import transformer as tfm

jax.config.update("jax_enable_x64", False)

B, S = 2, 16


def _inputs(cfg, key=0, s=S):
    k = jax.random.PRNGKey(key)
    if cfg.embed_input:
        return jax.random.randint(k, (B, s), 0, cfg.vocab_size)
    return jax.random.normal(k, (B, s, cfg.d_model), jnp.float32) * 0.3


def _mrope(cfg, s=S):
    if cfg.mrope_sections is None:
        return None
    pos = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, B, s))
    return pos


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    qc = QuantContext(mode="off")
    logits = tfm.forward_train(qc, params, _inputs(cfg), cfg,
                               mrope_pos=_mrope(cfg), moe_impl="dense_all")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    # padded vocab ids are masked out
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size :].max()) < -1e29


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_grads_finite(arch):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    x = _inputs(cfg)
    targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    @jax.jit
    def loss_fn(p):
        qc = QuantContext(mode="off")
        logits = tfm.forward_train(qc, p, x, cfg, mrope_pos=_mrope(cfg),
                                   moe_impl="dense_all")
        logp = jax.nn.log_softmax(logits[..., : cfg.vocab_size])
        return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # at least 90% of leaves get nonzero gradient signal
    nonzero = sum(float(jnp.abs(g).max()) > 0 for g in leaves)
    assert nonzero / len(leaves) > 0.7, f"{nonzero}/{len(leaves)}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    """Greedy decode logits == train-forward logits at each position."""
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(3))
    s = 8 if cfg.family != "ssm" else 8
    x = _inputs(cfg, key=4, s=s)
    qc = QuantContext(mode="off")
    ref = tfm.forward_train(qc, params, x, cfg, mrope_pos=_mrope(cfg, s),
                            moe_impl="dense_all", remat=False)

    cache = tfm.init_cache(cfg, B, max_seq=16)
    outs = []
    for t in range(s):
        tok = x[:, t] if cfg.embed_input else x[:, t : t + 1]
        mp = None
        if cfg.mrope_sections is not None:
            mp = jnp.broadcast_to(jnp.asarray(t)[None, None, None], (3, B, 1))
        logits, cache = tfm.decode_step(
            QuantContext(mode="off"), params, cache, tok, cfg, mrope_pos=mp)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec[..., : cfg.vocab_size], np.float32),
        np.asarray(ref[..., : cfg.vocab_size], np.float32),
        rtol=0.08, atol=0.08,
    )


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x22b",
                                  "mamba2-1.3b", "recurrentgemma-2b",
                                  "gemma2-2b"])
def test_cgmq_integration(arch):
    """Quantized train-mode forward: sites, gates, BOP, probe grads."""
    from repro.core import bop as bop_lib
    from repro.core.sites import (
        init_probes, init_ranges_from_weights, merge_ranges,
        split_learnable_ranges,
    )

    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(5))
    x = _inputs(cfg, key=6)
    qcfg = QuantConfig(granularity="per_tensor")

    sites = collect_sites(
        lambda qc, p, xx: tfm.forward_train(qc, p, xx, cfg,
                                            mrope_pos=_mrope(cfg),
                                            moe_impl="dense_all"),
        params, jax.eval_shape(lambda: x), cfg=qcfg,
    )
    assert sites, "no sites collected"
    # scanned sites must carry the stack multiplier
    stacked = [s for s in sites.values() if s.stack > 1]
    assert stacked, "expected scan-stacked sites"
    gates = init_gates(sites, qcfg)
    probes = init_probes(sites, qcfg)
    ranges = init_ranges_from_weights(sites, qcfg, lambda n: None)
    betas, signed = split_learnable_ranges(ranges)

    fp_bop = bop_lib.fp32_bop(sites)
    assert fp_bop > 0
    r = float(bop_lib.rbop(sites, gates))
    assert r == pytest.approx(1.0)  # init gates = 32-bit everywhere

    targets = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)

    def loss_fn(probes):
        qc = QuantContext(mode="train", cfg=qcfg, gates=gates,
                          ranges=merge_ranges(betas, signed), probes=probes)
        logits = tfm.forward_train(qc, params, x, cfg, mrope_pos=_mrope(cfg),
                                   moe_impl="dense_all")
        logp = jax.nn.log_softmax(logits[..., : cfg.vocab_size])
        loss = -jnp.mean(jnp.take_along_axis(logp, targets[..., None], -1))
        return loss, (qc.act_stats, qc.weight_stats)

    (loss, (astats, wstats)), pgrads = jax.value_and_grad(
        loss_fn, has_aux=True)(probes)
    assert bool(jnp.isfinite(loss))
    # probe gradients exist for stacked sites with the stacked shape
    some_stacked = next(s for s in sites.values() if s.stack > 1 and s.act_quantized)
    key = some_stacked.name + ".a"
    assert pgrads[key].shape == gates[key].shape
    assert bool(jnp.all(jnp.isfinite(pgrads[key])))
    # weight stats came back stacked as well
    wkey = some_stacked.name + ".w"
    assert wstats[wkey].shape == gates[wkey].shape
