"""Paged KV cache tests (DESIGN.md §10).

Four layers of coverage:
  * the hard equivalence gate — paged decode logits match the ring-cache
    path to bf16 tolerance on EVERY transformer config with attention;
  * kernel validation — the Pallas paged-attention kernel (interpret mode)
    against the pure-jnp oracle;
  * allocator state machine — alloc / share / tick-alloc / CoW / free
    round-trips on the device-resident free list;
  * scheduler behavior — prefix sharing admits N same-prefix requests with
    ONE prefill, copy-on-write isolates divergent continuations, and
    retirement returns every block to the pool.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.sites import QuantContext
from repro.kernels.paged_attention.ops import paged_attention_op
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models import transformer as tfm
from repro.serving import kv_pool
from repro.serving.engine import Request, SamplingParams, ServingEngine

ATTN_ARCHS = [
    a for a in ALL_ARCHS
    if any(k in ("global", "local")
           for k in (list(get_smoke_config(a).block_pattern)
                     + list(get_smoke_config(a).remainder_kinds)))
]

BS = 8          # block size
MAX_SEQ = 32


def _model(arch, seed=0):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _inputs(cfg, plen, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.embed_input:
        return jax.random.randint(k, (1, plen), 0, cfg.vocab_size)
    return jax.random.normal(k, (1, plen, cfg.d_model), jnp.float32) * 0.3


def _mrope(cfg, s):
    if cfg.mrope_sections is None:
        return None
    return jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))


# ---------------------------------------------------------------------------
# Equivalence gate: paged decode == ring decode on every transformer config
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ATTN_ARCHS)
def test_paged_decode_matches_ring_every_config(arch):
    """The acceptance gate: after an identical prefill, the paged block-pool
    decode path must reproduce the ring-cache decode logits to bf16
    tolerance, step after step, for every attention-bearing architecture
    (global, local/ring-window, GQA/MQA/MHA, softcap, qk-norm, M-RoPE, MoE,
    hybrid recurrent). Attention-free archs have no KV to page."""
    cfg, params = _model(arch)
    qc = QuantContext(mode="off")
    plen = 9
    x = _inputs(cfg, plen, key=1)

    cache_r = tfm.init_cache(cfg, 1, MAX_SEQ)
    logits_r, cache_r = tfm.prefill_slot(
        qc, params, x, plen, cache_r, 0, cfg, mrope_pos=_mrope(cfg, plen))

    mb = MAX_SEQ // BS
    nb = mb + 1
    cache_p = tfm.init_paged_cache(cfg, 1, nb, BS)
    alloc = kv_pool.init_alloc(nb, 1, mb)
    alloc = kv_pool.alloc_range(alloc, 0, 0, -(-plen // BS))
    logits_p, cache_p = tfm.prefill_slot(
        qc, params, x, plen, cache_p, 0, cfg, mrope_pos=_mrope(cfg, plen),
        block_table=alloc["table"])

    np.testing.assert_allclose(
        np.asarray(logits_p[0, plen - 1, : cfg.vocab_size]),
        np.asarray(logits_r[0, plen - 1, : cfg.vocab_size]),
        rtol=2e-2, atol=2e-2)

    rng = np.random.default_rng(2)
    adv = jnp.ones((1,), jnp.int32)
    for t in range(4):
        if cfg.embed_input:
            tok = jnp.asarray([int(rng.integers(0, cfg.vocab_size))],
                              jnp.int32)
        else:
            tok = jax.random.normal(jax.random.PRNGKey(10 + t),
                                    (1, 1, cfg.d_model), jnp.float32) * 0.3
        lr, cache_r = tfm.decode_step(qc, params, cache_r, tok, cfg,
                                      advance=adv)
        alloc = kv_pool.tick_alloc(alloc, cache_p["pos"], adv, BS)
        lp, cache_p = tfm.decode_step(qc, params, cache_p, tok, cfg,
                                      advance=adv,
                                      block_table=alloc["table"])
        np.testing.assert_allclose(
            np.asarray(lp[..., : cfg.vocab_size]),
            np.asarray(lr[..., : cfg.vocab_size]),
            rtol=2e-2, atol=2e-2, err_msg=f"{arch} step {t}")
        assert int(cache_p["pos"][0]) == int(cache_r["pos"][0]) == plen + t + 1


# ---------------------------------------------------------------------------
# Kernel: Pallas (interpret) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window,softcap", [(None, None), (8, None),
                                            (None, 30.0), (8, 50.0)])
def test_paged_attention_pallas_matches_ref(window, softcap):
    rng = np.random.default_rng(0)
    b, kvh, g, hd, bs, mb, nb = 3, 2, 4, 16, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    # distinct physical blocks per row, with unallocated (-1) tails
    table = np.full((b, mb), -1, np.int32)
    phys = rng.permutation(np.arange(1, nb))
    pos = np.asarray([5, 12, 25], np.int32)
    k = 0
    for r in range(b):
        for j in range(int(pos[r]) // bs + 1):
            table[r, j] = phys[k]
            k += 1
    table = jnp.asarray(table)
    posj = jnp.asarray(pos)
    want = paged_attention_ref(q, kp, vp, table, posj, window=window,
                               softcap=softcap)
    got = paged_attention_op(q, kp, vp, table, posj, window=window,
                             softcap=softcap, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Allocator state machine
# ---------------------------------------------------------------------------


def _snap(alloc):
    return {k: np.asarray(jax.device_get(v)) for k, v in alloc.items()}


def test_alloc_free_roundtrip_preserves_free_list():
    alloc = kv_pool.init_alloc(9, 2, 4)
    a0 = _snap(alloc)
    assert a0["n_free"] == 8 and a0["ref"][0] == 1
    alloc = kv_pool.alloc_range(alloc, 0, 0, 3)
    alloc = kv_pool.alloc_range(alloc, 1, 0, 2)
    a = _snap(alloc)
    assert a["n_free"] == 3
    row0, row1 = a["table"][0], a["table"][1]
    assert (row0[:3] > 0).all() and (row1[:2] > 0).all()
    used = set(row0[:3]) | set(row1[:2])
    assert len(used) == 5, "blocks must be distinct"
    assert all(a["ref"][i] == 1 for i in used)
    alloc = kv_pool.free_slot(alloc, 0)
    alloc = kv_pool.free_slot(alloc, 1)
    a = _snap(alloc)
    assert a["n_free"] == 8
    assert (a["table"] == -1).all()
    assert set(a["free"][:8]) == set(range(1, 9)), "free list lost blocks"
    assert (a["ref"][1:] == 0).all()


def test_share_prefix_refcounts_block_until_last_user_frees():
    alloc = kv_pool.init_alloc(9, 2, 4)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 2)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(row0), 2)
    a = _snap(alloc)
    assert (a["table"][1][:2] == row0[:2]).all()
    assert all(a["ref"][i] == 2 for i in row0[:2])
    alloc = kv_pool.free_slot(alloc, 0)
    a = _snap(alloc)
    assert a["n_free"] == 6, "shared blocks must survive the first free"
    assert all(a["ref"][i] == 1 for i in row0[:2])
    alloc = kv_pool.free_slot(alloc, 1)
    a = _snap(alloc)
    assert a["n_free"] == 8
    assert set(a["free"][:8]) == set(range(1, 9))


def test_tick_alloc_pops_only_for_rows_entering_new_blocks():
    alloc = kv_pool.init_alloc(17, 4, 4)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 1)
    alloc = kv_pool.alloc_range(alloc, 1, 0, 1)
    pos = jnp.asarray([8, 3, 0, 0], jnp.int32)   # row 0 crosses into block 1
    mask = jnp.asarray([1, 1, 0, 0], jnp.int32)  # rows 2/3 idle
    before = _snap(alloc)["n_free"]
    alloc = kv_pool.tick_alloc(alloc, pos, mask, 8)
    a = _snap(alloc)
    assert a["n_free"] == before - 1
    assert a["table"][0, 1] > 0 and a["ref"][a["table"][0, 1]] == 1
    assert a["table"][1, 1] == -1           # row 1 still inside block 0
    assert (a["table"][2:] == -1).all()     # idle rows untouched


def test_cow_block_gives_private_copy():
    cfg = get_smoke_config("tinyllama-1.1b")
    alloc = kv_pool.init_alloc(9, 2, 2)
    pool = kv_pool.init_pool(cfg, 9, BS)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 1)
    old = int(jax.device_get(alloc["table"][0, 0]))
    pool["k"] = pool["k"].at[old].set(1.5)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(row0), 1)
    alloc, layers = kv_pool.cow_block(alloc, [pool], 1, 0)
    a = _snap(alloc)
    new = int(a["table"][1, 0])
    assert new != old and a["ref"][old] == 1 and a["ref"][new] == 1
    np.testing.assert_array_equal(
        np.asarray(layers[0]["k"][new]), np.asarray(layers[0]["k"][old]))


# ---------------------------------------------------------------------------
# Allocator invariants under random op storms (property test, §13)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hypothesis not installed: deterministic shim
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

_NB, _SLOTS, _MB = 17, 4, 4     # 16 usable blocks, 4 slots, 4 blocks/slot


def _check_alloc_invariants(alloc, nb=_NB):
    """The §13 pool-safety contract, checked after EVERY operation:
    refcounts never negative, the free stack never double-pops, and no
    block is ever lost or aliased — in_use + free == num_blocks - 1
    (block 0 is the pinned garbage lane). ``in_use`` counts device refs,
    so LRU-style retained blocks (ref without a table entry) are covered
    too."""
    a = _snap(alloc)
    n_free = int(a["n_free"])
    assert 0 <= n_free <= nb - 1
    assert (a["ref"] >= 0).all(), "negative refcount"
    assert a["ref"][0] >= 1, "garbage block must stay pinned"
    head = a["free"][:n_free].tolist()
    assert len(set(head)) == n_free, "free stack double-pop"
    assert 0 not in head, "garbage block on the free stack"
    assert (a["ref"][a["free"][:n_free]] == 0).all(), \
        "free block still referenced"
    in_use = int((a["ref"][1:] > 0).sum())
    assert in_use + n_free == nb - 1, "blocks leaked or aliased"
    live = a["table"][a["table"] >= 0]
    assert (a["ref"][live] > 0).all(), "table points at a dead block"


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_allocator_invariants_under_random_op_storms(seed):
    """Random alloc / share / tick-alloc / CoW-free / preempt / steal
    sequences against the device-resident allocator: every §13 invariant
    holds after every single op, and after draining, the pool is whole."""
    rng = np.random.default_rng(seed)
    alloc = kv_pool.init_alloc(_NB, _SLOTS, _MB)
    stolen = None
    for _ in range(25):
        a = _snap(alloc)
        n_free = int(a["n_free"])
        occ = [s for s in range(_SLOTS) if (a["table"][s] >= 0).any()]
        empty = [s for s in range(_SLOTS) if s not in occ]
        op = rng.choice(["alloc", "share", "free", "tick", "preempt",
                         "steal"])
        if op == "alloc" and empty:
            n = int(rng.integers(1, _MB + 1))
            if n <= n_free:
                alloc = kv_pool.alloc_range(alloc, int(rng.choice(empty)),
                                            0, n)
        elif op == "share" and occ and empty:
            src = int(rng.choice(occ))
            k = int((a["table"][src] >= 0).sum())
            alloc = kv_pool.share_prefix(
                alloc, int(rng.choice(empty)),
                jnp.asarray(a["table"][src]), int(rng.integers(1, k + 1)))
        elif op == "free" and occ:
            alloc = kv_pool.free_slot(alloc, int(rng.choice(occ)))
        elif op == "tick" and occ:
            # rows crossing into their next (unallocated) block; honor the
            # no-preemption precondition demand <= n_free
            pos = np.zeros(_SLOTS, np.int32)
            mask = np.zeros(_SLOTS, np.int32)
            budget = n_free
            for s in occ:
                k = int((a["table"][s] >= 0).sum())
                if k < _MB and budget > 0 and rng.random() < 0.7:
                    pos[s], mask[s] = k * BS, 1
                    budget -= 1
            alloc = kv_pool.tick_alloc(alloc, jnp.asarray(pos),
                                       jnp.asarray(mask), BS)
        elif op == "preempt" and occ:
            # every growable row demands a block; victims are freed
            # in-devices until the demand fits the free stack
            pos = np.zeros(_SLOTS, np.int32)
            active = np.zeros(_SLOTS, bool)
            for s in occ:
                k = int((a["table"][s] >= 0).sum())
                if k < _MB:
                    pos[s], active[s] = k * BS, True
            alloc, pre = kv_pool.preempt_for_free(
                alloc, jnp.asarray(pos), jnp.asarray(active),
                jnp.asarray(rng.integers(1, 20, _SLOTS), jnp.int32),
                jnp.asarray(rng.permutation(_SLOTS) + 1, jnp.int32), BS)
            pre = np.asarray(jax.device_get(pre))
            a2 = _snap(alloc)
            assert (a2["table"][pre] == -1).all(), \
                "preempted row kept blocks"
        elif op == "steal":
            if stolen is None and n_free > 0:
                alloc, stolen = kv_pool.steal_blocks(
                    alloc, int(rng.integers(1, n_free + 1)))
            elif stolen is not None:
                alloc = kv_pool.unsteal_blocks(alloc, stolen)
                stolen = None
        _check_alloc_invariants(alloc)
    # drain: give back steals, free every slot -> the pool is whole again
    if stolen is not None:
        alloc = kv_pool.unsteal_blocks(alloc, stolen)
    a = _snap(alloc)
    for s in range(_SLOTS):
        if (a["table"][s] >= 0).any():
            alloc = kv_pool.free_slot(alloc, s)
    a = _snap(alloc)
    assert int(a["n_free"]) == _NB - 1
    assert set(a["free"][: _NB - 1].tolist()) == set(range(1, _NB))
    assert (a["ref"][1:] == 0).all()


# ---------------------------------------------------------------------------
# Scheduler: prefix sharing, CoW, retirement
# ---------------------------------------------------------------------------


def _solo_output(cfg, params, prompt, max_new, **kw):
    eng = ServingEngine(cfg, params, slots=1, max_seq=64, **kw)
    eng.submit(Request(rid=0, prompt=prompt, max_new=max_new))
    return eng.run_to_completion()[0].output


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_engine_ring_and_paged_layouts_agree(arch):
    """End-to-end: the engine emits identical token streams under both KV
    layouts, with requests admitted mid-flight at staggered lengths."""
    cfg, params = _model(arch)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (int(p),))
               for p in (5, 9, 4, 12)]
    outs = {}
    for layout in ("ring", "paged"):
        eng = ServingEngine(cfg, params, slots=2, max_seq=64,
                            kv_layout=layout)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=6))
        outs[layout] = {r.rid: r.output for r in eng.run_to_completion()}
    assert outs["ring"] == outs["paged"]


@pytest.mark.parametrize("plen", [11, 16])
def test_prefix_sharing_admits_n_requests_with_one_prefill(plen):
    """The headline paged-KV property: N same-prompt admissions run ONE
    prefill forward (plus sub-block teacher steps), and every request's
    output matches a solo run. plen=16 is block-aligned, exercising the
    copy-on-write of the final shared block."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(plen)
    prompt = rng.integers(0, cfg.vocab_size, (plen,))
    n = 4
    eng = ServingEngine(cfg, params, slots=n, max_seq=64)
    for i in range(n):
        eng.submit(Request(rid=i, prompt=prompt, max_new=5))
    fin = {r.rid: r.output for r in eng.run_to_completion()}
    st = eng.stats
    assert st["prefill_forwards"] == 1, "N same-prefix admissions != 1 prefill"
    assert st["shared_admissions"] == n - 1
    assert st["teacher_steps"] <= (n - 1) * eng.block_size
    if plen % eng.block_size == 0:
        assert st["cow_copies"] == n - 1
    want = _solo_output(cfg, params, prompt, 5)
    for i in range(n):
        assert fin[i] == want, f"shared request {i} diverged from solo"


def test_divergent_prompts_share_leading_blocks_only():
    """Two prompts equal through the first block but divergent INSIDE a
    later full block map only their leading table entries to the same
    physical blocks; the second request still runs its own prefill (from the
    divergent block on) and both outputs match their solo runs."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(9)
    head = rng.integers(0, cfg.vocab_size, (8,))
    pa = np.concatenate([head, rng.integers(0, cfg.vocab_size, (9,))])
    pb = np.concatenate([head, rng.integers(0, cfg.vocab_size, (9,))])
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=pa, max_new=4))
    eng.submit(Request(rid=1, prompt=pb, max_new=4))
    fin = {r.rid: r.output for r in eng.run_to_completion()}
    st = eng.stats
    assert st["prefix_hit_blocks"] == 1 and st["prompt_blocks"] == 4
    assert st["prefill_forwards"] == 2   # divergence in block 1: both prefill
    assert st["shared_admissions"] == 0
    assert fin[0] == _solo_output(cfg, params, pa, 4)
    assert fin[1] == _solo_output(cfg, params, pb, 4)


def test_divergent_tail_takes_fast_path_with_private_block():
    """Prompts sharing every FULL block but divergent in the sub-block tail
    admit without a second prefill: the tail is teacher-forced into a
    private block, so no CoW is needed and outputs match the solo runs."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(10)
    head = rng.integers(0, cfg.vocab_size, (8,))
    pa = np.concatenate([head, rng.integers(0, cfg.vocab_size, (3,))])
    pb = np.concatenate([head, rng.integers(0, cfg.vocab_size, (3,))])
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=pa, max_new=4))
    eng.submit(Request(rid=1, prompt=pb, max_new=4))
    fin = {r.rid: r.output for r in eng.run_to_completion()}
    st = eng.stats
    assert st["prefill_forwards"] == 1 and st["shared_admissions"] == 1
    assert st["cow_copies"] == 0 and st["teacher_steps"] == 3
    assert fin[0] == _solo_output(cfg, params, pa, 4)
    assert fin[1] == _solo_output(cfg, params, pb, 4)


def test_cow_sharer_does_not_keep_stale_prefix_entry():
    """Regression: a CoW'd sharer must drop the CoW'd block's prefix-cache
    key. If it kept the key, the map entry would outlive the registrant's
    retirement (which frees the physical block), and a later same-prefix
    admission would map a freed — possibly recycled — block. Interleaving:
    registrant A retires while CoW sharer B still runs, an unrelated
    request D recycles A's freed blocks, then C re-admits the shared
    prompt; C's output must match a solo run."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, (16,))   # block-aligned -> CoW
    other = rng.integers(0, cfg.vocab_size, (16,))
    eng = ServingEngine(cfg, params, slots=3, max_seq=64)
    eng.submit(Request(rid=0, prompt=shared, max_new=2))    # registrant
    eng.submit(Request(rid=1, prompt=shared, max_new=20))   # CoW sharer
    while not any(r.rid == 0 for r in eng.finished):
        eng.step()
    assert eng.stats["cow_copies"] == 1
    eng.submit(Request(rid=2, prompt=other, max_new=2))     # recycles blocks
    eng.submit(Request(rid=3, prompt=shared, max_new=4))
    fin = {r.rid: r.output for r in eng.run_to_completion()}
    assert fin[3] == _solo_output(cfg, params, shared, 4), \
        "late same-prefix admission mapped a freed/recycled block"


def test_retirement_returns_all_blocks_and_evicts_prefix_cache():
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (16,))
    eng = ServingEngine(cfg, params, slots=3, max_seq=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=prompt, max_new=3))
    mid_blocks = None
    eng.step()
    mid_blocks = eng.pool_stats()["blocks_in_use"]
    assert mid_blocks > 0
    eng.run_to_completion()
    ps = eng.pool_stats()
    assert ps["blocks_in_use"] == 0, "retirement leaked pool blocks"
    assert not eng._prefix_map and not eng._key_refs
    assert ps["prefix_hit_rate"] > 0


def test_prefix_lru_retains_blocks_past_zero_refs():
    """ROADMAP item: with ``prefix_lru_blocks`` the prefix cache holds a
    device ref on registered blocks, so a popular prompt survives ALL its
    requests retiring — the next same-prefix admission still skips the
    prefill (the default capacity-0 engine re-prefills here)."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab_size, (16,))
    want = _solo_output(cfg, params, prompt, 4)

    eng = ServingEngine(cfg, params, slots=1, max_seq=64,
                        prefix_lru_blocks=2)
    eng.submit(Request(rid=0, prompt=prompt, max_new=3))
    eng.run_to_completion()
    ps = eng.pool_stats()
    assert ps["retained_blocks"] == 2 and ps["blocks_in_use"] == 2
    assert eng._prefix_map, "retirement evicted retained prefix keys"

    eng.submit(Request(rid=1, prompt=prompt, max_new=4))
    fin = eng.run_to_completion()
    assert fin[-1].output == want, "retained blocks served stale KV"
    assert eng.stats["prefill_forwards"] == 1, \
        "re-admission after retirement should hit the retained prefix"
    assert eng.stats["shared_admissions"] == 1

    # capacity-0 baseline: same workload pays a second prefill
    base = ServingEngine(cfg, params, slots=1, max_seq=64)
    for rid in (0, 1):
        base.submit(Request(rid=rid, prompt=prompt, max_new=3))
        base.run_to_completion()
    assert base.stats["prefill_forwards"] == 2
    assert base.pool_stats()["retained_blocks"] == 0


def test_prefix_lru_capacity_pressure_evicts_oldest():
    """Capacity pressure: retained blocks are bounded by the LRU capacity —
    the oldest key is evicted (and its block released back to the pool)
    when a newer prefix needs the headroom; outputs stay correct through
    recycling."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(22)
    pa = rng.integers(0, cfg.vocab_size, (16,))
    pb = rng.integers(0, cfg.vocab_size, (16,))
    want_a = _solo_output(cfg, params, pa, 4)
    want_b = _solo_output(cfg, params, pb, 4)

    eng = ServingEngine(cfg, params, slots=1, max_seq=64,
                        prefix_lru_blocks=2)  # room for ONE 2-block prefix
    eng.submit(Request(rid=0, prompt=pa, max_new=3))
    eng.run_to_completion()
    assert eng.pool_stats()["retained_blocks"] == 2
    eng.submit(Request(rid=1, prompt=pb, max_new=3))
    eng.run_to_completion()
    # A's keys were evicted for B's; retained stays at capacity
    ps = eng.pool_stats()
    assert ps["retained_blocks"] == 2
    assert len(eng._prefix_map) == 2, "evicted keys must leave the map"

    eng.submit(Request(rid=2, prompt=pa, max_new=4))  # A: evicted -> prefill
    fin = eng.run_to_completion()
    assert fin[-1].output == want_a
    assert eng.stats["prefill_forwards"] == 3
    eng.submit(Request(rid=3, prompt=pb, max_new=4))  # B: evicted by A's readmit
    fin = eng.run_to_completion()
    assert fin[-1].output == want_b, "recycled block leaked into B's KV"
    # every non-retained block is back on the free stack
    ps = eng.pool_stats()
    assert ps["blocks_in_use"] == ps["retained_blocks"] == 2


def test_prefix_lru_never_starves_generation():
    """The pool is sized up by exactly the LRU capacity, so a full slot
    complement can still generate to max_seq with the cache at capacity."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(23)
    filler = rng.integers(0, cfg.vocab_size, (16,))
    eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                        prefix_lru_blocks=2)
    eng.submit(Request(rid=0, prompt=filler, max_new=2))
    eng.run_to_completion()
    assert eng.pool_stats()["retained_blocks"] == 2
    # both slots now generate deep into their rows with the cache full
    for i in range(2):
        eng.submit(Request(rid=10 + i,
                           prompt=rng.integers(0, cfg.vocab_size, (4,)),
                           max_new=24))
    fin = eng.run_to_completion()
    assert all(len(r.output) == 24 for r in fin[-2:])


def test_stop_token_releases_paged_blocks_in_same_tick():
    """Regression (DESIGN.md §12): a request that hits a stop token before
    ``max_new`` must release its paged KV blocks at retirement, in the SAME
    tick that emitted the stop — not hold them until ``max_new`` ticks
    elapse. The pool free-count must recover immediately."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab_size, (12,))
    probe = ServingEngine(cfg, params, slots=1, max_seq=64)
    stream = probe.generate([prompt], SamplingParams(max_new=8))[0].tokens

    stop = stream[3]
    k = stream.index(stop)
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    free0 = int(jax.device_get(eng.alloc["n_free"]))
    eng.submit(Request(rid=0, prompt=prompt,
                       params=SamplingParams(max_new=64, stop=(stop,))))
    ticks = 0
    while eng.waiting or any(r is not None for r in eng.slot_req):
        eng.step()
        ticks += 1
    req = eng.finished[0]
    assert req.finish_reason == "stop"
    assert req.output == stream[: k + 1], "stop token must end the stream"
    # retirement freed the row the moment the stop tick retired it: the
    # loop exited on the stop tick, nowhere near the 64-token budget
    assert ticks == max(k, 1) and eng.stats["decode_ticks"] == k
    assert int(jax.device_get(eng.alloc["n_free"])) == free0, \
        "stop-token retirement leaked pool blocks past the stop tick"
    assert eng.pool_stats()["blocks_in_use"] == 0

    # first-token stop: the armed slot must be shut down before its blocks
    # free, or the still-active row would pop fresh blocks every tick
    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    res = eng.generate([prompt], SamplingParams(max_new=64,
                                                stop=(stream[0],)))[0]
    assert res.finish_reason == "stop" and res.tokens == [stream[0]]
    assert eng.stats["decode_ticks"] == 0
    assert int(jax.device_get(eng.alloc["n_free"])) == free0
    # and the pool stays intact while another request runs to completion
    out = eng.generate([prompt], SamplingParams(max_new=8))[0]
    assert out.tokens == stream
    assert eng.pool_stats()["blocks_in_use"] == 0


def test_undersized_pool_policies_at_construction():
    """An undersized pool (can't back every slot at max_seq) is legal WITH
    victim preemption (§13) — "auto" turns it on — but is refused when
    preemption is explicitly off (an exhausted free stack would silently
    alias one physical block into two slots), and a pool too small to back
    even ONE slot is always refused."""
    cfg, params = _model("tinyllama-1.1b")
    eng = ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=16)
    assert eng.preemption, "undersized pool must auto-enable preemption"
    with pytest.raises(ValueError, match="preemption"):
        ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=16,
                      preemption=False)
    # floor: max_blocks + 1 garbage block = 9 for max_seq=64/bs=8
    with pytest.raises(ValueError, match="one slot"):
        ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=8)
    # exactly the full provisioning minimum: preemption stays off
    eng = ServingEngine(cfg, params, slots=2, max_seq=16,
                        num_blocks=2 * 2 + 1)
    assert not eng.preemption


def test_hybrid_ssm_attention_arch_serves_in_both_layouts():
    """A jamba-style config mixing SSM and attention blocks has a sub-chunk
    prefill tail but can't take the state-threaded tail forward (attention
    has no carried state to resume) — both layouts must fall back to
    teacher-forced tail steps and match the scan-of-decode-steps oracle."""
    import dataclasses

    base = get_smoke_config("tinyllama-1.1b")
    cfg = dataclasses.replace(
        base, name="hybrid-smoke", block_pattern=("ssm", "global"),
        n_layers=4, ssm_state=16, ssm_head_dim=16, ssm_expand=2,
        ssm_chunk=8, conv_kernel=4)
    params = tfm.init_params(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (11,))  # chunk 8 -> 3-token tail

    qc = QuantContext(mode="off")
    cache = tfm.init_cache(cfg, 1, 32)
    for t in prompt:
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([int(t)], jnp.int32), cfg)
    want = [int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))]
    for _ in range(3):
        logits, cache = tfm.decode_step(
            qc, params, cache, jnp.asarray([want[-1]], jnp.int32), cfg)
        want.append(int(jnp.argmax(logits[0, 0, : cfg.vocab_size])))

    for layout in ("ring", "paged"):
        eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                            kv_layout=layout)
        eng.submit(Request(rid=0, prompt=prompt, max_new=4))
        out = eng.run_to_completion()[0].output
        assert out == want, f"{layout} hybrid tail diverged from oracle"
        assert eng.stats["teacher_steps"] == 3


def test_paged_int8_serve_mode():
    """Paged layout composes with the int8 fused-dequant decode path."""
    from repro.serving.engine import make_uniform_quant_state

    cfg, params = _model("tinyllama-1.1b")
    qs = make_uniform_quant_state(cfg, params)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, (9,))
    outs = {}
    for layout in ("ring", "paged"):
        eng = ServingEngine(cfg, params, slots=2, max_seq=64, quant_state=qs,
                            matmul_impl="ref", kv_layout=layout)
        assert len(eng.qweights) >= 8
        eng.submit(Request(rid=0, prompt=prompt, max_new=4))
        outs[layout] = eng.run_to_completion()[0].output
    assert outs["ring"] == outs["paged"]


# ---------------------------------------------------------------------------
# Quantized pools: fused dequant kernel + CoW bit-identity (DESIGN.md §14)
# ---------------------------------------------------------------------------

from repro.quant import KVQuantSpec, quantize_kv  # noqa: E402


def _quant_kernel_fixture(bits, seed=0):
    rng = np.random.default_rng(seed)
    b, kvh, g, hd, bs, mb, nb = 3, 2, 4, 16, 8, 4, 16
    spec = KVQuantSpec(bits=bits, group_size=8, head_dim=hd)
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    table = np.full((b, mb), -1, np.int32)
    phys = rng.permutation(np.arange(1, nb))
    pos = np.asarray([5, 12, 25], np.int32)
    k = 0
    for r in range(b):
        for j in range(int(pos[r]) // bs + 1):
            table[r, j] = phys[k]
            k += 1
    return spec, q, kf, vf, jnp.asarray(table), jnp.asarray(pos)


@pytest.mark.parametrize("bits,window,softcap",
                         [(8, None, None), (8, 8, None), (8, None, 30.0),
                          (4, None, None), (4, 8, 50.0)])
def test_paged_attention_pallas_matches_ref_quantized(bits, window, softcap):
    """Fused dequant-on-block-load: the Pallas kernel (scales paged through
    the same block-table index_map as the codes, affine applied in-register)
    must reproduce the jnp oracle's gather-then-dequant semantics — and the
    quantized oracle itself must stay within codec tolerance of the float
    pool."""
    spec, q, kf, vf, table, pos = _quant_kernel_fixture(bits)
    kp, ks = quantize_kv(kf, spec)
    vp, vs = quantize_kv(vf, spec)
    want = paged_attention_ref(q, kp, vp, table, pos, window=window,
                               softcap=softcap, k_scale=ks, v_scale=vs)
    got = paged_attention_op(q, kp, vp, table, pos, window=window,
                             softcap=softcap, use_pallas=True, interpret=True,
                             k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    want_f = paged_attention_ref(q, kf, vf, table, pos, window=window,
                                 softcap=softcap)
    np.testing.assert_allclose(np.asarray(want), np.asarray(want_f),
                               atol=0.05 if bits == 8 else 0.35)


def test_cow_block_quantized_copies_codes_and_aux_bit_identical():
    """§14 CoW contract: the private copy of a shared quantized block is
    bit-identical in BOTH codes and per-group scales — the generic
    per-entry copy never round-trips through floats."""
    cfg = get_smoke_config("tinyllama-1.1b")
    hd = cfg.head_dim
    spec = KVQuantSpec(bits=8, group_size=hd, head_dim=hd)
    alloc = kv_pool.init_alloc(9, 2, 2)
    pool = kv_pool.init_pool(cfg, 9, BS, spec=spec)
    assert pool["k"].dtype == jnp.int8
    assert pool["k_scale"].dtype == jnp.float16
    alloc = kv_pool.alloc_range(alloc, 0, 0, 1)
    old = int(jax.device_get(alloc["table"][0, 0]))
    rng = np.random.default_rng(3)
    for name in ("k", "v"):
        block = jnp.asarray(
            rng.normal(size=(BS, cfg.n_kv_heads, hd)), jnp.float32)
        codes, scale = quantize_kv(block, spec)
        pool[name] = pool[name].at[old].set(codes)
        pool[name + "_scale"] = pool[name + "_scale"].at[old].set(scale)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(row0), 1)
    alloc, layers = kv_pool.cow_block(alloc, [pool], 1, 0)
    a = {k: np.asarray(jax.device_get(v)) for k, v in alloc.items()}
    new = int(a["table"][1, 0])
    assert new != old and a["ref"][old] == 1 and a["ref"][new] == 1
    for name in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(layers[0][name][new]), np.asarray(layers[0][name][old]))


def test_quantized_prefix_sharing_cow_streams_unaffected():
    """§14 regression, extending the stale-key test to int8 pools: a
    same-prefix admission shares blocks, the sharer CoWs on its first
    divergent write, and BOTH the registrant's and the sharer's streams
    equal their solo int8 runs."""
    cfg, params = _model("tinyllama-1.1b")
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, (16,))   # block-aligned -> CoW
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, kv_dtype="int8")
    eng.submit(Request(rid=0, prompt=shared, max_new=6))
    eng.submit(Request(rid=1, prompt=shared, max_new=12))
    fin = {r.rid: r.output for r in eng.run_to_completion()}
    assert eng.stats["shared_admissions"] == 1
    assert eng.stats["cow_copies"] >= 1
    assert fin[0] == _solo_output(cfg, params, shared, 6, kv_dtype="int8"), \
        "registrant stream perturbed by a sharer's CoW"
    assert fin[1] == _solo_output(cfg, params, shared, 12, kv_dtype="int8")


# ---------------------------------------------------------------------------
# §17 long-context: windowed kernel vs dense masked oracle
# ---------------------------------------------------------------------------

from repro.serving.window import (WindowSpec, as_window_spec,
                                  first_live_block, max_live_blocks,
                                  window_demand_blocks)


def _kernel_fixture(seed=0):
    rng = np.random.default_rng(seed)
    b, kvh, g, hd, bs, mb, nb = 3, 2, 4, 16, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kvh, hd)), jnp.float32)
    table = np.full((b, mb), -1, np.int32)
    phys = rng.permutation(np.arange(1, nb))
    pos = np.asarray([5, 12, 25], np.int32)
    k = 0
    for r in range(b):
        for j in range(int(pos[r]) // bs + 1):
            table[r, j] = phys[k]
            k += 1
    return q, kp, vp, table, jnp.asarray(pos)


def _dense_window_oracle(q, kp, vp, table, pos, window, sinks):
    """Gather-to-dense + masked softmax in numpy: the §17 acceptance
    oracle (``kp <= p and (p - kp < window or kp < sinks)``). Only
    positions the mask admits are gathered, so evicted (-1) out-of-window
    table entries never need to exist."""
    qn, kpn, vpn = map(np.asarray, (q, kp, vp))
    tn, pn = np.asarray(table), np.asarray(pos)
    b, kvh, g, hd = qn.shape
    bs = kpn.shape[1]
    out = np.zeros((b, kvh, g, hd), np.float32)
    scale = hd ** -0.5
    for r in range(b):
        p = int(pn[r])
        sel = []
        for kpos in range(p + 1):
            if window is not None and not ((p - kpos) < window
                                           or kpos < sinks):
                continue
            blk = int(tn[r, kpos // bs])
            assert blk >= 0, "mask admits an unbacked position"
            sel.append((kpos, blk))
        ks = np.stack([kpn[blk, kpos % bs] for kpos, blk in sel])
        vs = np.stack([vpn[blk, kpos % bs] for kpos, blk in sel])
        for h in range(kvh):
            s = qn[r, h] @ ks[:, h].T * scale
            e = np.exp(s - s.max(axis=1, keepdims=True))
            out[r, h] = (e / e.sum(axis=1, keepdims=True)) @ vs[:, h]
    return out


# ragged window x sink x block-size interactions: windows below / straddling
# / beyond one block, sinks covering none / one / two blocks, a window so
# large it never binds, and a one-token window pinned entirely to sinks
WINDOW_CASES = [(8, 0), (3, 0), (5, 8), (8, 8), (3, 16), (1, 16), (100, 0)]


@pytest.mark.parametrize("window,sinks", WINDOW_CASES)
def test_windowed_paged_attention_matches_dense_masked_oracle(window, sinks):
    """§17 acceptance oracle: the windowed jnp path AND the Pallas
    first-live-block walk both reproduce a dense gather with the causal
    window+sink mask, across ragged window/sink/block-size combos."""
    q, kp, vp, table, pos = _kernel_fixture()
    want = _dense_window_oracle(q, kp, vp, table, pos, window, sinks)
    got_ref = paged_attention_ref(q, kp, vp, jnp.asarray(table), pos,
                                  window=window, sinks=sinks)
    np.testing.assert_allclose(np.asarray(got_ref), want,
                               rtol=1e-5, atol=1e-5)
    got_pl = paged_attention_op(q, kp, vp, jnp.asarray(table), pos,
                                window=window, sinks=sinks,
                                use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), want,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window,sinks", [(8, 0), (5, 8), (8, 8), (1, 16)])
def test_windowed_kernel_bit_identical_on_evicted_tables(window, sinks):
    """Out-of-window eviction is invisible to attention: clearing every
    table entry below the first live block (the exact set the engine's
    eviction pass frees) leaves windowed logits BIT-identical on both the
    jnp oracle and the Pallas kernel — proof no evicted block is read."""
    q, kp, vp, table, pos = _kernel_fixture()
    bs = kp.shape[1]
    sb = -(-sinks // bs)
    ev = table.copy()
    for r in range(table.shape[0]):
        fl = max((int(pos[r]) - window + 1) // bs, sb)
        ev[r, sb:fl] = -1
    for use_pallas in (False, True):
        full = paged_attention_op(q, kp, vp, jnp.asarray(table), pos,
                                  window=window, sinks=sinks,
                                  use_pallas=use_pallas, interpret=True)
        evd = paged_attention_op(q, kp, vp, jnp.asarray(ev), pos,
                                 window=window, sinks=sinks,
                                 use_pallas=use_pallas, interpret=True)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(evd))


@pytest.mark.parametrize("bits,window,sinks",
                         [(8, 5, 8), (8, 8, 0), (4, 5, 8), (4, 1, 16)])
def test_windowed_pallas_matches_ref_quantized(bits, window, sinks):
    """§17 x §14: the first-live-block walk routes the quantized scale
    operands through the same dead-block index_map as the codes — windowed
    int8/int4 Pallas must match the windowed quantized jnp oracle."""
    spec, q, kf, vf, table, pos = _quant_kernel_fixture(bits)
    kp, ks = quantize_kv(kf, spec)
    vp, vs = quantize_kv(vf, spec)
    want = paged_attention_ref(q, kp, vp, table, pos, window=window,
                               sinks=sinks, k_scale=ks, v_scale=vs)
    got = paged_attention_op(q, kp, vp, table, pos, window=window,
                             sinks=sinks, use_pallas=True, interpret=True,
                             k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# §17 eviction ops: release_range / evict_out_of_window state machine
# ---------------------------------------------------------------------------


def test_release_range_frees_only_unshared_blocks():
    alloc = kv_pool.init_alloc(9, 2, 4)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 4)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(row0), 2)
    alloc = kv_pool.release_range(alloc, 0, 0, 3)
    a = _snap(alloc)
    assert (a["table"][0, :3] == -1).all()
    assert a["table"][0, 3] == row0[3], "untouched tail cleared"
    assert (a["table"][1, :2] == row0[:2]).all(), "sharer's row perturbed"
    assert a["ref"][row0[0]] == 1 and a["ref"][row0[1]] == 1, \
        "shared block freed under the sharer"
    assert a["ref"][row0[2]] == 0
    assert row0[2] in a["free"][: int(a["n_free"])].tolist()
    _check_alloc_invariants(alloc, nb=9)


def test_evict_out_of_window_respects_refcounts_sinks_and_retention():
    """The §17 eviction contract: sink blocks are pinned, a block shared
    with another slot (ref > 1) or retained by the LRU cache is decremented
    but NEVER freed, rows with live=False are untouched, and a second
    eviction at the same first-live index is a no-op."""
    alloc = kv_pool.init_alloc(17, 3, 4)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 4)
    alloc = kv_pool.alloc_range(alloc, 2, 0, 3)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    row2 = np.asarray(jax.device_get(alloc["table"][2]))
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(row0), 2)
    alloc = kv_pool.retain_block(alloc, int(row0[1]))  # LRU-held
    free0 = int(_snap(alloc)["n_free"])
    fl = jnp.asarray([3, 0, 0], jnp.int32)
    live = jnp.asarray([True, False, False])
    alloc = kv_pool.evict_out_of_window(alloc, fl, live, 1)
    a = _snap(alloc)
    # col 0 is a sink block: pinned, still mapped
    assert a["table"][0, 0] == row0[0] and a["ref"][row0[0]] == 2
    # col 1 was shared + retained: unmapped here, but never freed
    assert a["table"][0, 1] == -1
    assert a["ref"][row0[1]] == 2, "shared/retained block lost refs"
    assert row0[1] not in a["free"][: int(a["n_free"])].tolist()
    # col 2 was exclusive: freed
    assert a["table"][0, 2] == -1 and a["ref"][row0[2]] == 0
    assert row0[2] in a["free"][: int(a["n_free"])].tolist()
    # col 3 is at/above first-live: untouched
    assert a["table"][0, 3] == row0[3] and a["ref"][row0[3]] == 1
    # live=False rows untouched even though fl would evict nothing anyway
    assert (a["table"][1, :2] == row0[:2]).all()
    assert (a["table"][2] == row2).all()
    assert int(a["n_free"]) == free0 + 1
    _check_alloc_invariants(alloc)
    # idempotence: same first-live again evicts nothing
    again = _snap(kv_pool.evict_out_of_window(alloc, fl, live, 1))
    for k in ("table", "ref", "n_free"):
        np.testing.assert_array_equal(again[k], a[k])


def test_evict_out_of_window_dedups_a_block_shared_within_one_row():
    """A physical block mapped at TWO evicted columns of the same row (the
    self-share degenerate case) must lose both refs in one pass without
    being pushed to the free stack twice."""
    alloc = kv_pool.init_alloc(9, 2, 4)
    alloc = kv_pool.alloc_range(alloc, 0, 0, 1)
    row0 = np.asarray(jax.device_get(alloc["table"][0]))
    phys = np.full((4,), -1, np.int32)
    phys[0] = phys[1] = row0[0]
    alloc = kv_pool.share_prefix(alloc, 1, jnp.asarray(phys), 2)
    assert int(_snap(alloc)["ref"][row0[0]]) == 3
    alloc = kv_pool.evict_out_of_window(
        alloc, jnp.asarray([0, 2], jnp.int32),
        jnp.asarray([False, True]), 0)
    a = _snap(alloc)
    assert (a["table"][1, :2] == -1).all()
    assert a["ref"][row0[0]] == 1, "row-internal double-count"
    head = a["free"][: int(a["n_free"])].tolist()
    assert head.count(int(row0[0])) == 0
    _check_alloc_invariants(alloc, nb=9)
    alloc = kv_pool.free_slot(alloc, 0)
    a = _snap(alloc)
    assert int(a["n_free"]) == 8 and (a["ref"][1:] == 0).all()


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_window_eviction_invariants_under_random_op_storms(seed):
    """§17 storm: random WINDOW SIZES crossed with evict / release-range /
    retain / CoW-share / preempt / steal sequences. After every op the §13
    pool contract holds (``in_use + free == num_blocks - 1``), eviction
    never frees a block another slot still references (pre-op ref > 1) or
    a prefix-LRU-retained block, and after draining, the pool is whole."""
    rng = np.random.default_rng(seed)
    alloc = kv_pool.init_alloc(_NB, _SLOTS, _MB)
    stolen = None
    retained: list[int] = []        # host mirror of LRU-held device refs
    for _ in range(30):
        a = _snap(alloc)
        n_free = int(a["n_free"])
        occ = [s for s in range(_SLOTS) if (a["table"][s] >= 0).any()]
        empty = [s for s in range(_SLOTS) if s not in occ]
        op = rng.choice(["alloc", "share", "evict", "release", "retain",
                         "free", "preempt", "steal"])
        if op == "alloc" and empty:
            n = int(rng.integers(1, _MB + 1))
            if n <= n_free:
                alloc = kv_pool.alloc_range(alloc, int(rng.choice(empty)),
                                            0, n)
        elif op == "share" and occ and empty:
            # share_prefix's contract is a CONTIGUOUS valid prefix (the
            # engine only shares at admission, before any eviction can
            # punch holes in a row) — mirror that here
            src = int(rng.choice(occ))
            row = a["table"][src]
            lead = int(np.argmax(row < 0)) if (row < 0).any() else _MB
            if lead >= 1:
                alloc = kv_pool.share_prefix(
                    alloc, int(rng.choice(empty)), jnp.asarray(row),
                    int(rng.integers(1, lead + 1)))
        elif op == "evict" and occ:
            # random window geometry: the engine's eviction shape is
            # fl = max((pos - W + 1) // BS, sink_blocks); here fl and
            # sink_blocks are drawn directly to cover every ragged case
            sb = int(rng.integers(0, _MB))
            fl = np.zeros(_SLOTS, np.int32)
            live = np.zeros(_SLOTS, bool)
            for s in occ:
                if rng.random() < 0.8:
                    fl[s] = int(rng.integers(sb, _MB + 1))
                    live[s] = True
            ref_before = a["ref"].copy()
            alloc = kv_pool.evict_out_of_window(
                alloc, jnp.asarray(fl), jnp.asarray(live), sb)
            a2 = _snap(alloc)
            head = set(a2["free"][: int(a2["n_free"])].tolist())
            for s in np.where(live)[0]:
                for j in range(sb, fl[s]):
                    blk = int(a["table"][s, j])
                    if blk < 0:
                        continue
                    assert a2["table"][s, j] == -1
                    if ref_before[blk] > 1 or blk in retained:
                        assert blk not in head or a2["ref"][blk] == 0, \
                            "freed a block with live references"
                        if blk in retained:
                            assert a2["ref"][blk] >= 1, \
                                "freed an LRU-retained block"
                            assert blk not in head
        elif op == "release" and occ:
            s = int(rng.choice(occ))
            k = int((a["table"][s] >= 0).sum())
            start = int(rng.integers(0, k))
            alloc = kv_pool.release_range(
                alloc, s, start, int(rng.integers(1, k - start + 1)))
        elif op == "retain":
            mapped = np.unique(a["table"][a["table"] >= 0])
            cand = [int(b) for b in mapped if b not in retained]
            if cand and rng.random() < 0.7:
                blk = int(rng.choice(cand))
                alloc = kv_pool.retain_block(alloc, blk)
                retained.append(blk)
            elif retained:
                blk = retained.pop(int(rng.integers(0, len(retained))))
                alloc = kv_pool.release_block(alloc, blk)
        elif op == "free" and occ:
            alloc = kv_pool.free_slot(alloc, int(rng.choice(occ)))
        elif op == "preempt" and occ:
            pos = np.zeros(_SLOTS, np.int32)
            active = np.zeros(_SLOTS, bool)
            for s in occ:
                k = int((a["table"][s] >= 0).sum())
                if k < _MB:
                    pos[s], active[s] = k * BS, True
            alloc, _pre = kv_pool.preempt_for_free(
                alloc, jnp.asarray(pos), jnp.asarray(active),
                jnp.asarray(rng.integers(1, 20, _SLOTS), jnp.int32),
                jnp.asarray(rng.permutation(_SLOTS) + 1, jnp.int32), BS)
        elif op == "steal":
            if stolen is None and n_free > 0:
                alloc, stolen = kv_pool.steal_blocks(
                    alloc, int(rng.integers(1, n_free + 1)))
            elif stolen is not None:
                alloc = kv_pool.unsteal_blocks(alloc, stolen)
                stolen = None
        _check_alloc_invariants(alloc)
        a3 = _snap(alloc)
        head = set(a3["free"][: int(a3["n_free"])].tolist())
        assert not (head & set(retained)), "retained block on free stack"
    # drain: give back steals and retention, free every slot -> pool whole
    if stolen is not None:
        alloc = kv_pool.unsteal_blocks(alloc, stolen)
    for blk in retained:
        alloc = kv_pool.release_block(alloc, blk)
    a = _snap(alloc)
    for s in range(_SLOTS):
        if (a["table"][s] >= 0).any():
            alloc = kv_pool.free_slot(alloc, s)
    a = _snap(alloc)
    assert int(a["n_free"]) == _NB - 1
    assert set(a["free"][: _NB - 1].tolist()) == set(range(1, _NB))
    assert (a["ref"][1:] == 0).all()
