"""Continuous-batching scheduler: property storm + trace-replay determinism.

The test half of DESIGN.md §15. Two families:

  * **Property storm** (hypothesis; deterministic-replay shim without it):
    random interleavings of submits, engine ticks, stop tokens, sampled and
    greedy requests — against both an ample pool and an undersized one that
    forces mid-storm preemption. After every drain the scheduler must be
    clean: no slot or pending-prefill leaks, every submitted request ends
    in exactly one typed ``FINISHED_*`` reason, the KV pool holds only
    prefix-cache-retained blocks, and the §8 one-host-sync-per-tick ledger
    still balances.
  * **Trace replay determinism**: the same seeded ``benchmarks.loadgen``
    trace, replayed on a virtual ``TickClock``, produces identical
    per-request streams, finish reasons AND SLO stamps across two runs —
    and identical streams across different ``slots`` /
    ``prefill_chunk_tokens`` settings (including the legacy wave
    scheduler), which is the stream-equivalence property that makes the
    ``continuous_batching`` bench row comparable across configurations.
"""

import functools
import itertools
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving import (TERMINAL_REASONS, Request, SamplingParams,
                           ServingEngine)

from benchmarks.loadgen import (TickClock, make_trace, replay,  # noqa: E402
                                stream_summary)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

ARCH = "tinyllama-1.1b"

# one rid space across all storm examples so "exactly one terminal record
# per request" is checkable against the engine's cumulative finished list
_RID = itertools.count()


@functools.lru_cache(maxsize=None)
def _model():
    cfg = get_smoke_config(ARCH)
    return cfg, tfm.init_params(cfg, jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _storm_engine(pressured: bool):
    """One engine per pool regime, reused across hypothesis examples (each
    example drains it back to empty, so examples stay independent while the
    jit closures compile once)."""
    cfg, params = _model()
    if pressured:
        # 11 usable blocks for 3 slots: concurrent worst-case demand
        # overflows the pool, so the storm preempts and resumes mid-flight
        return ServingEngine(cfg, params, slots=3, max_seq=64,
                             num_blocks=12, prefill_chunk_tokens=4)
    return ServingEngine(cfg, params, slots=3, max_seq=64,
                         prefill_chunk_tokens=4)


def _storm(eng, seed, *, plen_hi, max_new_hi):
    """Drive one random schedule: submits interleaved with ticks, then a
    bounded drain. Returns the submitted requests."""
    rng = np.random.default_rng(seed)
    cfg = eng.cfg
    submitted = []
    for _ in range(int(rng.integers(1, 7))):
        plen = int(rng.integers(1, plen_hi + 1))
        sampled = rng.random() < 0.5
        stop = tuple(int(t) for t in
                     rng.integers(0, cfg.vocab_size,
                                  (int(rng.integers(0, 3)),)))
        sp = SamplingParams(
            max_new=int(rng.integers(1, max_new_hi + 1)),
            temperature=0.8 if sampled else 0.0,
            top_p=0.9 if sampled else 1.0,
            seed=int(rng.integers(2 ** 31 - 1)) if sampled else None,
            stop=stop)
        req = Request(rid=next(_RID),
                      prompt=rng.integers(0, cfg.vocab_size, (plen,)),
                      params=sp)
        eng.submit(req)
        submitted.append(req)
        for _ in range(int(rng.integers(0, 3))):
            eng.step()
    for _ in range(600):
        if not eng.waiting and all(r is None for r in eng.slot_req):
            break
        eng.step()
    return submitted


def _assert_clean(eng, submitted):
    """The §15 post-drain invariants."""
    assert not eng.waiting and all(r is None for r in eng.slot_req), \
        "engine did not drain"
    assert not eng._pending, "prefill state leaked past retirement"
    for req in submitted:
        assert req.done and req.finish_reason in TERMINAL_REASONS, req.rid
        assert req.finish_s is not None
    rids = [r.rid for r in eng.finished]
    assert len(rids) == len(set(rids)), "request finished more than once"
    assert {r.rid for r in submitted} <= set(rids)
    st = eng.stats
    assert st["tick_syncs"] == st["decode_ticks"]
    if eng.paged:
        ps = eng.pool_stats()
        assert ps["blocks_in_use"] == ps["retained_blocks"], \
            "pool blocks leaked"


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_storm_random_schedules_leave_engine_clean(seed):
    eng = _storm_engine(False)
    submitted = _storm(eng, seed, plen_hi=20, max_new_hi=6)
    _assert_clean(eng, submitted)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_storm_under_pool_pressure_leaves_engine_clean(seed):
    eng = _storm_engine(True)
    submitted = _storm(eng, seed, plen_hi=28, max_new_hi=12)
    _assert_clean(eng, submitted)


def test_pressure_storm_actually_preempts_and_recovers():
    """The undersized pool really exercises preemption: a deterministic
    heavy wave must preempt at least once and still retire every request
    with a typed reason and zero leaked blocks. Long decode phases make
    the chunk-staggered decoders overlap and grow concurrently (3 slots x
    6-block worst demand > 11 usable blocks), which the budgeted prefill
    stagger alone would otherwise spread out enough to dodge."""
    eng = _storm_engine(True)
    base = eng.stats["preemptions"]
    rng = np.random.default_rng(99)
    reqs = []
    for _ in range(6):
        reqs.append(Request(rid=next(_RID),
                            prompt=rng.integers(0, eng.cfg.vocab_size, (24,)),
                            params=SamplingParams(max_new=24)))
        eng.submit(reqs[-1])
    for _ in range(900):
        if not eng.waiting and all(r is None for r in eng.slot_req):
            break
        eng.step()
    _assert_clean(eng, reqs)
    assert eng.stats["preemptions"] > base


# ---------------------------------------------------------------------------
# Trace replay: determinism and stream equivalence
# ---------------------------------------------------------------------------


def _trace(cfg, seed=5, n=24):
    return make_trace(seed, n, cfg.vocab_size, mean_iat_s=0.004,
                      plen_buckets=(4, 12, 24), prefix_groups=2,
                      prefix_len=8, prefix_fraction=0.3, max_new=(2, 8))


def test_trace_replay_identical_streams_and_slo_stamps_across_runs():
    """Same seeded trace + same TickClock config → the replay is a pure
    function: token streams, finish reasons, per-request SLO stamps and the
    aggregated slo_stats() all repeat bit-for-bit."""
    cfg, params = _model()
    trace = _trace(cfg)
    runs = []
    for _ in range(2):
        clock = TickClock(tick_s=1e-3)
        eng = ServingEngine(cfg, params, slots=4, max_seq=64,
                            prefill_chunk_tokens=4, clock=clock)
        res = replay(eng, trace, clock=clock)
        assert res["submitted"] == len(trace)
        runs.append((stream_summary(res),
                     {rid: (r.submit_s, r.first_token_s, r.finish_s)
                      for rid, r in res["requests"].items()},
                     eng.slo_stats()))
    assert runs[0] == runs[1]
    slo = runs[0][2]
    assert slo["requests"] == len(trace)
    assert slo["ttft_s"]["count"] == len(trace)
    assert slo["ttft_s"]["p50"] > 0 and slo["ttft_s"]["p95"] >= \
        slo["ttft_s"]["p50"]
    assert slo["tpot_s"]["count"] > 0


def test_trace_replay_streams_invariant_to_slots_and_chunking():
    """Stream equivalence across scheduler configurations: the same trace
    yields identical per-request streams and finish reasons no matter the
    slot count or chunk size — including the legacy wave scheduler
    (``prefill_chunk_tokens=None``). Throughput changes; tokens must not."""
    cfg, params = _model()
    trace = _trace(cfg, seed=7, n=20)
    summaries = []
    for slots, chunk in ((2, 4), (4, 4), (8, 16), (4, None)):
        clock = TickClock(tick_s=1e-3)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=64,
                            prefill_chunk_tokens=chunk, clock=clock)
        res = replay(eng, trace, clock=clock)
        if chunk is not None:
            # fully-prefix-cached admissions legally skip chunking, so the
            # floor here is "chunking happened", not a per-request count
            assert eng.stats["prefill_chunks"] > 0
        summaries.append(((slots, chunk), stream_summary(res)))
    want = summaries[0][1]
    for key, got in summaries[1:]:
        assert got == want, f"streams diverged under {key}"
