"""Adam (fp32 + 8-bit block-quantized states) tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adam import AdamConfig, adam, apply_updates, sgd


def _quadratic_problem(seed=0, dim=32):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2) + 0.5 * jnp.sum((p["y"] - 1.0) ** 2)

    params = {"x": jnp.zeros((dim,)), "y": jnp.zeros((7, 3))}
    return loss, params, target


@pytest.mark.parametrize("bits", [32, 8])
def test_adam_converges(bits):
    loss, params, target = _quadratic_problem()
    init, update = adam(AdamConfig(lr=0.05, state_bits=bits))
    state = init(params)

    @jax.jit
    def step(params, state):
        l, g = jax.value_and_grad(loss)(params)
        upd, state = update(g, state, params)
        return apply_updates(params, upd), state, l

    for _ in range(400):
        params, state, l = step(params, state)
    assert float(l) < 1e-2
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target), atol=0.05)


def test_adam8_close_to_fp32_trajectory():
    """8-bit moment quantization tracks the fp32 update path.

    Requantization error compounds, so we assert optimizer-level closeness
    (same loss decrease, bounded parameter gap) rather than lockstep.
    """
    loss, params, _ = _quadratic_problem(seed=1)
    traj, losses = {}, {}
    for bits in (32, 8):
        p = jax.tree.map(jnp.copy, params)
        init, update = adam(AdamConfig(lr=0.01, state_bits=bits))
        st = init(p)
        for _ in range(50):
            l, g = jax.value_and_grad(loss)(p)
            upd, st = update(g, st, p)
            p = apply_updates(p, upd)
        traj[bits], losses[bits] = p, float(l)
    d = jnp.max(jnp.abs(traj[32]["x"] - traj[8]["x"]))
    assert float(d) < 0.15
    assert abs(losses[8] - losses[32]) / max(losses[32], 1e-6) < 0.10


def test_adam8_state_memory_is_int8():
    _, params, _ = _quadratic_problem()
    init, _ = adam(AdamConfig(state_bits=8))
    st = init(params)
    assert st.m["x"]["codes"].dtype == jnp.int8
    assert st.v["y"]["codes"].dtype == jnp.int8


def test_grad_clip():
    init, update = adam(AdamConfig(lr=1.0, grad_clip_norm=1.0))
    params = {"x": jnp.zeros((4,))}
    st = init(params)
    big = {"x": jnp.full((4,), 100.0)}
    upd, st = update(big, st, params)
    # after clipping to norm 1, adam normalizes again; update must be finite
    assert np.isfinite(np.asarray(upd["x"])).all()


def test_sgd_is_plain():
    init, update = sgd(0.1)
    upd, _ = update({"g": jnp.asarray(2.0)}, init(None), None)
    assert float(upd["g"]) == pytest.approx(-0.2)
