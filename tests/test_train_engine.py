"""Unified training engine (repro.train, DESIGN.md §9).

Covers the scan-epoch contract: scan == python-loop numerics, tail batches
kept, one host sync per eval window, full-state checkpoint/resume
bit-identity, and user-set ``check_every`` being honored.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bop as bop_lib
from repro.core import controller as ctrl
from repro.core.controller import CGMQConfig
from repro.core.pipeline import (
    PipelineConfig,
    prepare_bundle,
    run_cgmq_stage,
    steps_per_epoch,
)
from repro.core.sites import QuantConfig
from repro.data.synthetic import digits
from repro.models import lenet
from repro.train import (
    EngineConfig,
    TrainEngine,
    restore_state,
    save_state,
    stage_epoch,
)

BATCH = 64


@pytest.fixture(scope="module")
def tiny_digits():
    xtr, ytr = digits(300, split="train")   # 300 = 4 full batches + tail of 44
    xte, yte = digits(120, split="test")
    return (
        (jnp.asarray(xtr), jnp.asarray(ytr)),
        (jnp.asarray(xte), jnp.asarray(yte)),
    )


@pytest.fixture(scope="module")
def tiny_bundle(tiny_digits):
    train, test = tiny_digits
    params = lenet.init_params(jax.random.PRNGKey(0))
    return prepare_bundle(
        lenet.forward, lenet.weight_lookup, params, train, test,
        QuantConfig(), _pcfg(), seed=0,
    )


def _pcfg(**kw):
    base = dict(pretrain_epochs=2, range_epochs=1, cgmq_epochs=6,
                batch_size=BATCH, eval_every=2, log=lambda s: None)
    base.update(kw)
    return PipelineConfig(**base)


def _engine(bundle, loop, ccfg):
    eng = TrainEngine(
        lenet.forward,
        EngineConfig(batch_size=BATCH, lr=1e-3, eval_every=2, loop=loop,
                     log=lambda s: None),
        qcfg=bundle.qcfg)
    eng.bind_sites(bundle.sites, bundle.signed)
    eng.bind_controller(ccfg, bop_lib.budget_from_rbop(bundle.sites,
                                                       ccfg.budget_rbop))
    return eng


# ---------------------------------------------------------------------------
# Batch staging: tail batches kept
# ---------------------------------------------------------------------------


def test_stage_epoch_keeps_tail_batch():
    """ceil(N/B) batches; every sample appears exactly once with weight 1
    (the seed loop dropped the tail partial batch)."""
    xs = jnp.arange(10, dtype=jnp.float32)[:, None]
    ys = jnp.arange(10, dtype=jnp.int32)
    bx, by, bw, _ = stage_epoch(jax.random.PRNGKey(0), xs, ys, 4)
    assert bx.shape == (3, 4, 1) and bw.shape == (3, 4)
    assert float(jnp.sum(bw)) == 10.0
    real = np.asarray(by).ravel()[np.asarray(bw).ravel() == 1.0]
    assert sorted(real.tolist()) == list(range(10))


def test_stage_epoch_dataset_smaller_than_batch():
    """pad > N (dataset smaller than half a batch): padding cycles the
    permutation instead of under-filling the reshape."""
    xs = jnp.arange(10, dtype=jnp.float32)[:, None]
    ys = jnp.arange(10, dtype=jnp.int32)
    bx, by, bw, _ = stage_epoch(jax.random.PRNGKey(0), xs, ys, 64)
    assert bx.shape == (1, 64, 1)
    assert float(jnp.sum(bw)) == 10.0
    real = np.asarray(by).ravel()[np.asarray(bw).ravel() == 1.0]
    assert sorted(real.tolist()) == list(range(10))


def test_engine_rejects_scalar_mean_loss(tiny_bundle, tiny_digits):
    """A legacy scalar-mean loss (pipeline.cross_entropy) must error loudly
    instead of silently training on tail-padding duplicates."""
    from repro.core.pipeline import cross_entropy

    train, test = tiny_digits
    eng = TrainEngine(
        lenet.forward,
        EngineConfig(batch_size=BATCH, eval_every=2, log=lambda s: None),
        qcfg=tiny_bundle.qcfg, loss_fn=cross_entropy)
    eng.bind_sites(tiny_bundle.sites, tiny_bundle.signed)
    state = eng.init_quant_state(tiny_bundle.params, tiny_bundle.betas,
                                 tiny_bundle.gates, tiny_bundle.probes)
    with pytest.raises(ValueError, match="PER-EXAMPLE"):
        eng.run_stage(state, "range", train, 1)


def test_stage_epoch_full_batches_unweighted():
    xs = jnp.zeros((8, 2))
    ys = jnp.zeros((8,), jnp.int32)
    bx, by, bw, _ = stage_epoch(jax.random.PRNGKey(0), xs, ys, 4)
    assert bx.shape == (2, 4, 2)
    assert float(jnp.min(bw)) == 1.0


def test_eval_is_batched_and_matches_full_forward(tiny_bundle, tiny_digits):
    _, test = tiny_digits
    eng = _engine(tiny_bundle, "scan", CGMQConfig(check_every=5))
    acc = eng.eval_accuracy(tiny_bundle.params, test, quant=False)
    from repro.core.sites import QuantContext

    logits = lenet.forward(QuantContext(mode="off"), tiny_bundle.params,
                           test[0])
    want = float(jnp.mean((jnp.argmax(logits, -1) == test[1])
                          .astype(jnp.float32)))
    assert abs(acc - want) < 1e-6


# ---------------------------------------------------------------------------
# Scan == python-loop reference (acceptance criterion)
# ---------------------------------------------------------------------------


def _run_cgmq(bundle, train, test, loop, epochs=4):
    ccfg = CGMQConfig(budget_rbop=0.02, direction="dir1", gate_lr=0.01,
                      check_every=steps_per_epoch(train[0].shape[0], BATCH))
    eng = _engine(bundle, loop, ccfg)
    state = eng.init_quant_state(bundle.params, bundle.betas, bundle.gates,
                                 bundle.probes, seed=7)
    state, history = eng.run_stage(state, "cgmq", train, epochs,
                                   eval_data=test)
    return eng, state, history


def test_scan_epoch_matches_python_loop(tiny_bundle, tiny_digits):
    """Same seed => identical gate trajectory, Sat flags and eval accuracy
    between the jitted-scan engine and the per-batch python reference."""
    train, test = tiny_digits
    _, s_scan, h_scan = _run_cgmq(tiny_bundle, train, test, "scan")
    _, s_py, h_py = _run_cgmq(tiny_bundle, train, test, "python")

    # The two loop modes share staging + step code but compile as different
    # XLA programs, so trajectories agree to float-reassociation tolerance,
    # not bitwise: gates to < 5e-4 gate-units after 4 epochs, Sat flags
    # exactly, eval accuracy to < 1 test sample (120 samples -> 1/120).
    for k in s_scan.cgmq.gates:
        np.testing.assert_allclose(
            np.asarray(s_scan.cgmq.gates[k]), np.asarray(s_py.cgmq.gates[k]),
            rtol=0, atol=5e-4, err_msg=k)
    assert bool(s_scan.cgmq.sat) == bool(s_py.cgmq.sat)
    assert bool(s_scan.cgmq.best_valid) == bool(s_py.cgmq.best_valid)
    assert [h["sat"] for h in h_scan] == [h["sat"] for h in h_py]
    for a, b in zip(h_scan, h_py):
        assert abs(a["acc"] - b["acc"]) < 0.5 / 120, (a, b)  # same hit count
        assert abs(a["rbop"] - b["rbop"]) < 1e-5


def test_one_host_sync_per_eval_window(tiny_bundle, tiny_digits):
    train, test = tiny_digits
    eng, _, history = _run_cgmq(tiny_bundle, train, test, "scan", epochs=6)
    # eval_every=2, 6 epochs => 3 windows => exactly 3 host transfers
    assert len(history) == 3
    assert eng.host_syncs == 3


# ---------------------------------------------------------------------------
# check_every semantics (satellite: honor user-set values)
# ---------------------------------------------------------------------------


def test_user_check_every_is_honored(tiny_bundle, tiny_digits):
    """A user-set check_every must survive run_cgmq_stage; only an unset
    (None) value defaults to steps-per-epoch (the seed overwrote both)."""
    train, test = tiny_digits
    spe = steps_per_epoch(train[0].shape[0], BATCH)
    assert spe == 5  # 300 samples / 64 -> 4 full + 1 tail batch

    # A trivially satisfiable budget with a check interval that never comes
    # due: no check fires, so nothing is ever certified. The seed replaced
    # check_every with steps-per-epoch, which would certify at the first
    # epoch end — best_valid distinguishes the two behaviors.
    never = CGMQConfig(budget_rbop=1.0, direction="dir1", gate_lr=0.01,
                       check_every=10**9)
    res = run_cgmq_stage(lenet.forward, tiny_bundle, train, test, never,
                         _pcfg(cgmq_epochs=2))
    assert not bool(res.state.best_valid)
    assert int(res.state.step) == 2 * spe  # tail batch runs as a real step

    # Unset (None) defaults to end-of-epoch checking: certifies immediately.
    res2 = run_cgmq_stage(lenet.forward, tiny_bundle, train, test,
                          CGMQConfig(budget_rbop=1.0, direction="dir1",
                                     gate_lr=0.01),
                          _pcfg(cgmq_epochs=2))
    assert bool(res2.state.best_valid)


def test_controller_update_treats_none_as_every_step():
    gates = {"a.w": jnp.asarray(5.5), "a.a": jnp.asarray(5.5)}
    from repro.core.sites import SiteInfo

    sites = {"a": SiteInfo(name="a", weight_shape=(4, 4), fan_in=4,
                           out_features=4, positions=1, stack=1,
                           active_frac=1.0, act_quantized=True)}
    state = ctrl.init_state(gates, sites)
    cfg = CGMQConfig(budget_rbop=1.0)  # check_every defaults to None
    probe = {"a.w": jnp.asarray(0.1), "a.a": jnp.asarray(0.1)}
    wstats = {"a.w": jnp.asarray(1.0)}
    astats = {"a.a": {"mean_abs": jnp.asarray(1.0)}}
    budget = bop_lib.budget_from_rbop(sites, 1.0)
    new = ctrl.controller_update(state, cfg, sites, probe, wstats, astats,
                                 budget)
    # due on step 1 (None == check every step): bop refreshed, sat=True
    assert bool(new.sat)


# ---------------------------------------------------------------------------
# Full-state checkpoint / resume (satellite: bit-identical continuation)
# ---------------------------------------------------------------------------


def test_checkpoint_resume_is_bit_identical(tiny_bundle, tiny_digits,
                                            tmp_path):
    train, test = tiny_digits
    ccfg = CGMQConfig(budget_rbop=0.02, direction="dir1", gate_lr=0.01,
                      check_every=steps_per_epoch(train[0].shape[0], BATCH))

    # uninterrupted: 4 epochs
    eng_a = _engine(tiny_bundle, "scan", ccfg)
    sa = eng_a.init_quant_state(tiny_bundle.params, tiny_bundle.betas,
                                tiny_bundle.gates, tiny_bundle.probes, seed=3)
    sa, ha = eng_a.run_stage(sa, "cgmq", train, 4, eval_data=test)

    # interrupted: 2 epochs, save, restore into a FRESH engine, 2 more
    ck_dir = str(tmp_path / "ck")
    eng_b = _engine(tiny_bundle, "scan", ccfg)
    sb = eng_b.init_quant_state(tiny_bundle.params, tiny_bundle.betas,
                                tiny_bundle.gates, tiny_bundle.probes, seed=3)
    sb, hb1 = eng_b.run_stage(sb, "cgmq", train, 2, eval_data=test)
    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(ck_dir)
    save_state(ck, 2, sb, extra={"stage": "cgmq", "epoch": 2})

    eng_c = _engine(tiny_bundle, "scan", ccfg)
    template = eng_c.init_quant_state(tiny_bundle.params, tiny_bundle.betas,
                                      tiny_bundle.gates, tiny_bundle.probes,
                                      seed=3)
    sc, epoch, extra = restore_state(ck, template)
    assert epoch == 2 and extra["stage"] == "cgmq"
    sc, hb2 = eng_c.run_stage(sc, "cgmq", train, 4, eval_data=test,
                              start_epoch=epoch)

    # gate trajectory, controller flags and eval accuracy: bit-identical
    for k in sa.cgmq.gates:
        np.testing.assert_array_equal(np.asarray(sa.cgmq.gates[k]),
                                      np.asarray(sc.cgmq.gates[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(sa.cgmq.best_gates[k]),
                                      np.asarray(sc.cgmq.best_gates[k]))
    assert bool(sa.cgmq.sat) == bool(sc.cgmq.sat)
    assert bool(sa.cgmq.best_valid) == bool(sc.cgmq.best_valid)
    assert int(sa.cgmq.step) == int(sc.cgmq.step)
    assert int(sa.step) == int(sc.step)
    np.testing.assert_array_equal(np.asarray(sa.rng), np.asarray(sc.rng))
    full = ha[-1]
    resumed = hb2[-1]
    assert full["sat"] == resumed["sat"]
    assert abs(full["acc"] - resumed["acc"]) < 1e-7
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(sa.params)[0]),
        np.asarray(jax.tree.leaves(sc.params)[0]), rtol=0, atol=0)


def test_run_cgmq_stage_resume_path(tiny_bundle, tiny_digits, tmp_path):
    """The pipeline-level ckpt_dir/resume plumbing reproduces the full run."""
    train, test = tiny_digits
    ck_dir = str(tmp_path / "stage_ck")

    def _cfg():
        return CGMQConfig(budget_rbop=0.02, direction="dir1", gate_lr=0.01)

    full = run_cgmq_stage(lenet.forward, tiny_bundle, train, test, _cfg(),
                          _pcfg(cgmq_epochs=4))

    # run to epoch 2 (checkpointing every eval window = 2 epochs), then kill
    run_cgmq_stage(lenet.forward, tiny_bundle, train, test, _cfg(),
                   _pcfg(cgmq_epochs=2), ckpt_dir=ck_dir)
    resumed = run_cgmq_stage(lenet.forward, tiny_bundle, train, test, _cfg(),
                             _pcfg(cgmq_epochs=4), ckpt_dir=ck_dir,
                             resume=True)

    assert full.satisfied == resumed.satisfied
    assert abs(full.final_test_acc - resumed.final_test_acc) < 1e-6
    assert abs(full.final_rbop - resumed.final_rbop) < 1e-9
    for k in full.state.gates:
        np.testing.assert_array_equal(np.asarray(full.state.gates[k]),
                                      np.asarray(resumed.state.gates[k]))


# ---------------------------------------------------------------------------
# Data-parallel sharding (subprocess: multi-device host platform)
# ---------------------------------------------------------------------------


def test_data_parallel_engine_matches_unsharded():
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bop as bop_lib
        from repro.core.controller import CGMQConfig
        from repro.core.pipeline import prepare_bundle, PipelineConfig, steps_per_epoch
        from repro.core.sites import QuantConfig
        from repro.data.synthetic import digits
        from repro.distributed.sharding import ShardingPlan
        from repro.models import lenet
        from repro.train import EngineConfig, TrainEngine

        xtr, ytr = digits(128, split="train")
        xte, yte = digits(64, split="test")
        train = (jnp.asarray(xtr), jnp.asarray(ytr))
        test = (jnp.asarray(xte), jnp.asarray(yte))
        pcfg = PipelineConfig(pretrain_epochs=1, range_epochs=1, cgmq_epochs=2,
                              batch_size=32, eval_every=2, log=lambda s: None)
        params = lenet.init_params(jax.random.PRNGKey(0))
        bundle = prepare_bundle(lenet.forward, lenet.weight_lookup, params,
                                train, test, QuantConfig(), pcfg)
        ccfg = CGMQConfig(budget_rbop=0.05, direction="dir1", gate_lr=0.01,
                          check_every=steps_per_epoch(128, 32))
        out = {}
        for shard in (False, True):
            plan = None
            if shard:
                # plain Mesh (not launch.mesh.make_test_mesh): works on jax
                # versions without jax.sharding.AxisType
                mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
                plan = ShardingPlan(mesh=mesh, cfg=None, batch_axes=("data",))
            eng = TrainEngine(lenet.forward,
                              EngineConfig(batch_size=32, eval_every=2,
                                           log=lambda s: None),
                              qcfg=bundle.qcfg, plan=plan)
            eng.bind_sites(bundle.sites, bundle.signed)
            eng.bind_controller(ccfg, bop_lib.budget_from_rbop(bundle.sites, 0.05))
            state = eng.shard_state(eng.init_quant_state(
                bundle.params, bundle.betas, bundle.gates, bundle.probes, seed=1))
            state, hist = eng.run_stage(state, "cgmq", train, 2, eval_data=test)
            out[str(shard)] = {"loss": hist[-1]["loss"], "acc": hist[-1]["acc"],
                               "rbop": hist[-1]["rbop"]}
        print(json.dumps(out))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    import json

    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["False"]["loss"] - res["True"]["loss"]) < 5e-3
    assert abs(res["False"]["acc"] - res["True"]["acc"]) < 1e-4
    assert abs(res["False"]["rbop"] - res["True"]["rbop"]) < 1e-6
