"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The property tests only need ``@settings`` / ``@given`` with four strategy
kinds (floats, integers, booleans, sampled_from). This shim replays each
test body over a fixed-seed sample of the strategy space — no shrinking, no
database, but the suite collects and the properties still get exercised on
machines without the package. Real hypothesis is preferred whenever
importable (see the try/except in the test modules).
"""

from __future__ import annotations

import random

# Keep the fallback fast: hypothesis-configured example counts (50-80) are
# overkill for a fixed-seed replay.
_MAX_EXAMPLES_CAP = 25


class _Strategy:
    def __init__(self, sample):
        self.sample = sample  # rng -> drawn value


class _Strategies:
    @staticmethod
    def floats(min_value, max_value, **_):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value, max_value, **_):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])


strategies = _Strategies()


def settings(max_examples=20, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # No functools.wraps: copying __wrapped__ would make pytest read the
        # original signature and hunt for fixtures named like the strategy
        # args. The replayed test takes no pytest-visible parameters.
        def run():
            n = min(getattr(run, "_max_examples", 20), _MAX_EXAMPLES_CAP)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(**drawn)

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run

    return deco
