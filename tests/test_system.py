"""End-to-end behaviour tests for the CGMQ system (fast CI versions)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import bop as bop_lib
from repro.launch import steps as steps_lib


def test_llm_cgmq_training_reaches_and_certifies_budget():
    """The full production train step drives a small LM under its BOP budget
    and certifies a satisfying snapshot (paper §3 at LLM scale)."""
    cfg = get_smoke_config("tinyllama-1.1b")
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    recipe = steps_lib.make_recipe(cfg, shape, budget_rbop=0.0625,
                                   check_every=5)
    state = steps_lib.init_train_state(recipe, jax.random.PRNGKey(0))
    step = jax.jit(steps_lib.make_train_step(recipe, None),
                   donate_argnums=(0,))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                               jnp.int32),
    }
    losses = []
    for _ in range(60):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    # the guarantee is about the certified export: gates oscillate around
    # the boundary once reached (Sat lets them grow back), but a satisfying
    # snapshot must exist and must meet the budget.
    from repro.core import controller as ctrl

    assert bool(state.cgmq.best_valid)
    assert ctrl.guarantee_satisfied(state.cgmq, recipe.sites,
                                    recipe.budget_bop)


def test_decode_after_cgmq_training_is_finite():
    """Train a few steps, then serve with the same quantized state."""
    from repro.core.sites import QuantContext, merge_ranges
    from repro.models import transformer as tfm

    cfg = get_smoke_config("gemma2-2b")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    recipe = steps_lib.make_recipe(cfg, shape, check_every=3)
    state = steps_lib.init_train_state(recipe, jax.random.PRNGKey(1))
    step = jax.jit(steps_lib.make_train_step(recipe, None))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                               jnp.int32),
    }
    for _ in range(5):
        state, _ = step(state, batch)

    qc = QuantContext(
        mode="train", cfg=recipe.qcfg, gates=state.cgmq.gates,
        ranges=merge_ranges(state.betas, recipe.signed), probes={},
    )
    cache = tfm.init_cache(cfg, 2, max_seq=8)
    logits, cache = tfm.decode_step(
        qc, state.params, cache, jnp.asarray([1, 2], jnp.int32), cfg)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
