"""Unit + property tests for the Eq. 1 quantizer and its STE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

from repro.core.quantizer import fake_quant, quantize, quantize_to_int

jax.config.update("jax_enable_x64", False)


def _grid(bits, beta, signed):
    n = 2**bits - 1
    alpha = -beta if signed else 0.0
    s = (beta - alpha) / n
    return alpha + s * np.arange(n + 1)


@pytest.mark.parametrize("bits", [2, 4, 8, 16])
@pytest.mark.parametrize("signed", [True, False])
def test_quantize_on_grid(bits, signed):
    """Quantized values land exactly on the b-bit uniform grid."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(512,)).astype(np.float32) * 2.0
    beta = 1.5
    q = np.asarray(quantize(jnp.asarray(x), bits, beta, signed))
    grid = _grid(bits, beta, signed)
    dist = np.abs(q[:, None] - grid[None, :]).min(axis=1)
    assert dist.max() < 1e-5


@pytest.mark.parametrize("signed", [True, False])
def test_quantize_idempotent(signed):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q1 = quantize(x, 4, 1.0, signed)
    q2 = quantize(q1, 4, 1.0, signed)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


def test_quantize_clips_to_range():
    x = jnp.asarray([-10.0, 10.0], jnp.float32)
    q = np.asarray(quantize(x, 8, 2.0, True))
    assert q[0] == pytest.approx(-2.0, abs=1e-6)
    assert q[1] == pytest.approx(2.0, abs=1e-6)


def test_bits_32_passthrough():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(64,)), jnp.float32)
    q = quantize(x, 32, 1.0, True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


def test_monotone_error_in_bits():
    """More bits never increases quantization error (for fixed range)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(-1, 1, size=(2048,)).astype(np.float32))
    errs = []
    for b in (2, 4, 8, 16):
        q = quantize(x, b, 1.0, True)
        errs.append(float(jnp.abs(q - x).mean()))
    assert errs == sorted(errs, reverse=True)


def test_per_element_bits():
    """`bits` may be an array: each element quantized at its own width."""
    x = jnp.asarray([0.333, 0.333, 0.333, 0.333], jnp.float32)
    bits = jnp.asarray([2.0, 4.0, 8.0, 32.0])
    q = np.asarray(quantize(x, bits, 1.0, False))
    for i, b in enumerate([2, 4, 8]):
        grid = _grid(b, 1.0, False)
        assert np.abs(q[i] - grid).min() < 1e-6
    assert q[3] == np.float32(0.333)


def test_ste_gradient_masked_by_range():
    f = lambda x: fake_quant(x, jnp.asarray(4.0), jnp.asarray(1.0), True).sum()
    g = jax.grad(f)(jnp.asarray([-2.0, -0.5, 0.5, 2.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


def test_range_gradient_signs():
    """LSQ-style beta gradient: +1 above range, -1 below (signed)."""

    def f(beta):
        x = jnp.asarray([5.0, -5.0, 0.25], jnp.float32)
        return (fake_quant(x, jnp.asarray(4.0), beta, True) * jnp.asarray([1.0, 1.0, 1.0])).sum()

    g = jax.grad(f)(jnp.asarray(1.0))
    # top-clip contributes +1, bottom-clip -1 (cancel); in-range term small.
    assert abs(float(g)) < 1.0

    def f_top(beta):
        return fake_quant(jnp.asarray([5.0], jnp.float32), jnp.asarray(4.0), beta, True).sum()

    assert float(jax.grad(f_top)(jnp.asarray(1.0))) == pytest.approx(1.0)


def test_range_gradient_unsigned_bottom_zero():
    def f(beta):
        return fake_quant(jnp.asarray([-3.0], jnp.float32), jnp.asarray(4.0), beta, False).sum()

    assert float(jax.grad(f)(jnp.asarray(1.0))) == pytest.approx(0.0)


@settings(max_examples=50, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8, 16]),
    beta=st.floats(0.1, 10.0),
    signed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quant_error_bound(bits, beta, signed, seed):
    """|Q(x) - clip(x)| <= step/2 for every element (round-to-nearest)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32) * beta)
    q = quantize(x, bits, beta, signed)
    alpha = -beta if signed else 0.0
    step = (beta - alpha) / (2**bits - 1)
    xc = jnp.clip(x, alpha, beta)
    assert float(jnp.abs(q - xc).max()) <= step / 2 + 1e-5


def test_quantize_to_int_roundtrip():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    beta = jnp.max(jnp.abs(x))
    codes, scale, bias = quantize_to_int(x, 8, beta, True)
    assert codes.dtype == jnp.int8
    deq = codes.astype(jnp.float32) * scale + bias
    q = quantize(x, 8, beta, True)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(q), atol=1e-4)
