"""Serving under pressure: the §13 failure model.

Chaos coverage for the overload/fault surface of the serving engine
(DESIGN.md §13):
  * submit-time request validation — malformed requests fail fast with a
    uniform ValueError, never reaching a slot,
  * KV-pool exhaustion → victim preemption: on an undersized pool every
    request still completes, and each preempted stream is BIT-IDENTICAL to
    the same request run solo on an ample pool — both KV layouts, greedy
    and seeded sampling (the §12 purity contract survives eviction),
  * bounded admission: queue capacity with reject / block policies,
    watermark-based admission that avoids preemption entirely,
  * per-request TTFT and wall deadlines against an injectable clock,
  * non-finite logits fail ONLY the poisoned request (FINISHED_ERROR) with
    the one-host-sync-per-tick ledger unchanged,
  * deadline-priority waiting queue: head-of-line holds, no starvation,
  * ServingSupervisor: mid-generation crash → engine rebuild + request-log
    replay produces the same results as an uninterrupted run; slow ticks
    feed the shared StragglerDetector.

Overload NEVER surfaces as an exception from ``step()``: it becomes a
typed ``FINISHED_*`` reason or backpressure at ``submit()``.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving import (FINISHED_DEADLINE, FINISHED_ERROR,
                           FINISHED_LENGTH, FINISHED_REJECTED,
                           TERMINAL_REASONS, AdmissionConfig, FaultInjector,
                           Request, SamplingParams, ServingEngine,
                           ServingSupervisor, WaitingQueue)

ARCH = "tinyllama-1.1b"


def _model(seed=0, arch=ARCH):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _prompts(cfg, n, plen, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
            for _ in range(n)]


def _drain(eng, max_ticks=500):
    """Drive step() until no work remains; overload must never raise."""
    for _ in range(max_ticks):
        if not eng.waiting and all(r is None for r in eng.slot_req):
            break
        eng.step()
    assert not eng.waiting and all(r is None for r in eng.slot_req), "engine did not drain"


def _solo_tokens(cfg, params, prompt, sp, kv_layout, max_seq=64):
    """Reference stream: the same request alone on an ample pool."""
    eng = ServingEngine(cfg, params, slots=2, max_seq=max_seq,
                        kv_layout=kv_layout)
    req = eng.submit(Request(rid=1, prompt=prompt, params=sp))
    _drain(eng)
    assert req.finish_reason == FINISHED_LENGTH
    return list(req.output)


# ---------------------------------------------------------------------------
# Submit-time request validation
# ---------------------------------------------------------------------------


class TestSubmitValidation:
    @pytest.fixture(scope="class")
    def eng(self):
        cfg, params = _model()
        return ServingEngine(cfg, params, slots=2, max_seq=32)

    def test_empty_prompt_rejected(self, eng):
        with pytest.raises(ValueError, match="prompt"):
            eng.submit(Request(rid=9, prompt=np.zeros(0, np.int32)))

    def test_non_positive_max_new_rejected(self, eng):
        # SamplingParams owns max_new validation; submit can never see <= 0
        with pytest.raises(ValueError, match="max_new"):
            SamplingParams(max_new=0)
        with pytest.raises(ValueError, match="max_new"):
            Request(rid=9, prompt=np.ones(3, np.int32), max_new=-1)

    def test_out_of_vocab_token_ids_rejected(self, eng):
        vocab = eng.cfg.vocab_size
        for bad in ([0, 1, vocab], [-1, 0, 1]):
            with pytest.raises(ValueError, match="token ids outside"):
                eng.submit(Request(rid=9,
                                   prompt=np.asarray(bad, np.int32)))

    def test_non_integer_prompt_rejected(self, eng):
        with pytest.raises(ValueError, match="integer"):
            eng.submit(Request(rid=9,
                               prompt=np.asarray([0.5, 1.0, 2.0])))

    def test_too_long_prompt_rejected(self, eng):
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(Request(
                rid=9, prompt=np.ones(eng.max_seq + 1, np.int32)))

    def test_rejected_request_never_reaches_queue(self, eng):
        before = len(eng.waiting)
        with pytest.raises(ValueError):
            eng.submit(Request(rid=9, prompt=np.zeros(0, np.int32)))
        assert len(eng.waiting) == before


# ---------------------------------------------------------------------------
# KV-pool exhaustion -> preemption -> identical resumed streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_preempted_streams_identical_to_solo_paged(temperature):
    """THE §13 acceptance bar: on a pool too small for the offered load,
    requests are preempted mid-decode, re-queued, resumed via prefix
    replay — and every finished stream is bit-identical to running that
    request alone on an ample pool. Greedy and seeded sampling."""
    cfg, params = _model()
    prompts = _prompts(cfg, 4, 12)
    sps = [SamplingParams(temperature=temperature, top_p=0.9, seed=100 + i,
                          max_new=24) for i in range(4)]
    solo = [_solo_tokens(cfg, params, p, sp, "paged")
            for p, sp in zip(prompts, sps)]

    eng = ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=14)
    assert eng.preemption     # undersized pool auto-enables eviction
    reqs = [eng.submit(Request(rid=i + 1, prompt=p, params=sp))
            for i, (p, sp) in enumerate(zip(prompts, sps))]
    _drain(eng)

    assert eng.stats["preemptions"] > 0
    assert eng.stats["resumed_admissions"] > 0
    for req, ref in zip(reqs, solo):
        assert req.finish_reason == FINISHED_LENGTH
        assert list(req.output) == ref, f"rid {req.rid} diverged"
    # no leaked blocks after the storm
    assert eng.pool_stats()["blocks_in_use"] == 0


@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_preempted_streams_identical_to_solo_ring(temperature):
    """Ring layout has no pool to exhaust, but host-forced preemption
    (`engine.preempt`) must give the same resume-identical streams."""
    cfg, params = _model()
    prompts = _prompts(cfg, 2, 10, seed=3)
    sps = [SamplingParams(temperature=temperature, seed=7 + i, max_new=12)
           for i in range(2)]
    solo = [_solo_tokens(cfg, params, p, sp, "ring")
            for p, sp in zip(prompts, sps)]

    eng = ServingEngine(cfg, params, slots=2, max_seq=64, kv_layout="ring")
    reqs = [eng.submit(Request(rid=i + 1, prompt=p, params=sp))
            for i, (p, sp) in enumerate(zip(prompts, sps))]
    for _ in range(4):
        eng.step()
    eng.preempt(0)            # evict mid-generation
    _drain(eng)

    assert eng.stats["preemptions"] == 1
    assert reqs[0].preemptions == 1
    for req, ref in zip(reqs, solo):
        assert req.finish_reason == FINISHED_LENGTH
        assert list(req.output) == ref


def test_in_tick_exhaustion_frees_blocks_same_tick():
    """When the injector drains the free list mid-run, the NEXT allocating
    tick picks victims inside the jitted tick, frees their blocks in the
    same tick, and the run still completes after the pool is restored."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, num_blocks=17,
                        preemption=True)
    prompts = _prompts(cfg, 2, 9, seed=5)
    reqs = [eng.submit(Request(rid=i + 1, prompt=p,
                               params=SamplingParams(max_new=20)))
            for i, p in enumerate(prompts)]
    for _ in range(3):
        eng.step()
    stolen = eng.drain_free_blocks(leave=0)
    assert stolen > 0
    for _ in range(6):
        eng.step()            # forces in-tick victim preemption
    assert eng.stats["preemptions"] >= 1
    eng.restore_free_blocks()
    _drain(eng)
    assert all(r.finish_reason == FINISHED_LENGTH for r in reqs)
    assert eng.pool_stats()["blocks_in_use"] == 0


def test_preemption_keeps_one_host_sync_per_tick():
    """The preemption/NaN masks ride the existing tick sync: tick_syncs
    stays exactly equal to decode_ticks through an eviction storm."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=14)
    for i, p in enumerate(_prompts(cfg, 4, 12)):
        eng.submit(Request(rid=i + 1, prompt=p,
                           params=SamplingParams(max_new=24)))
    _drain(eng)
    assert eng.stats["preemptions"] > 0
    assert eng.stats["tick_syncs"] == eng.stats["decode_ticks"]


# ---------------------------------------------------------------------------
# Non-finite logits guard
# ---------------------------------------------------------------------------


def test_nan_logits_fail_only_the_poisoned_request():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=32)
    prompts = _prompts(cfg, 2, 6, seed=11)
    sp = SamplingParams(max_new=10)
    good = eng.submit(Request(rid=1, prompt=prompts[0], params=sp))
    bad = eng.submit(Request(rid=2, prompt=prompts[1], params=sp))
    eng.step()                 # both admitted, first tokens emitted
    victim = next(s for s in range(eng.slots)
                  if eng.slot_req[s] is bad)
    eng.inject_logit_fault(victim)
    _drain(eng)
    assert bad.finish_reason == FINISHED_ERROR
    assert eng.stats["nan_failures"] == 1
    assert good.finish_reason == FINISHED_LENGTH
    assert len(good.output) == 10
    assert eng.stats["tick_syncs"] == eng.stats["decode_ticks"]


# ---------------------------------------------------------------------------
# Bounded admission + backpressure
# ---------------------------------------------------------------------------


def test_queue_capacity_reject_policy():
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                        admission=AdmissionConfig(queue_capacity=2,
                                                  on_full="reject"))
    prompts = _prompts(cfg, 6, 6)
    sp = SamplingParams(max_new=6)
    reqs = [eng.submit(Request(rid=i + 1, prompt=p, params=sp))
            for i, p in enumerate(prompts)]
    rejected = [r for r in reqs if r.finish_reason == FINISHED_REJECTED]
    assert len(rejected) == 4          # 2 queue seats, no ticks in between
    assert all(r.done for r in rejected)
    _drain(eng)
    assert eng.stats["rejected_requests"] == 4
    served = [r for r in reqs if r.finish_reason == FINISHED_LENGTH]
    assert len(served) == 2
    assert all(len(r.output) == 6 for r in served)


def test_queue_capacity_block_policy_serves_everyone():
    """``block`` turns submit() into backpressure: it drives ticks until a
    queue seat frees, so every request is eventually served."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                        admission=AdmissionConfig(queue_capacity=2,
                                                  on_full="block"))
    sp = SamplingParams(max_new=6)
    reqs = [eng.submit(Request(rid=i + 1, prompt=p, params=sp))
            for i, p in enumerate(_prompts(cfg, 6, 6))]
    _drain(eng)
    assert [r.finish_reason for r in reqs] == [FINISHED_LENGTH] * 6
    assert eng.stats["rejected_requests"] == 0


def test_watermark_admission_avoids_preemption():
    """With a pool-occupancy watermark, the engine holds requests in the
    queue instead of admitting into guaranteed eviction: same undersized
    pool as the chaos test, zero preemptions."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=4, max_seq=64, num_blocks=14,
                        admission=AdmissionConfig(watermark=1.0))
    reqs = [eng.submit(Request(rid=i + 1, prompt=p,
                               params=SamplingParams(max_new=24)))
            for i, p in enumerate(_prompts(cfg, 4, 12))]
    _drain(eng)
    assert eng.stats["preemptions"] == 0
    assert all(r.finish_reason == FINISHED_LENGTH for r in reqs)


def test_deadlines_expire_with_typed_reason():
    """TTFT and wall deadlines resolve against an injectable clock; expiry
    is a FINISHED_DEADLINE result, never an exception."""
    cfg, params = _model()
    now = [0.0]
    eng = ServingEngine(cfg, params, slots=1, max_seq=32,
                        clock=lambda: now[0],
                        admission=AdmissionConfig(deadline_s=10.0))
    sp = SamplingParams(max_new=8)
    prompts = _prompts(cfg, 3, 6)
    r1 = eng.submit(Request(rid=1, prompt=prompts[0], params=sp))
    r2 = eng.submit(Request(rid=2, prompt=prompts[1], params=sp,
                            ttft_deadline_s=5.0))   # per-request override
    r3 = eng.submit(Request(rid=3, prompt=prompts[2], params=sp))
    for _ in range(3):
        eng.step()
    now[0] = 20.0              # everything is now past its budget
    _drain(eng)
    # r2 has the tightest budget (TTFT 5s), so the deadline-priority queue
    # admitted IT first; it ran until the wall deadline caught it
    assert r2.finish_reason == FINISHED_DEADLINE   # running past wall
    assert 0 < len(r2.output) < 8  # kept what it generated before expiry
    assert r1.finish_reason == FINISHED_DEADLINE   # expired while waiting
    assert r3.finish_reason == FINISHED_DEADLINE
    assert r1.output == [] and r3.output == []
    assert eng.stats["deadline_expired"] == 3
    assert all(r.finish_reason in TERMINAL_REASONS for r in (r1, r2, r3))


# ---------------------------------------------------------------------------
# Deadline-priority queue: ordering + no starvation
# ---------------------------------------------------------------------------


def test_waiting_queue_orders_by_deadline_then_seq():
    q = WaitingQueue()
    loose = Request(rid=1, prompt=np.ones(2, np.int32))
    loose.seq, loose.deadline_by = 0, 100.0
    tight = Request(rid=2, prompt=np.ones(2, np.int32))
    tight.seq, tight.deadline_by = 1, 5.0
    fifo_a = Request(rid=3, prompt=np.ones(2, np.int32))
    fifo_a.seq = 2
    for r in (loose, tight, fifo_a):
        q.push(r)
    # tightest deadline first; ties (both inf) fall back to FIFO seq
    assert [q.pop().rid for _ in range(3)] == [2, 1, 3]


def test_preempted_request_not_starved():
    """A preempted request keeps its original seq, so it re-queues AHEAD
    of younger traffic and completes even under sustained load."""
    cfg, params = _model()
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, num_blocks=17,
                        preemption=True)
    old = eng.submit(Request(rid=1, prompt=_prompts(cfg, 1, 8)[0],
                             params=SamplingParams(max_new=16)))
    for _ in range(2):
        eng.step()
    eng.preempt(next(s for s in range(eng.slots)
                     if eng.slot_req[s] is old))
    # pile on younger requests while rid 1 waits
    young = [eng.submit(Request(rid=10 + i, prompt=p,
                                params=SamplingParams(max_new=4)))
             for i, p in enumerate(_prompts(cfg, 4, 8, seed=9))]
    assert next(iter(eng.waiting)).rid == 1    # head of line
    _drain(eng)
    assert old.finish_reason == FINISHED_LENGTH
    assert len(old.output) == 16
    assert all(r.finish_reason == FINISHED_LENGTH for r in young)


# ---------------------------------------------------------------------------
# Supervisor: crash-restart-replay + straggler detection
# ---------------------------------------------------------------------------


def _factory(cfg, params, **kw):
    def make():
        return ServingEngine(cfg, params, slots=2, max_seq=48, **kw)
    return make


def test_supervisor_restart_replays_to_identical_results():
    cfg, params = _model()
    prompts = _prompts(cfg, 3, 8, seed=21)
    sp = SamplingParams(temperature=0.8, seed=None, max_new=10)

    clean = ServingSupervisor(_factory(cfg, params), log=lambda *_: None)
    for p in prompts:
        clean.submit(p, sp)
    want = clean.run()

    chaotic = ServingSupervisor(
        _factory(cfg, params),
        injector=FaultInjector().at(4, "crash", "mid-decode device loss"),
        log=lambda *_: None)
    for p in prompts:
        chaotic.submit(p, sp)   # same rng stream -> same pinned seeds
    got = chaotic.run()

    assert chaotic.restarts == 1
    assert set(got) == set(want)
    for rid in want:
        assert got[rid].tokens == want[rid].tokens
        assert got[rid].finish_reason == want[rid].finish_reason


def test_supervisor_exhaust_and_restore_pool_mid_run():
    """Injected pool exhaustion mid-run: victims are preempted, the pool
    comes back, everything completes with typed reasons."""
    cfg, params = _model()
    sup = ServingSupervisor(
        _factory(cfg, params, num_blocks=17, preemption=True),
        injector=FaultInjector().at(3, "exhaust_pool").at(8,
                                                          "restore_pool"),
        log=lambda *_: None)
    for p in _prompts(cfg, 2, 9, seed=5):
        sup.submit(p, SamplingParams(max_new=20))
    results = sup.run()
    assert sup.engine.stats["preemptions"] >= 1
    assert all(r.finish_reason == FINISHED_LENGTH
               for r in results.values())


def test_supervisor_flags_injected_straggler_tick():
    cfg, params = _model()
    inj = FaultInjector().at(14, "slow_tick", 0.25)
    sup = ServingSupervisor(_factory(cfg, params), injector=inj,
                            straggler_window=16, straggler_z=4.0,
                            log=lambda *_: None)
    for p in _prompts(cfg, 2, 6, seed=2):
        sup.submit(p, SamplingParams(max_new=24))
    sup.run()
    assert any(tick == 14 for tick, _ in sup.detector.flagged)
    assert (14, ("slow_tick", 0.25)) in inj.fired


def test_supervisor_gives_up_after_max_restarts():
    cfg, params = _model()
    inj = FaultInjector()
    for t in range(0, 40, 2):
        inj.at(t, "crash")
    sup = ServingSupervisor(_factory(cfg, params), injector=inj,
                            max_restarts=2, log=lambda *_: None)
    sup.submit(_prompts(cfg, 1, 6)[0], SamplingParams(max_new=8))
    with pytest.raises(Exception, match="crash"):
        sup.run()
    assert sup.restarts == 3
