"""Fully-integer inference tests (DESIGN.md §16).

Covers the ``.in`` activation-site machinery end to end:
  * site plumbing — ``QuantConfig(quantize_inputs=True)`` creates per-tensor
    ``.in`` gates/probes/ranges, calibrate-mode forwards record their
    ranges, train-mode forwards fake-quantize through them;
  * the BOP certificate — ``activation_gate`` resolves ``.in`` before
    ``.a``, dropping an input gate's width drops ``model_bop``, and
    weight-only states reproduce the historical numbers exactly;
  * the integer GEMM — ``quant_matmul_qt`` with an ``ActQuantSpec`` equals
    ``fake_quant(x) @ dequant(qt)`` within fp32 epilogue rounding (2e-5),
    the Pallas int kernels match the int32-accumulating oracle BITWISE when
    the affine epilogue is the identity, and the shared tile unpack equals
    ``quant.pack.unpack_codes``;
  * serving — decode logits of the int8×int8 path match the
    int-weight × fp32-act oracle on every arch and both KV layouts, and the
    engine's ``act_bits=`` knob serves integer end to end at one host sync
    per tick with full ledger coverage.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: deterministic replay
    from _hyp_fallback import given, settings
    from _hyp_fallback import strategies as st

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core import bop as bop_lib
from repro.core.calibration import calibrate_activations
from repro.core.quantizer import fake_quant, quantize_to_int
from repro.core.sites import (QuantConfig, QuantContext, collect_sites,
                              init_gates, init_probes,
                              init_ranges_from_weights)
from repro.kernels.quant_matmul.layout import unpack_tile
from repro.kernels.quant_matmul.ops import quant_matmul_qt
from repro.kernels.quant_matmul.quant_matmul import (int_matmul_packed_pallas,
                                                     int_matmul_pallas)
from repro.kernels.quant_matmul.ref import int_matmul_ref
from repro.models import transformer as tfm
from repro.quant import ActQuantSpec, QuantizedTensor, specs_from_state
from repro.quant.export import export_act_sites
from repro.quant.pack import pack_codes, unpack_codes
from repro.serving import (SamplingParams, ServingEngine, export_int_model,
                           make_act_specs, make_uniform_quant_state)
from repro.serving import kv_pool

ARCH = "tinyllama-1.1b"

# Decode-logits gap vs the int-weight × fp32-act oracle (max |Δlogit| over a
# prefill + 3 greedy decode steps, measured per arch, both layouts
# identical). The gap is requantization error on every GEMM input — ~1e-2
# relative on random smoke weights — NOT accumulator error (that path is
# tested bitwise below). recurrentgemma's RG-LRU recurrence compounds the
# per-step perturbation a little harder than attention archs.
DECODE_ATOL = 0.1
DECODE_ATOL_ARCH = {"recurrentgemma-2b": 0.25}


def _model(arch=ARCH, seed=0):
    cfg = get_smoke_config(arch)
    return cfg, tfm.init_params(cfg, jax.random.PRNGKey(seed))


def _in_cfg():
    return QuantConfig(quantize_inputs=True)


# ---------------------------------------------------------------------------
# ``.in`` site plumbing: creation, calibration, train-mode fake quant
# ---------------------------------------------------------------------------


def test_in_sites_created_and_calibrated():
    cfg, params = _model()
    qcfg = _in_cfg()
    toks = jnp.zeros((1, 8), jnp.int32)
    sites = collect_sites(
        lambda qc, x: tfm.forward_train(qc, params, x, cfg), toks, cfg=qcfg)

    gates = init_gates(sites, qcfg)
    probes = init_probes(sites, qcfg)
    ranges = init_ranges_from_weights(sites, qcfg, lambda name: None)
    in_keys = sorted(k for k in gates if k.endswith(".in"))
    assert in_keys, "quantize_inputs=True must create .in gates"
    for key in in_keys:
        site = sites[key[: -len(".in")]]
        assert site.act_quantized  # fp-output sites carry no .in gate
        # per-tensor by contract: scalar, or (stack,) for scanned layers
        expected = (site.stack,) if site.stack > 1 else ()
        assert gates[key].shape == expected
        assert probes[key].shape == expected
        assert ranges[key]["beta"].shape == expected
        assert ranges[key]["signed"] is True
    # the default config creates none of this (exact pytree compatibility)
    assert not any(k.endswith(".in") for k in init_gates(sites, QuantConfig()))

    # calibrate-mode forward records per-tensor ranges for every .in site
    act_ranges = calibrate_activations(
        lambda qc, x: tfm.forward_train(qc, params, x, cfg), [toks], qcfg)
    for key in in_keys:
        assert key in act_ranges
        assert float(np.asarray(act_ranges[key]["beta"]).min()) > 0.0

    # train-mode forward fake-quantizes through the .in gates and taps stats
    qc = QuantContext(mode="train", cfg=qcfg, gates=gates, ranges=ranges,
                      probes=probes)
    tfm.forward_train(qc, params, toks, cfg)
    for key in in_keys:
        assert "mean_abs" in qc.act_stats[key]


# ---------------------------------------------------------------------------
# BOP certificate: true w_bits x a_bits x MACs
# ---------------------------------------------------------------------------


def test_bop_certificate_covers_activation_sites():
    cfg, params = _model()
    qcfg = _in_cfg()
    toks = jnp.zeros((1, 8), jnp.int32)
    sites = collect_sites(
        lambda qc, x: tfm.forward_train(qc, params, x, cfg), toks, cfg=qcfg)
    gates = init_gates(sites, qcfg, init=2.5)          # everything 8-bit

    # resolution order: .in wins over .a; .a is the fallback; else fp32
    name = next(s.name for s in sites.values() if s.act_quantized)
    ag = bop_lib.activation_gate(gates, name)
    assert ag is gates[name + ".in"]
    no_in = {k: v for k, v in gates.items() if not k.endswith(".in")}
    assert bop_lib.activation_gate(no_in, name) is gates[name + ".a"]
    assert bop_lib.activation_gate({}, name) is None

    # halving every GEMM-input width halves the certified BOPs — the .a
    # output gates stay untouched, so the drop can only come from .in
    b8 = float(bop_lib.model_bop(sites, gates))
    g4 = {k: (jnp.full_like(v, 1.5) if k.endswith(".in") else v)
          for k, v in gates.items()}
    b4 = float(bop_lib.model_bop(sites, g4))
    assert b4 == pytest.approx(b8 / 2.0, rel=1e-6)


def test_weight_only_bop_unchanged_without_in_gates():
    """No ``.in`` keys -> model_bop is exactly the historical .w/.a sum."""
    cfg, params = _model()
    toks = jnp.zeros((1, 8), jnp.int32)
    sites = collect_sites(
        lambda qc, x: tfm.forward_train(qc, params, x, cfg), toks,
        cfg=QuantConfig())
    gates = init_gates(sites, QuantConfig(), init=2.3)
    legacy = sum(
        float(bop_lib.site_bop(s, gates.get(s.name + ".w"),
                               gates.get(s.name + ".a")))
        for s in sites.values())
    assert float(bop_lib.model_bop(sites, gates)) == pytest.approx(
        legacy, rel=0, abs=0)


# ---------------------------------------------------------------------------
# ActQuantSpec grid properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       beta=st.floats(min_value=0.05, max_value=20.0),
       signed=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**16))
def test_act_quantize_dequantize_idempotent(bits, beta, signed, seed):
    """Requantizing a dequantized activation reproduces the codes bitwise,
    and the spec's affine/zero-point views agree with the stored grid."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=beta, size=(5, 33)), jnp.float32)
    spec = ActQuantSpec(bits=bits, beta=jnp.asarray(beta, jnp.float32),
                        signed=signed)

    codes, scale, bias = quantize_to_int(x, spec.bits, spec.beta, spec.signed)
    deq = codes.astype(jnp.float32) * scale + bias
    codes2, scale2, bias2 = quantize_to_int(deq, spec.bits, spec.beta,
                                            spec.signed)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))

    s, b = spec.affine()
    assert float(s) == float(scale) and float(b) == float(bias)
    # x ~ scale * (codes - zero_point), by definition of the zero point
    z = spec.zero_point()
    np.testing.assert_allclose(
        np.asarray(s * (codes.astype(jnp.float32) - z)), np.asarray(deq),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Integer GEMM vs the fake-quant oracle
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(m=st.integers(min_value=1, max_value=5),
       k=st.integers(min_value=3, max_value=70),
       n=st.integers(min_value=1, max_value=40),
       storage=st.sampled_from([2, 4, 8]),
       act_bits=st.sampled_from([4, 8]),
       act_signed=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**16))
def test_int_path_equals_fake_quant_oracle(m, k, n, storage, act_bits,
                                           act_signed, seed):
    """quant_matmul_qt(x, qt, act_spec) == fake_quant(x) @ dequant(qt) up to
    fp32 epilogue rounding — ragged K, packed sub-byte weights, signed and
    unsigned activation grids."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(scale=0.2, size=(k, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    if not act_signed:
        x = jnp.abs(x)
    qt = QuantizedTensor.from_float(
        w, storage, jnp.max(jnp.abs(w), axis=0), True, storage_bits=storage)
    beta = jnp.maximum(jnp.max(jnp.abs(x)), 1e-3)
    spec = ActQuantSpec(bits=act_bits, beta=beta, signed=act_signed)

    y = quant_matmul_qt(x, qt, act_spec=spec, use_pallas=False)
    oracle = fake_quant(x, jnp.asarray(float(act_bits)), beta,
                        act_signed) @ qt.dequantize()
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(min_value=1, max_value=4),
       k=st.integers(min_value=5, max_value=90),
       n=st.integers(min_value=1, max_value=20),
       bits=st.sampled_from([2, 4, 8]),
       seed=st.integers(min_value=0, max_value=2**16))
def test_int_kernel_accumulator_bitwise_vs_oracle(m, k, n, bits, seed):
    """With an identity epilogue (eff_scale=1, eff_bias=0, const=0) the
    Pallas kernels ARE the int32 matmul — bitwise, both storage classes.
    (int8 x int8 over K <= 90 keeps |acc| < 2^24, exactly held by fp32.)"""
    rng = np.random.default_rng(seed)
    qx = jnp.asarray(rng.integers(-128, 128, size=(m, k)), jnp.int8)
    ones, zeros = jnp.ones((n,), jnp.float32), jnp.zeros((n,), jnp.float32)
    rowsum = jnp.asarray(rng.normal(size=(m,)), jnp.float32)  # must not leak

    lo = -(1 << (bits - 1))
    codes = jnp.asarray(rng.integers(lo, -lo, size=(k, n)), jnp.int8)
    acc = np.asarray(jax.lax.dot(qx.astype(jnp.int32), codes.astype(jnp.int32),
                                 preferred_element_type=jnp.int32),
                     np.float32)
    ref = np.asarray(int_matmul_ref(qx, codes, ones, zeros, rowsum, zeros))
    np.testing.assert_array_equal(ref, acc)

    if bits == 8:
        y = int_matmul_pallas(qx, codes, ones, zeros, rowsum, zeros,
                              interpret=True)
    else:
        y = int_matmul_packed_pallas(qx, pack_codes(codes, bits), ones, zeros,
                                     rowsum, zeros, bits=bits, k=k,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(y), acc)


@settings(max_examples=40, deadline=None)
@given(bits=st.sampled_from([2, 4]),
       k=st.integers(min_value=1, max_value=70),
       n=st.integers(min_value=1, max_value=24),
       seed=st.integers(min_value=0, max_value=2**16))
def test_unpack_tile_matches_unpack_codes(bits, k, n, seed):
    """The kernels' repeat+shift tile decode == quant.pack.unpack_codes
    (ragged K: rows past K are pack padding and are dropped)."""
    rng = np.random.default_rng(seed)
    lo = -(1 << (bits - 1))
    codes = jnp.asarray(rng.integers(lo, -lo, size=(k, n)), jnp.int8)
    packed = pack_codes(codes, bits)
    tile = unpack_tile(packed.astype(jnp.int32), bits)[:k]
    np.testing.assert_array_equal(np.asarray(tile),
                                  np.asarray(unpack_codes(packed, bits, k)))
    np.testing.assert_array_equal(np.asarray(tile), np.asarray(codes))


# ---------------------------------------------------------------------------
# Activation export ledger
# ---------------------------------------------------------------------------


def test_act_export_ledger_flags_fallbacks():
    cfg, params = _model()
    qs = make_uniform_quant_state(cfg, params)
    _, ledger = export_int_model(params, cfg, qs)
    act = make_act_specs(cfg, params, 8)

    # full calibration: every site served integer, nothing hidden
    entries = export_act_sites(act, ledger.sites)
    assert set(entries) == {name + ".in" for name in ledger.sites}
    assert all(e.served == "int" for e in entries.values())
    for e in entries.values():
        assert e.bits == 8
        assert e.scale is not None and e.zero_point is not None

    # a site without a spec must surface as a fake-quant fallback + warning
    victim = next(name + ".in" for name, s in ledger.sites.items()
                  if s.act_quantized)
    partial = {k: v for k, v in act.items() if k != victim}
    with pytest.warns(UserWarning, match="float GEMM inputs"):
        entries = export_act_sites(partial, ledger.sites)
    assert entries[victim].served == "fake_quant"
    assert entries[victim].reason == "no_act_spec"

    # fp-output sites with no spec are excluded by design, not fallbacks
    fp_sites = [name for name, s in ledger.sites.items()
                if not s.act_quantized]
    if fp_sites:
        entries = export_act_sites(
            {k: v for k, v in act.items()
             if k[: -len(".in")] not in fp_sites}, ledger.sites, warn=False)
        assert entries[fp_sites[0] + ".in"].served == "excluded"


# ---------------------------------------------------------------------------
# Serving: integer decode vs the int-weight x fp32-act oracle
# ---------------------------------------------------------------------------

_BS, _MAXSEQ = 8, 32
_PLEN = 8  # SSD chunked prefill needs plen % chunk_size == 0


def _mrope(cfg, s):
    if cfg.mrope_sections is None:
        return None
    return jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))


def _decode_rows(cfg, params, qc, layout, steps=3):
    """Last prefill logits row + ``steps`` greedy-free decode rows."""
    k = jax.random.PRNGKey(1)
    if cfg.embed_input:
        x = jax.random.randint(k, (1, _PLEN), 0, cfg.vocab_size)
    else:
        x = jax.random.normal(k, (1, _PLEN, cfg.d_model), jnp.float32) * 0.3
    if layout == "ring":
        cache, alloc = tfm.init_cache(cfg, 1, _MAXSEQ), None
    else:
        mb = _MAXSEQ // _BS
        cache = tfm.init_paged_cache(cfg, 1, mb + 1, _BS)
        alloc = kv_pool.init_alloc(mb + 1, 1, mb)
        alloc = kv_pool.alloc_range(alloc, 0, 0, -(-_PLEN // _BS))
    table = None if alloc is None else alloc["table"]
    lg, cache = tfm.prefill_slot(qc, params, x, _PLEN, cache, 0, cfg,
                                 mrope_pos=_mrope(cfg, _PLEN),
                                 block_table=table)
    rows = [np.asarray(lg[0, _PLEN - 1, : cfg.vocab_size])]
    adv = jnp.ones((1,), jnp.int32)
    rng = np.random.default_rng(2)
    for t in range(steps):
        if cfg.embed_input:
            tok = jnp.asarray([int(rng.integers(0, cfg.vocab_size))],
                              jnp.int32)
        else:
            tok = jax.random.normal(jax.random.PRNGKey(10 + t),
                                    (1, 1, cfg.d_model), jnp.float32) * 0.3
        if alloc is not None:
            alloc = kv_pool.tick_alloc(alloc, cache["pos"], adv, _BS)
        lg, cache = tfm.decode_step(
            qc, params, cache, tok, cfg, advance=adv,
            block_table=None if alloc is None else alloc["table"])
        rows.append(np.asarray(lg[0, 0, : cfg.vocab_size]))
    return np.stack(rows)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_logits_match_oracle_all_archs_both_layouts(arch):
    cfg, params = _model(arch)
    qs = make_uniform_quant_state(cfg, params)
    qw, _ = export_int_model(params, cfg, qs)
    specs = specs_from_state(qs["gates"], qs["betas"], qs["signed"])
    act = make_act_specs(cfg, params, 8)
    assert act, "every arch must calibrate at least one .in site"
    qc_int = QuantContext(mode="serve", cfg=qs["qcfg"],
                          specs={**specs, **act}, qweights=qw,
                          matmul_impl="ref")
    qc_oracle = QuantContext(mode="serve", cfg=qs["qcfg"], specs=specs,
                             qweights=qw, matmul_impl="ref")
    kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
    layouts = ["ring"] + (
        ["paged"] if any(kk in ("global", "local") for kk in kinds) else [])
    atol = DECODE_ATOL_ARCH.get(arch, DECODE_ATOL)
    for layout in layouts:
        got = _decode_rows(cfg, params, qc_int, layout)
        want = _decode_rows(cfg, params, qc_oracle, layout)
        np.testing.assert_allclose(got, want, atol=atol,
                                   err_msg=f"{arch}/{layout}")


def test_engine_act_bits_serves_integer_end_to_end():
    cfg, params = _model()
    qs = make_uniform_quant_state(cfg, params)
    prompts = [np.arange(1, 5), np.arange(2, 9), np.arange(3, 7)]

    eng = ServingEngine(cfg, params, slots=2, max_seq=32, quant_state=qs,
                        act_bits=8)
    res = eng.generate(prompts, SamplingParams(max_new=6))
    assert [len(r.tokens) for r in res] == [6, 6, 6]
    assert eng.stats["tick_syncs"] == eng.stats["decode_ticks"]

    rep = eng.quant_report()
    acts = rep["acts"]
    assert acts["total"] > 0
    assert acts["covered"] == acts["total"]
    assert acts["fallback_sites"] == []
    assert set(acts["bits"].values()) == {8}
    # the certificate now prices activations at their SERVED width: uniform
    # 8-bit weights x 8-bit inputs == the uniform-int8 BOP baseline exactly
    assert rep["bops"]["model"] == pytest.approx(rep["bops"]["uniform_int8"])

    eng4 = ServingEngine(cfg, params, slots=2, max_seq=32, quant_state=qs,
                         act_bits=4)
    rep4 = eng4.quant_report()
    assert set(rep4["acts"]["bits"].values()) == {4}
    assert rep4["bops"]["model"] == pytest.approx(
        rep["bops"]["model"] / 2.0, rel=1e-6)
    assert len(eng4.generate(prompts, SamplingParams(max_new=4))) == \
        len(prompts)

    with pytest.raises(ValueError, match="act_bits"):
        ServingEngine(cfg, params, act_bits=8)
