"""End-to-end CGMQ controller tests on a tiny quantized MLP.

The critical paper claim (§3): training with any valid direction reaches a
model satisfying the BOP constraint, without hyperparameter tuning. We verify
it as a property over all four directions and both granularities.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bop as bop_lib
from repro.core import controller as ctrl
from repro.core.directions import DIRECTIONS, build_stats, check_direction_properties, compute_directions
from repro.core.sites import (
    PER_TENSOR,
    PER_WEIGHT,
    QuantConfig,
    QuantContext,
    collect_sites,
    init_gates,
    init_probes,
    init_ranges_from_weights,
    merge_ranges,
    split_learnable_ranges,
)

D_IN, D_H, D_OUT = 8, 16, 4


def mlp_forward(qc: QuantContext, params, x):
    x = qc.input(x)
    w1q = qc.weight("fc1", params["w1"])
    qc.register_matmul("fc1", params["w1"].shape, fan_in=D_IN, out_features=D_H)
    h = jax.nn.relu(x @ w1q + params["b1"])
    h = qc.act("fc1", h)
    w2q = qc.weight("fc2", params["w2"])
    qc.register_matmul("fc2", params["w2"].shape, fan_in=D_H, out_features=D_OUT,
                       act_quantized=False)  # fp head (paper §4.2)
    return h @ w2q + params["b2"]


def _init(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    params = {
        "w1": jax.random.normal(k1, (D_IN, D_H)) * 0.4,
        "b1": jnp.zeros((D_H,)),
        "w2": jax.random.normal(k2, (D_H, D_OUT)) * 0.4,
        "b2": jnp.zeros((D_OUT,)),
    }
    return params


def _setup(granularity, seed=0):
    params = _init(seed)
    cfg = QuantConfig(granularity=granularity)
    sites = collect_sites(
        lambda qc, p, x: mlp_forward(qc, p, x),
        params,
        jax.ShapeDtypeStruct((32, D_IN), jnp.float32),
        cfg=cfg,
    )
    gates = init_gates(sites, cfg)
    probes = init_probes(sites, cfg)
    ranges = init_ranges_from_weights(sites, cfg, lambda n: params["w1"] if n == "fc1" else params["w2"])
    return params, cfg, sites, gates, probes, ranges


def test_collect_sites_metadata():
    _, _, sites, gates, probes, _ = _setup(PER_TENSOR)
    assert set(sites) == {"fc1", "fc2"}
    assert sites["fc1"].macs_per_token == D_IN * D_H
    assert not sites["fc2"].act_quantized
    assert set(gates) == {"fc1.w", "fc1.a", "fc2.w"}
    assert set(probes) == {"fc1.a"}  # act probes only via init_probes
    assert gates["fc1.w"].shape == ()


def test_per_weight_gate_shapes():
    _, _, sites, gates, _, _ = _setup(PER_WEIGHT)
    assert gates["fc1.w"].shape == (D_IN, D_H)
    assert gates["fc1.a"].shape == (D_H,)  # act gates per-channel


def _loss_and_stats(params, probes, gates, betas, signed, cfg, batch):
    x, y = batch
    qc = QuantContext(
        mode="train", cfg=cfg, gates=gates,
        ranges=merge_ranges(betas, signed), probes=probes,
    )
    logits = mlp_forward(qc, params, x)
    loss = jnp.mean((logits - y) ** 2)
    return loss, (qc.act_stats, qc.weight_stats)


def _run_cgmq(direction, granularity, budget_rbop=0.02, steps=400, seed=0,
              gate_lr=0.01):
    params, cfg, sites, gates, probes, ranges = _setup(granularity, seed)
    # add weight probes too (gradient taps for dir computation)
    for s in sites.values():
        key = s.name + ".w"
        probes[key] = jnp.zeros_like(jnp.asarray(gates[key], jnp.float32))
    betas, signed = split_learnable_ranges(ranges)
    ccfg = ctrl.CGMQConfig(
        budget_rbop=budget_rbop, direction=direction,
        gate_lr=gate_lr, check_every=10,
    )
    budget = bop_lib.budget_from_rbop(sites, budget_rbop)
    state = ctrl.init_state(gates, sites)

    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(32, D_IN)).astype(np.float32))
    ys = jnp.asarray(rng.normal(size=(32, D_OUT)).astype(np.float32))

    @jax.jit
    def step(params, betas, state):
        grad_fn = jax.value_and_grad(_loss_and_stats, argnums=(0, 1, 3), has_aux=True)
        (loss, (astats, wstats)), (gp, gprobe, gbeta) = grad_fn(
            params, probes, state.gates, betas, signed, cfg, (xs, ys)
        )
        params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, gp)
        betas = jax.tree.map(lambda b, g: b - 1e-3 * g, betas, gbeta)
        state = ctrl.controller_update(
            state, ccfg, sites, gprobe, wstats, astats, budget
        )
        return params, betas, state, loss

    for _ in range(steps):
        params, betas, state, loss = step(params, betas, state)
    return state, sites, budget, float(loss)


@pytest.mark.parametrize("direction", DIRECTIONS)
def test_constraint_guarantee(direction):
    """Paper §3: the final model satisfies B_BOP for every direction.

    The guarantee is learning-rate independent (dir stays strictly positive
    while Unsat); bounded directions move gates slowly, so they get a longer
    horizon — the paper itself trains for 250 epochs.
    """
    # dir2 normalizes by magnitude stats and moves more slowly than dir1 at
    # this toy scale: 400 steps leaves it just above the bound, 800 certifies
    steps = {"dir1": 400, "dir2": 800, "dir3": 6000, "dir4": 2500}[direction]
    state, sites, budget, _ = _run_cgmq(direction, PER_TENSOR, steps=steps)
    assert ctrl.guarantee_satisfied(state, sites, budget)


def test_constraint_guarantee_per_weight():
    state, sites, budget, _ = _run_cgmq("dir1", PER_WEIGHT)
    assert ctrl.guarantee_satisfied(state, sites, budget)


def test_gates_recover_when_satisfied():
    """With a generous budget, gates should grow back toward 32-bit."""
    state, sites, budget, _ = _run_cgmq("dir1", PER_TENSOR, budget_rbop=1.0, steps=100)
    bits = ctrl.export_bits(state)
    # budget is satisfiable at init, so gates should stay at/climb to 32.
    assert all(int(np.min(b)) >= 16 for b in bits.values())


def test_direction_sign_properties():
    """Property (i)/(ii) of §2.3 for every direction kind."""
    gates = {"l.w": jnp.asarray(2.0), "l.a": jnp.asarray(3.0)}
    pg = {"l.w": jnp.asarray(0.3), "l.a": jnp.asarray(-0.2)}
    ws = {"l.w": jnp.asarray(0.5)}
    ast = {"l.a": {"mean_abs": jnp.asarray(0.8)}}
    gs, ms = build_stats(gates, pg, ws, ast)
    for kind in DIRECTIONS:
        for sat in (False, True):
            dirs = compute_directions(kind, jnp.asarray(sat), gates, gs, ms)
            assert check_direction_properties(dirs, sat), (kind, sat)


def test_sat_flag_lags_by_window():
    """The Sat flag only updates on check boundaries (paper: end of epoch)."""
    params, cfg, sites, gates, probes, ranges = _setup(PER_TENSOR)
    ccfg = ctrl.CGMQConfig(budget_rbop=1.0, check_every=5)
    budget = bop_lib.budget_from_rbop(sites, 1.0)
    state = ctrl.init_state(gates, sites)
    assert not bool(state.sat)
    zeros_pg = {k: jnp.zeros_like(jnp.asarray(v, jnp.float32)) for k, v in gates.items()}
    ws = {k: jnp.asarray(1.0) for k in gates if k.endswith(".w")}
    ast = {k: {"mean_abs": jnp.asarray(1.0)} for k in gates if k.endswith(".a")}
    for i in range(1, 5):
        state = ctrl.controller_update(state, ccfg, sites, zeros_pg, ws, ast, budget)
        if i < 5:
            assert not bool(state.sat)  # not yet checked
    state = ctrl.controller_update(state, ccfg, sites, zeros_pg, ws, ast, budget)
    assert bool(state.sat)  # budget 100% is trivially satisfied at check


def test_gates_strictly_decrease_while_unsat():
    """While Unsat, every gate strictly decreases (the §3 guarantee engine)."""
    from repro.core.gates import GATE_INIT

    state, sites, budget, _ = _run_cgmq("dir2", PER_TENSOR, budget_rbop=0.02, steps=50)
    assert not bool(state.best_valid) or float(state.bop) <= budget
    for k, g in state.gates.items():
        assert float(np.max(np.asarray(g))) < GATE_INIT, k
