"""Substrate tests: checkpointing, fault tolerance, compression, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_smoke_config
from repro.distributed.fault_tolerance import (
    StragglerDetector,
    SupervisorConfig,
    TrainSupervisor,
)
from repro.models import transformer as tfm
from repro.serving.engine import Request, ServingEngine, export_int_codes


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (32, 16)),
        "nested": {"b": jnp.arange(7, dtype=jnp.float32),
                   "step": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(10, state, blocking=True, extra={"note": "hi"})
    restored, step, extra = ck.restore(jax.eval_shape(lambda: state))
    assert step == 10 and extra["note"] == "hi"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(), blocking=True)
    root = os.path.join(str(tmp_path), "step_0000000005")
    victim = [f for f in os.listdir(root) if f.endswith(".npy")][0]
    with open(os.path.join(root, victim), "r+b") as f:
        f.seek(10)
        f.write(b"\xff\xff\xff")
    with pytest.raises(IOError):
        ck.restore(jax.eval_shape(_state))


def test_checkpoint_restores_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), blocking=True)
    ck.save(7, _state(7), blocking=True)
    _, step, _ = ck.restore(jax.eval_shape(lambda: _state(7)))
    assert step == 7


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def _toy_step():
    @jax.jit
    def step(state, batch):
        w = state["w"] - 0.1 * batch["g"]
        return {"w": w, "count": state["count"] + 1}, {"norm": jnp.sum(w**2)}

    return step


def _batches(n):
    def get(step):
        if step >= n:
            return None
        return {"g": jnp.full((4,), float(step % 3) - 1.0)}

    return get


def test_supervisor_runs_to_completion(tmp_path):
    sup = TrainSupervisor(SupervisorConfig(str(tmp_path), checkpoint_every=4),
                          log=lambda s: None)
    state = {"w": jnp.zeros((4,)), "count": jnp.asarray(0)}
    state, step, status = sup.run(state, _toy_step(), _batches(10))
    assert status == "done" and step == 10
    assert int(state["count"]) == 10


def test_supervisor_recovers_from_injected_failure(tmp_path):
    sup = TrainSupervisor(SupervisorConfig(str(tmp_path), checkpoint_every=3),
                          log=lambda s: None)
    sup.inject_failure_at = 7
    state = {"w": jnp.zeros((4,)), "count": jnp.asarray(0)}
    state, step, status = sup.run(state, _toy_step(), _batches(12))
    assert status == "done" and step == 12
    assert sup.restarts == 1
    # deterministic replay: same result as a clean run
    clean = {"w": jnp.zeros((4,)), "count": jnp.asarray(0)}
    fn = _toy_step()
    for i in range(12):
        clean, _ = fn(clean, _batches(12)(i))
    np.testing.assert_allclose(np.asarray(state["w"]), np.asarray(clean["w"]),
                               rtol=1e-6)


def test_supervisor_preemption_checkpoints(tmp_path):
    sup = TrainSupervisor(SupervisorConfig(str(tmp_path), checkpoint_every=100),
                          log=lambda s: None)
    state = {"w": jnp.zeros((4,)), "count": jnp.asarray(0)}
    calls = {"n": 0}

    def batches(step):
        calls["n"] += 1
        if calls["n"] == 5:
            sup.preempt()
        return {"g": jnp.ones((4,))}

    state, step, status = sup.run(state, _toy_step(), batches)
    assert status == "preempted"
    # a checkpoint exists at the preemption step
    assert sup.ckpt.latest_step() == step


def test_straggler_detector():
    det = StragglerDetector(window=16, z=4.0)
    flagged = []
    for i in range(40):
        dt = 0.1 if i != 30 else 1.0  # one 10x step
        if det.observe(i, dt):
            flagged.append(i)
    assert flagged == [30]


# ---------------------------------------------------------------------------
# Gradient compression (int8 EF over a pod axis)
# ---------------------------------------------------------------------------


def test_compressed_psum_exact_mean_and_error_feedback():
    # runs on 1 device: psum over a size-1 'pod' axis via shard_map on a
    # trivial mesh still exercises quantize/dequant + EF bookkeeping
    from repro.launch.mesh import make_test_mesh
    from repro.optim.compression import init_residuals, make_compressed_pod_psum

    mesh = make_test_mesh((1,), ("pod",))
    f = make_compressed_pod_psum(mesh)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(40, 30)),
                          jnp.float32)}
    r = init_residuals(g)
    out, r1 = f(g, r)
    # single pod: mean == dequant(quant(g)); error = residual
    err = g["w"] - out["w"]
    np.testing.assert_allclose(np.asarray(err), np.asarray(r1["w"]), atol=1e-6)
    assert float(jnp.abs(r1["w"]).max()) < float(jnp.abs(g["w"]).max()) / 64
    # error feedback: applying again re-injects the residual
    out2, r2 = f(g, r1)
    total_seen = out["w"] + out2["w"] + r2["w"]
    np.testing.assert_allclose(np.asarray(total_seen), np.asarray(2 * g["w"]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------


def test_export_int_codes_bits():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(16, 8)), jnp.float32)
    q = export_int_codes(w, gate=jnp.asarray(2.5), beta=jnp.max(jnp.abs(w)),
                         signed=True)
    assert q.storage_bits == 8
    deq = q.dequantize()
    assert float(jnp.abs(deq - w).max()) < float(jnp.abs(w).max()) / 50
    # sub-byte gate -> packed storage, still the same dequant contract
    q2 = export_int_codes(w, gate=jnp.asarray(0.8), beta=jnp.max(jnp.abs(w)),
                          signed=True)
    assert q2.storage_bits == 2 and q2.packed
    assert q2.codes_bytes() == q.codes_bytes() // 4
    assert float(jnp.abs(q2.dequantize() - w).max()) <= float(
        jnp.abs(w).max())


def test_serving_engine_continuous_batching():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, (5,)),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_to_completion()
    assert len(finished) == 5
    for r in finished:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_serving_greedy_matches_manual_decode():
    """Engine output for a single request == manual greedy decode."""
    from repro.core.sites import QuantContext

    cfg = get_smoke_config("tinyllama-1.1b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    prompt = np.asarray([1, 2, 3], np.int32)

    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=3))
    out_engine = eng.run_to_completion()[0].output

    cache = tfm.init_cache(cfg, 1, 32)
    qc = QuantContext(mode="off")
    tok = None
    outs = []
    for t in prompt:
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([t], jnp.int32), cfg)
        tok = int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))
    outs.append(tok)
    for _ in range(2):
        logits, cache = tfm.decode_step(qc, params, cache,
                                        jnp.asarray([tok], jnp.int32), cfg)
        tok = int(jnp.argmax(logits[0, 0, : cfg.vocab_size]))
        outs.append(tok)
    assert out_engine == outs
