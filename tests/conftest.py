"""Shared pytest configuration.

One tier-1 process compiles thousands of distinct XLA programs — every
``ServingEngine``/train-engine instance jits its own closures over its
own weights. On CPU jaxlib the retained compiler/executable state from
hundreds of engines can crash ``backend_compile`` late in a long run;
dropping JAX's in-process caches between test modules bounds that state
without changing any individual test (each module recompiles what it
actually uses).
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
