"""Long-context serving tests (DESIGN.md §17).

Four layers of coverage over the sliding-window / sink-block subsystem:
  * identity gate — a NON-binding window (wider than anything attended) is
    bit-identical to ``window=None`` on every arch, both KV layouts, all
    KV dtypes: threading the window through the stack perturbs nothing;
  * oracle gate — a windowed engine's greedy stream equals a hand-driven
    transformer-level run with the same (window, sinks) mask: the engine's
    eviction/allocation machinery is invisible to the logits;
  * residency gate — a prompt far longer than the window serves on a pool
    sized for the window (chunked prefill + between-chunk and in-tick
    eviction), bit-identical to an ample pool, with ``blocks_in_use``
    bounded by window demand and zero blocks leaked at the end;
  * admission gate — the §17 watermark fix: projections cap at window
    demand, so long-context requests aren't rejected for length the pool
    never has to hold.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.sites import QuantContext
from repro.models import transformer as tfm
from repro.quant import KVQuantSpec
from repro.serving import (SamplingParams, ServingEngine, WindowSpec,
                           kv_pool)
from repro.serving.admission import AdmissionConfig, projected_blocks
from repro.serving.window import (as_window_spec, first_live_block,
                                  max_live_blocks, window_demand_blocks)

BS = 8
MAX_SEQ = 32


def _model(arch="tinyllama-1.1b", seed=0):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _inputs(cfg, plen, key=1):
    k = jax.random.PRNGKey(key)
    if cfg.embed_input:
        return jax.random.randint(k, (1, plen), 0, cfg.vocab_size)
    return jax.random.normal(k, (1, plen, cfg.d_model), jnp.float32) * 0.3


def _mrope(cfg, s):
    if cfg.mrope_sections is None:
        return None
    return jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, 1, s))


def _kv_spec(cfg, bits):
    return KVQuantSpec(bits=bits, group_size=math.gcd(cfg.head_dim, 32),
                       head_dim=cfg.head_dim)


def _decode_rows(cfg, params, layout, kv_spec, window):
    """Prefill + 4 decode steps with an explicit window mask; per-step
    logit rows as numpy."""
    qc = QuantContext(mode="off")
    # ssd_chunked asserts plen % ssm_chunk == 0 on direct prefill_slot
    # calls (the engine pads via its chunk-aligned-prefix path; tests
    # driving the model layer must align themselves)
    plen = 8 if "ssm" in cfg.layer_kinds() else 9
    x = _inputs(cfg, plen)
    kv_dtype = jnp.float32 if kv_spec is None else jnp.bfloat16
    if layout == "ring":
        cache = tfm.init_cache(cfg, 1, MAX_SEQ, kv_dtype=kv_dtype,
                               kv_spec=kv_spec)
        alloc = None
    else:
        mb = MAX_SEQ // BS
        cache = tfm.init_paged_cache(cfg, 1, mb + 1, BS, kv_dtype=kv_dtype,
                                     kv_spec=kv_spec)
        alloc = kv_pool.init_alloc(mb + 1, 1, mb)
        alloc = kv_pool.alloc_range(alloc, 0, 0, -(-plen // BS))
    table = None if alloc is None else alloc["table"]
    lg, cache = tfm.prefill_slot(qc, params, x, plen, cache, 0, cfg,
                                 mrope_pos=_mrope(cfg, plen),
                                 block_table=table, window=window)
    rows = [np.asarray(lg[0, plen - 1, : cfg.vocab_size])]
    adv = jnp.ones((1,), jnp.int32)
    rng = np.random.default_rng(2)
    for t in range(4):
        if cfg.embed_input:
            tok = jnp.asarray([int(rng.integers(0, cfg.vocab_size))],
                              jnp.int32)
        else:
            tok = jax.random.normal(jax.random.PRNGKey(10 + t),
                                    (1, 1, cfg.d_model), jnp.float32) * 0.3
        if alloc is not None:
            alloc = kv_pool.tick_alloc(alloc, cache["pos"], adv, BS)
        lg, cache = tfm.decode_step(
            qc, params, cache, tok, cfg, advance=adv,
            block_table=None if alloc is None else alloc["table"],
            window=window)
        rows.append(np.asarray(lg[0, 0, : cfg.vocab_size]))
    return rows


# ---------------------------------------------------------------------------
# Identity gate: non-binding window == window=None, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_nonbinding_window_bit_identical_all_archs_layouts_dtypes(arch):
    """The §17 acceptance identity: threading ``window=(W, 0)`` with W
    wider than anything attended must be BIT-identical to ``window=None``
    — per arch, on both KV layouts, for bf16/int8/int4 KV storage. This
    pins the whole-table fallback in the chunk gather, the first-live-block
    clamp in the kernel, and the local-layer ``min(cfg.window, W)``
    composition all at once."""
    cfg, params = _model(arch)
    kinds = list(cfg.block_pattern) + list(cfg.remainder_kinds)
    has_attn = any(k in ("global", "local") for k in kinds)
    wide = (4 * MAX_SEQ, 0)
    # attention-free archs have no KV to page or quantize: the window must
    # simply be inert. Quantized dtypes ride the paged/kernel path, where
    # the first-live walk lives; bf16 additionally covers the ring masks.
    combos = [("ring", None)]
    if has_attn:
        combos += [("paged", None), ("paged", _kv_spec(cfg, 8)),
                   ("paged", _kv_spec(cfg, 4))]
    for layout, spec in combos:
        base = _decode_rows(cfg, params, layout, spec, None)
        wind = _decode_rows(cfg, params, layout, spec, wide)
        for t, (b, w) in enumerate(zip(base, wind)):
            np.testing.assert_array_equal(
                b, w, err_msg=f"{arch} {layout} "
                f"{'f32' if spec is None else spec.bits} step {t}")


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_engine_window_none_vs_nonbinding_stream_identity(kv_layout,
                                                          kv_dtype):
    """Engine-level identity: ``attention_window=None`` and a window as
    wide as ``max_seq`` (so it never binds and nothing is ever evicted)
    emit the same token streams — greedy AND seeded-sampled — on both
    layouts and every KV dtype."""
    cfg, params = _model()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, (12,)) for _ in range(2)]
    sps = [SamplingParams(temperature=0.0, max_new=6),
           SamplingParams(temperature=0.9, top_p=0.9, seed=11, max_new=6)]

    def run(window):
        kd = {} if kv_dtype == "bf16" else {"kv_dtype": kv_dtype}
        eng = ServingEngine(cfg, params, slots=2, max_seq=64,
                            kv_layout=kv_layout, attention_window=window,
                            **kd)
        return [r.tokens for r in eng.generate(prompts, sps)]

    assert run(None) == run(64)


# ---------------------------------------------------------------------------
# Oracle gate: windowed engine == transformer-level windowed greedy run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_windowed_engine_matches_model_level_windowed_oracle(kv_dtype):
    """A binding window WITH sink blocks: the engine's stream (wave
    prefill, in-tick eviction, paged pool) equals a hand-driven
    prefill_slot + argmax decode_step loop under the same (window,
    sink_tokens) mask — the eviction machinery must be invisible."""
    spec = WindowSpec(window=12, sink_blocks=1)
    cfg, params = _model()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, (20,))
    n_new = 6
    kd = {} if kv_dtype == "bf16" else {"kv_dtype": kv_dtype}
    eng = ServingEngine(cfg, params, slots=1, max_seq=64, block_size=BS,
                        attention_window=spec, **kd)
    got = eng.generate([prompt],
                       SamplingParams(temperature=0.0,
                                      max_new=n_new))[0].tokens

    qc = QuantContext(mode="off")
    kv_spec = None if kv_dtype == "bf16" else _kv_spec(cfg, 8)
    kv_store = jnp.bfloat16
    mb = 64 // BS
    cache = tfm.init_paged_cache(cfg, 1, mb + 1, BS, kv_dtype=kv_store,
                                 kv_spec=kv_spec)
    alloc = kv_pool.init_alloc(mb + 1, 1, mb)
    alloc = kv_pool.alloc_range(alloc, 0, 0, -(-len(prompt) // BS))
    wmask = spec.bind(BS).mask
    plen = len(prompt)
    x = jnp.asarray(prompt, jnp.int32)[None, :]
    lg, cache = tfm.prefill_slot(qc, params, x, plen, cache, 0, cfg,
                                 block_table=alloc["table"], window=wmask)
    want = []
    row = np.asarray(lg[0, plen - 1, : cfg.vocab_size])
    adv = jnp.ones((1,), jnp.int32)
    for _ in range(n_new):
        tok = int(row.argmax())
        want.append(tok)
        alloc = kv_pool.tick_alloc(alloc, cache["pos"], adv, BS)
        lg, cache = tfm.decode_step(qc, params, cache,
                                    jnp.asarray([tok], jnp.int32), cfg,
                                    advance=adv, block_table=alloc["table"],
                                    window=wmask)
        row = np.asarray(lg[0, 0, : cfg.vocab_size])
    assert got == want


# ---------------------------------------------------------------------------
# Residency gate: long prompt on a window-sized pool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
def test_long_prompt_serves_on_window_sized_pool(kv_dtype):
    """A prompt ~8x the window decodes on a pool sized for the window:
    chunked prefill evicts between chunks, decode evicts in-tick, the
    stream is bit-identical to an ample pool, residency never exceeds
    window demand, no blocks leak, and the one-host-sync-per-tick ledger
    holds."""
    spec = WindowSpec(window=16, sink_blocks=1)
    cfg, params = _model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (120,))
    sp = SamplingParams(temperature=0.0, max_new=6)
    kd = {} if kv_dtype == "bf16" else {"kv_dtype": kv_dtype}
    demand = window_demand_blocks(spec.bind(BS), 256 // BS, 16, BS)
    small = ServingEngine(cfg, params, slots=1, max_seq=256, block_size=BS,
                          num_blocks=demand + 1, prefill_chunk_tokens=16,
                          attention_window=spec, **kd)
    assert not small.preemption, "window sizing should not need preemption"
    peak = []
    out = small.generate([prompt], sp,
                         on_token=lambda ev: peak.append(
                             small.pool_stats()["blocks_in_use"]))
    ample = ServingEngine(cfg, params, slots=1, max_seq=256, block_size=BS,
                          prefill_chunk_tokens=16, attention_window=spec,
                          **kd)
    assert out[0].tokens == ample.generate([prompt], sp)[0].tokens
    assert max(peak) <= demand, (max(peak), demand)
    assert small.pool_stats()["blocks_in_use"] == 0, "blocks leaked"
    st = small.stats
    assert st["tick_syncs"] == st["decode_ticks"], "extra in-tick syncs"


def test_window_residency_bounded_during_decode_past_window():
    """Decode far past the window on an unchunked engine: in-tick eviction
    keeps ``blocks_in_use`` at the §17 bound (sink + ceil(W/bs) + 1
    straddling block, +1 decode block being filled) even as positions run
    to several windows' length."""
    spec = WindowSpec(window=16, sink_blocks=1)
    cfg, params = _model()
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (10,))
    eng = ServingEngine(cfg, params, slots=1, max_seq=128, block_size=BS,
                        attention_window=spec)
    cap = max_live_blocks(16, 1, BS) + 1
    peak = []
    eng.generate([prompt], SamplingParams(temperature=0.0, max_new=70),
                 on_token=lambda ev: peak.append(
                     eng.pool_stats()["blocks_in_use"]))
    assert max(peak) <= cap, (max(peak), cap)
    assert eng.pool_stats()["blocks_in_use"] == 0


# ---------------------------------------------------------------------------
# Windowed engines compose with preemption and (sink-restricted) sharing
# ---------------------------------------------------------------------------


def test_windowed_prefix_sharing_restricted_to_sink_blocks():
    """§17 sink-block contract: under a windowed engine, prefix sharing
    registers/shares ONLY sink-region blocks (the blocks eviction can
    never recycle), and shared-sink streams still match a solo run."""
    spec = WindowSpec(window=12, sink_blocks=1)
    cfg, params = _model()
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, (20,))
    sp = SamplingParams(temperature=0.0, max_new=5)
    solo = ServingEngine(cfg, params, slots=1, max_seq=64, block_size=BS,
                         attention_window=spec)
    want = solo.generate([prompt], sp)[0].tokens
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, block_size=BS,
                        attention_window=spec)
    a, b = eng.generate([prompt, prompt], [sp, sp])
    assert a.tokens == b.tokens == want
    # only the single sink block is shareable: 1 hit out of 2 full blocks,
    # never a full-prompt shared admission
    assert eng.stats["prefix_hit_blocks"] == 1
    assert eng.stats["shared_admissions"] == 0
    assert eng.pool_stats()["blocks_in_use"] == 0


def test_windowed_engine_preemption_streams_equal_solo():
    """Eviction composes with §13 preemption: an oversubscribed windowed
    pool preempts, resumes, and still reproduces every unpressured solo
    stream."""
    spec = WindowSpec(window=16)
    cfg, params = _model()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (12,)) for _ in range(3)]
    sps = [SamplingParams(temperature=0.0, max_new=16) for _ in range(3)]
    solo = []
    for p, sp in zip(prompts, sps):
        e = ServingEngine(cfg, params, slots=1, max_seq=64, block_size=BS,
                          attention_window=spec)
        solo.append(e.generate([p], [sp])[0].tokens)
    # 9 blocks = the engine floor (one slot's worst case + garbage): three
    # 12+16-token requests want ~12 blocks, so victims must be preempted
    eng = ServingEngine(cfg, params, slots=3, max_seq=64, block_size=BS,
                        num_blocks=9, preemption=True,
                        attention_window=spec)
    outs = eng.generate(prompts, sps)
    for o, s in zip(outs, solo):
        assert o.tokens == s
    assert eng.pool_stats()["blocks_in_use"] == 0


# ---------------------------------------------------------------------------
# Admission: window-capped projections (the §17 watermark fix)
# ---------------------------------------------------------------------------


def test_projected_blocks_caps_at_window_demand():
    assert projected_blocks(1000, 100, 8, 200) == 138
    assert projected_blocks(1000, 100, 8, 200, window_blocks=5) == 5
    assert projected_blocks(10, 2, 8, 200, window_blocks=50) == 2
    assert projected_blocks(1000, 100, 8, 4, window_blocks=50) == 4


def test_watermark_admits_long_context_request_window_demand():
    """The watermark fix end-to-end: a request whose FULL-length projection
    overshoots the watermark is admitted anyway on a windowed engine,
    because eviction bounds its true residency to window demand."""
    spec = WindowSpec(window=16, sink_blocks=1)
    cfg, params = _model()
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab_size, (120,))
    ad = AdmissionConfig(watermark=0.9)
    eng = ServingEngine(cfg, params, slots=1, max_seq=256, block_size=BS,
                        num_blocks=12, prefill_chunk_tokens=16,
                        admission=ad, attention_window=spec)
    # full-length projection (16 blocks) would overshoot 0.9 * 11 usable;
    # window demand (7) fits
    full = projected_blocks(120, 4, BS, eng.max_blocks)
    assert full > 0.9 * (eng.num_blocks - 1)
    assert eng._slot_demand <= 0.9 * (eng.num_blocks - 1)
    out = eng.generate([prompt], SamplingParams(temperature=0.0, max_new=4))
    assert len(out[0].tokens) == 4
    assert eng.stats["rejected_requests"] == 0


# ---------------------------------------------------------------------------
# WindowSpec / helper unit behavior
# ---------------------------------------------------------------------------


def test_window_spec_binding_and_demand():
    spec = as_window_spec(24, 8)
    assert spec.window == 24 and spec.sink_blocks == 0
    assert spec.mask == (24, 0)
    assert as_window_spec(None) is None
    bound = WindowSpec(window=16, sink_blocks=2).bind(8)
    assert bound.sink_tokens == 16
    assert bound.mask == (16, 16)
    # live blocks: sinks + ceil(W/bs) + 1 straddling block, table-capped
    assert bound.live_blocks(100) == 2 + 2 + 1
    assert bound.live_blocks(3) == 3
    # demand: full table when unwindowed or unchunked; live + chunk blocks
    # when eviction can actually run between chunks
    assert window_demand_blocks(None, 40, 16, 8) == 40
    assert window_demand_blocks(bound, 40, None, 8) == 40
    assert window_demand_blocks(bound, 40, 16, 8) == 5 + 3
    with pytest.raises(ValueError):
        WindowSpec(window=0)
    with pytest.raises(ValueError):
        WindowSpec(window=8, sink_blocks=-1)


def test_first_live_block_matches_mask_reachability():
    """first_live_block is exactly the first block holding any key the §17
    mask can still admit (outside sinks) — checked exhaustively over ragged
    pos/window/sink combos."""
    for bs in (4, 8):
        for w in (1, 3, bs, bs + 3, 4 * bs):
            for sb in (0, 1, 2):
                for pos in range(0, 6 * bs):
                    fl = int(first_live_block(pos, w, sb, bs))
                    sinks = sb * bs
                    # first key position the window admits for query at pos
                    lo = max(pos - w + 1, 0)
                    want = max(min(lo // bs, 10 ** 9), sb)
                    assert fl == want or fl == max(lo // bs, sb), \
                        (bs, w, sb, pos, fl)
                    # no admissible non-sink key lives below fl
                    for kp in range(min(fl * bs, pos + 1)):
                        if kp >= sinks:
                            assert not (pos - kp < w) or kp // bs >= fl
