"""BOP cost model tests (paper §2.5): hand-computed counts per granularity."""

import jax.numpy as jnp
import numpy as np

from repro.core import bop
from repro.core.sites import SiteInfo


def _site(fan_in=4, out=3, positions=1, stack=1, frac=1.0, act_q=True, name="l"):
    return SiteInfo(
        name=name,
        weight_shape=(fan_in, out),
        fan_in=fan_in,
        out_features=out,
        positions=positions,
        stack=stack,
        active_frac=frac,
        act_quantized=act_q,
    )


def _gate_for_bits(bits):
    """Inverse of T on the representative interval midpoints."""
    table = {2: 0.5, 4: 1.5, 8: 2.5, 16: 3.5, 32: 5.5}
    return table[bits]


def test_per_tensor_matches_macs_formula():
    """Per-tensor: BOP = MACs * b_w * b_a."""
    s = _site(fan_in=4, out=3)
    g = {"l.w": jnp.asarray(_gate_for_bits(4)), "l.a": jnp.asarray(_gate_for_bits(8))}
    got = float(bop.site_bop(s, g["l.w"], g["l.a"]))
    assert got == 4 * 3 * 4 * 8


def test_per_channel_inner_product():
    """Paper formula: sum_o b_a[o] * sum_j b_W[j, o]."""
    s = _site(fan_in=4, out=3)
    bw = jnp.asarray([_gate_for_bits(b) for b in (2, 4, 8)])   # per out-channel
    ba = jnp.asarray([_gate_for_bits(b) for b in (8, 8, 16)])
    got = float(bop.site_bop(s, bw, ba))
    want = 4 * (2 * 8 + 4 * 8 + 8 * 16)
    assert got == want


def test_per_weight_general_form():
    s = _site(fan_in=2, out=2)
    bw = jnp.asarray(
        [[_gate_for_bits(2), _gate_for_bits(4)], [_gate_for_bits(8), _gate_for_bits(16)]]
    )  # (in, out)
    ba = jnp.asarray([_gate_for_bits(4), _gate_for_bits(8)])
    got = float(bop.site_bop(s, bw, ba))
    want = (2 + 8) * 4 + (4 + 16) * 8
    assert got == want


def test_positions_multiplier_conv():
    s = _site(fan_in=9, out=8, positions=26 * 26)
    g32 = jnp.asarray(_gate_for_bits(32))
    got = float(bop.site_bop(s, g32, g32))
    assert got == 9 * 8 * 26 * 26 * 32 * 32


def test_stacked_per_tensor():
    s = _site(fan_in=4, out=3, stack=2)
    bw = jnp.asarray([_gate_for_bits(4), _gate_for_bits(8)])
    ba = jnp.asarray([_gate_for_bits(8), _gate_for_bits(8)])
    got = float(bop.site_bop(s, bw, ba))
    want = 4 * 3 * (4 * 8 + 8 * 8)
    assert got == want


def test_stacked_per_channel():
    s = _site(fan_in=4, out=2, stack=2)
    bw = jnp.asarray([[_gate_for_bits(2), _gate_for_bits(4)],
                      [_gate_for_bits(8), _gate_for_bits(8)]])  # (stack, out)
    ba = jnp.asarray([[_gate_for_bits(4), _gate_for_bits(4)],
                      [_gate_for_bits(16), _gate_for_bits(16)]])
    got = float(bop.site_bop(s, bw, ba))
    want = 4 * ((2 * 4 + 4 * 4) + (8 * 16 + 8 * 16))
    assert got == want


def test_moe_active_fraction():
    s = _site(fan_in=8, out=8, frac=2 / 8)
    g32 = jnp.asarray(_gate_for_bits(32))
    got = float(bop.site_bop(s, g32, g32))
    assert got == 8 * 8 * 32 * 32 * (2 / 8)


def test_fp_output_site_excluded():
    s = _site(act_q=False)
    assert float(bop.site_bop(s, jnp.asarray(0.5), None)) == 0.0
    assert bop.fp32_bop({"l": s}) == 0.0


def test_rbop_lower_bound_is_2bit():
    sites = {"a": _site(fan_in=10, out=10, name="a"), "b": _site(fan_in=20, out=5, name="b")}
    g2 = {k + suf: jnp.asarray(_gate_for_bits(2)) for k in sites for suf in (".w", ".a")}
    r = float(bop.rbop(sites, g2))
    np.testing.assert_allclose(r, 4.0 / 1024.0, rtol=1e-6)
    assert bop.min_bop(sites) == bop.fp32_bop(sites) * 4 / 1024


def test_budget_from_rbop():
    sites = {"a": _site(fan_in=10, out=10)}
    assert bop.budget_from_rbop(sites, 0.004) == 0.004 * 100 * 1024
