"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU).

Each Pallas kernel is swept across shapes/dtypes and asserted against its
ref.py oracle, per the deliverable (c) requirements.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gates import gated_fake_quant
from repro.core.quantizer import quantize_to_int
from repro.kernels.fake_quant.ops import fake_quant_op
from repro.kernels.fake_quant.ref import fake_quant_ref
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant_matmul.ops import quant_matmul_op
from repro.kernels.quant_matmul.ref import quant_matmul_ref


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 32), (128, 128), (300, 257), (1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("signed", [True, False])
def test_fake_quant_kernel_vs_ref(shape, dtype, signed):
    rng = np.random.default_rng(hash((shape, signed)) % 2**31)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    gate = jnp.asarray(rng.uniform(0.2, 5.5, size=(shape[-1],)), jnp.float32)
    beta = jnp.asarray(rng.uniform(0.3, 2.0, size=(shape[-1],)), jnp.float32)
    got = fake_quant_op(x, gate, beta, signed=signed, use_pallas=True)
    want = fake_quant_ref(
        x.reshape(-1, shape[-1]).astype(jnp.float32), gate, beta, signed
    ).reshape(shape).astype(dtype)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_fake_quant_kernel_matches_core_path():
    """Kernel == the core gated_fake_quant used by training (bit-exact)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    gate = jnp.asarray(2.5)   # 8-bit
    beta = jnp.asarray(1.2)
    got = fake_quant_op(x, gate, beta, signed=True)
    want = gated_fake_quant(x, gate, beta, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_fake_quant_per_tensor_scalar_broadcast():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(33, 65)), jnp.float32)
    got = fake_quant_op(x, jnp.asarray(1.5), jnp.asarray(1.0), signed=True)
    want = fake_quant_ref(x.astype(jnp.float32), jnp.full((65,), 1.5),
                          jnp.ones((65,)), True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mkn", [(16, 64, 32), (128, 256, 128), (200, 384, 96),
                                 (64, 1024, 256)])
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_matmul_vs_ref(mkn, bits):
    m, k, n = mkn
    rng = np.random.default_rng(m + k + n + bits)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    beta = jnp.max(jnp.abs(w), axis=0)
    codes, scale, bias = quantize_to_int(w, bits, beta, True)
    got = quant_matmul_op(x, codes, scale, bias, use_pallas=True)
    want = quant_matmul_ref(x, codes, scale, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (96, 640, 72)])
def test_quant_matmul_bias_path_per_channel_asymmetric(mkn):
    """The kernel's affine epilogue: per-channel asymmetric export, bias != 0.

    Unsigned (alpha = 0) grids produce a nonzero per-channel ``bias``; the
    Pallas kernel must fold ``bias[n] * rowsum(x)[m]`` into the output tile
    (the rank-1 term of y = x @ (codes*scale + bias)). Checked against the
    jnp oracle AND the exact fp32 matmul on the dequantized weight.
    """
    m, k, n = mkn
    rng = np.random.default_rng(m * 7 + n)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(np.abs(rng.normal(size=(k, n))).astype(np.float32))
    beta = jnp.max(jnp.abs(w), axis=0)
    codes, scale, bias = quantize_to_int(w, 8, beta, signed=False)
    assert float(jnp.abs(bias).max()) > 0.0
    got = quant_matmul_op(x, codes, scale, bias, use_pallas=True)
    want = quant_matmul_ref(x, codes, scale, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    exact = x @ (codes.astype(jnp.float32) * scale[None, :] + bias[None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                               rtol=2e-4, atol=2e-4)


def test_quant_matmul_mixed_bits_grid_matches_fake_quant():
    """Array-bits export: codes*scale+bias reproduces the fake-quant grid."""
    from repro.core.quantizer import quantize

    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    beta = jnp.max(jnp.abs(w), axis=0)
    bits = jnp.asarray(rng.choice([2.0, 4.0, 8.0], size=(48,)))
    codes, scale, bias = quantize_to_int(w, bits, beta, True)
    assert codes.dtype == jnp.int8
    deq = codes.astype(jnp.float32) * scale[None, :] + bias[None, :]
    fq = quantize(w, bits[None, :], beta[None, :], True)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(fq), atol=1e-6)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (8, 37, 24), (64, 130, 72)])
@pytest.mark.parametrize("bits", [2, 4])
def test_packed_quant_matmul_vs_int8_oracle(mkn, bits):
    """The packed fused unpack+dequant kernel (interpret) and its jnp ref
    against the unpacked int8 oracle — including ragged K that is not a
    multiple of the codes-per-byte packing factor."""
    from repro.quant import QuantizedTensor
    from repro.kernels.quant_matmul.ops import quant_matmul_qt

    m, k, n = mkn
    rng = np.random.default_rng(m + k + n + bits)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    beta = jnp.max(jnp.abs(w), axis=0)
    qt = QuantizedTensor.from_float(w, bits, beta[None, :], True,
                                    storage_bits=bits)
    oracle = QuantizedTensor.from_float(w, bits, beta[None, :], True,
                                        storage_bits=bits, pack=False)
    assert qt.packed and qt.codes.shape[0] == -(-k // (8 // bits))
    want = quant_matmul_qt(x, oracle, use_pallas=False)
    got_ref = quant_matmul_qt(x, qt, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    got_pl = quant_matmul_qt(x, qt, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quant_matmul_end_to_end_error_small():
    """x @ dequant(quant(w)) stays close to x @ w at 8 bits."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(32, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    beta = jnp.max(jnp.abs(w), axis=0)
    codes, scale, bias = quantize_to_int(w, 8, beta, True)
    got = quant_matmul_op(x, codes, scale, bias)
    exact = x @ w
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert rel < 1e-2  # int8 absmax grid: ~0.4% RMS weight error


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [64, 128, 256])
@pytest.mark.parametrize("d", [32, 64])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_vs_ref(s, d, window):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(size=(2, 3, s, d)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.normal(size=(2, 3, s, d)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.normal(size=(2, 3, s, d)).astype(np.float32))
    got = flash_attention_op(q, k, v, causal=True, window=window)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_softcap_and_gqa():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32))
    got = flash_attention_op(q, k, v, causal=True, softcap=30.0)
    k_r = jnp.repeat(k, 2, axis=1)
    v_r = jnp.repeat(v, 2, axis=1)
    want = attention_ref(q, k_r, v_r, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention_op(q, k, v, causal=True)
    want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
