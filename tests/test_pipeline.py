"""Fast end-to-end tests of the CGMQ pipeline on LeNet + synthetic digits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import CGMQConfig
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.sites import PER_TENSOR, PER_WEIGHT, QuantConfig
from repro.data.synthetic import digits, lm_tokens
from repro.models import lenet


@pytest.fixture(scope="module")
def small_digits():
    xtr, ytr = digits(600, split="train")
    xte, yte = digits(200, split="test")
    return (
        (jnp.asarray(xtr), jnp.asarray(ytr)),
        (jnp.asarray(xte), jnp.asarray(yte)),
    )


def _run(small_digits, granularity, direction="dir1", budget=0.02, epochs=25):
    train, test = small_digits
    params = lenet.init_params(jax.random.PRNGKey(0))
    return run_pipeline(
        lenet.forward, lenet.weight_lookup, params, train, test,
        QuantConfig(granularity=granularity),
        CGMQConfig(budget_rbop=budget, direction=direction, gate_lr=0.01),
        PipelineConfig(pretrain_epochs=6, range_epochs=2, cgmq_epochs=epochs,
                       eval_every=100, batch_size=64, log=lambda s: None),
    )


@pytest.mark.slow
def test_pipeline_reaches_budget_per_tensor(small_digits):
    # paper §3: the budget is reached *given enough steps* — 40 epochs gives
    # dir1 the headroom it needs at this data scale (25 was borderline and
    # failed at the seed too); the scan engine makes the longer run cheap
    res = _run(small_digits, PER_TENSOR, epochs=40)
    assert res.satisfied, f"rbop={res.final_rbop}"
    assert res.final_rbop <= 0.02 + 1e-6
    # quantized accuracy stays within reach of the fp32 baseline
    assert res.final_test_acc >= res.fp32_test_acc - 0.15


@pytest.mark.slow
def test_pipeline_reaches_budget_per_weight(small_digits):
    res = _run(small_digits, PER_WEIGHT, direction="dir3", epochs=40)
    assert res.satisfied, f"rbop={res.final_rbop}"


def test_lenet_fp32_forward_shapes(small_digits):
    (xtr, _), _ = small_digits
    from repro.core.sites import QuantContext

    params = lenet.init_params(jax.random.PRNGKey(1))
    out = lenet.forward(QuantContext(mode="off"), params, xtr[:8])
    assert out.shape == (8, 10)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_lenet_site_macs():
    """Hand-checked MAC counts for the classic LeNet-5."""
    from repro.core.sites import QuantConfig, collect_sites

    params = lenet.init_params(jax.random.PRNGKey(0))
    sites = collect_sites(
        lenet.forward, params, jax.ShapeDtypeStruct((4, 28, 28, 1), jnp.float32),
        cfg=QuantConfig(),
    )
    macs = {k: s.macs_per_token for k, s in sites.items()}
    assert macs["conv1"] == 5 * 5 * 1 * 6 * 28 * 28
    assert macs["conv2"] == 5 * 5 * 6 * 16 * 10 * 10
    assert macs["fc1"] == 400 * 120
    assert macs["fc3"] == 84 * 10
    assert not sites["fc3"].act_quantized


def test_synthetic_digits_learnable_and_deterministic():
    x1, y1 = digits(64, split="train", seed=3)
    x2, y2 = digits(64, split="train", seed=3)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 28, 28, 1)
    assert set(np.unique(y1)) == set(range(10))
    # classes must differ visually (mean image distance > noise floor)
    m0 = x1[y1 == 0].mean(axis=0)
    m1 = x1[y1 == 1].mean(axis=0)
    assert np.abs(m0 - m1).mean() > 0.05


def test_lm_tokens_structure():
    toks = lm_tokens(4, 128, vocab=97, seed=1, noise=0.0)
    assert toks.shape == (4, 129)
    # noiseless stream is exactly affine-predictable
    a_next = toks[:, 1:]
    # recover (a, b) from the first two transitions and verify globally
    x0, x1, x2 = int(toks[0, 0]), int(toks[0, 1]), int(toks[0, 2])
    # solve x1 = a*x0+b, x2 = a*x1+b mod 97
    for a in range(97):
        b = (x1 - a * x0) % 97
        if (a * x1 + b) % 97 == x2:
            pred = (a * toks[:, :-1] + b) % 97
            if np.array_equal(pred, a_next):
                return
    raise AssertionError("no affine rule found")
