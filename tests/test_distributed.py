"""Distribution-layer tests requiring multiple (host) devices.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count
so the main test process keeps its single-device view (per the dry-run
contract: nothing but dryrun.py sets the flag globally).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import ShardingPlan
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh, batch_axes_of
"""


def test_sharded_loss_matches_single_device():
    """The sharded CGMQ train step computes the same loss as unsharded."""
    out = _run(PRELUDE + """
cfg = get_smoke_config("tinyllama-1.1b")
shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
recipe = steps_lib.make_recipe(cfg, shape, check_every=2)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}

losses = {}
for use_mesh in (False, True):
    state = steps_lib.init_train_state(recipe, jax.random.PRNGKey(0))
    plan = None
    b = batch
    if use_mesh:
        mesh = make_test_mesh((2, 2), ("data", "model"))
        plan = ShardingPlan(mesh=mesh, cfg=cfg, batch_axes=("data",))
        sh = steps_lib.train_state_shardings(recipe, jax.eval_shape(lambda: state), plan)
        state = jax.tree.map(jax.device_put, state, sh)
        bs = plan.batch_dict_shardings(batch)
        b = {k: jax.device_put(v, bs[k]) for k, v in batch.items()}
    step = jax.jit(steps_lib.make_train_step(recipe, plan))
    ls = []
    for _ in range(3):
        state, m = step(state, b)
        ls.append(float(m["loss"]))
    losses[use_mesh] = ls
print(json.dumps(losses))
""")
    losses = json.loads(out.strip().splitlines()[-1])
    for a, b in zip(losses["false"], losses["true"]):
        assert abs(a - b) / max(abs(a), 1e-6) < 5e-3, losses


def test_vocab_parallel_xent_matches_dense():
    out = _run(PRELUDE + """
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.steps import vocab_parallel_xent
from repro.configs import get_smoke_config
cfg = get_smoke_config("tinyllama-1.1b")
mesh = make_test_mesh((2, 2), ("data", "model"))
plan = ShardingPlan(mesh=mesh, cfg=cfg, batch_axes=("data",))
rng = np.random.default_rng(1)
logits = jnp.asarray(rng.normal(size=(4, 8, 64)), jnp.float32)
targets = jnp.asarray(rng.integers(0, 60, (4, 8)), jnp.int32)
dense = float(vocab_parallel_xent(None, logits, targets, 60))
lg = jax.device_put(logits, NamedSharding(mesh, P("data", None, "model")))
tg = jax.device_put(targets, NamedSharding(mesh, P("data", None)))
sharded = float(jax.jit(lambda l, t: vocab_parallel_xent(plan, l, t, 60))(lg, tg))
print(json.dumps([dense, sharded]))
""")
    dense, sharded = json.loads(out.strip().splitlines()[-1])
    assert abs(dense - sharded) < 1e-4


def test_sharded_embed_lookup_matches_take():
    out = _run(PRELUDE + """
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.steps import sharded_embed_lookup
cfg = get_smoke_config("tinyllama-1.1b")
mesh = make_test_mesh((2, 2), ("data", "model"))
plan = ShardingPlan(mesh=mesh, cfg=cfg, batch_axes=("data",))
rng = np.random.default_rng(2)
table = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
toks = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
want = jnp.take(table, toks, axis=0)
tab = jax.device_put(table, NamedSharding(mesh, P("model", None)))
tk = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
got = jax.jit(lambda t, k: sharded_embed_lookup(plan, t, k))(tab, tk)
print(float(jnp.abs(got - want).max()))
""")
    assert float(out.strip().splitlines()[-1]) < 1e-5


def test_grad_compression_across_pods():
    """int8 EF compression over a real 2-pod axis: exact-mean property."""
    out = _run(PRELUDE + """
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim.compression import make_compressed_pod_psum, init_residuals
mesh = make_test_mesh((2, 2), ("pod", "data"))
f = make_compressed_pod_psum(mesh)
rng = np.random.default_rng(3)
g = {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
r = init_residuals(g)
out_, r1 = jax.jit(f)(g, r)
# replicated input -> mean == dequant(quant(g)); small error, EF captures it
err = float(jnp.abs(out_["w"] - g["w"]).max())
ef = float(jnp.abs((g["w"] - out_["w"]) - r1["w"]).max())
print(json.dumps([err, ef, float(jnp.abs(g["w"]).max())]))
""", devices=4)
    err, ef, gmax = json.loads(out.strip().splitlines()[-1])
    assert err < gmax / 64  # int8 quantization error bound
    assert ef < 1e-5        # residual exactly tracks the error


def test_checkpoint_elastic_remesh():
    """Save on a (2,2) mesh, restore onto a (4,) mesh — elastic scaling."""
    out = _run(PRELUDE + """
import tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint.checkpointer import Checkpointer
tmp = tempfile.mkdtemp()
mesh_a = make_test_mesh((2, 2), ("data", "model"))
arr = jnp.arange(64.0).reshape(8, 8)
sharded = jax.device_put(arr, NamedSharding(mesh_a, P("data", "model")))
ck = Checkpointer(tmp)
ck.save(1, {"w": sharded}, blocking=True)
mesh_b = make_test_mesh((4,), ("data",))
target = NamedSharding(mesh_b, P("data", None))
restored, step, _ = ck.restore(
    jax.eval_shape(lambda: {"w": arr}), shardings={"w": target})
ok = bool(jnp.all(restored["w"] == arr))
print(json.dumps([ok, step, str(restored["w"].sharding.spec)]))
""", devices=4)
    ok, step, spec = json.loads(out.strip().splitlines()[-1])
    assert ok and step == 1
    assert "data" in spec
