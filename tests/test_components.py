"""Component-level oracle tests: SSD, RG-LRU, MoE, attention decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.sites import QuantContext
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssd as ssd_lib

QC = lambda: QuantContext(mode="off")


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


class TestSSD:
    cfg = get_smoke_config("mamba2-1.3b")

    def _params(self, seed=0):
        return ssd_lib.init_ssd(jax.random.PRNGKey(seed), self.cfg)

    def test_chunked_matches_stepwise_reference(self):
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, self.cfg.d_model),
                              jnp.float32) * 0.5
        y_ref, s_ref = ssd_lib.ssd_reference(p, x, self.cfg)
        y, (_, s) = ssd_lib.ssd_chunked(QC(), p, x, self.cfg)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-3, atol=1e-3)

    def test_state_carry_equals_joint(self):
        """Processing [x1; x2] == processing x1 then x2 with carried state."""
        p = self._params(2)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, self.cfg.d_model)) * 0.5
        y_all, (cv_all, s_all) = ssd_lib.ssd_chunked(QC(), p, x, self.cfg)
        y1, (cv1, s1) = ssd_lib.ssd_chunked(QC(), p, x[:, :8], self.cfg)
        y2, (cv2, s2) = ssd_lib.ssd_chunked(
            QC(), p, x[:, 8:], self.cfg, conv_state=cv1, ssm_state=s1)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1), np.float32),
            np.asarray(y_all, np.float32), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                                   rtol=1e-3, atol=1e-3)

    def test_decode_continues_prefill(self):
        p = self._params(4)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 17, self.cfg.d_model)) * 0.5
        # reference: full 17-token reference run
        y_ref, _ = ssd_lib.ssd_reference(p, x, self.cfg)
        # prefill on 16 (chunk multiple), then one decode step
        _, (cv, s) = ssd_lib.ssd_chunked(QC(), p, x[:, :16], self.cfg)
        y_step, _ = ssd_lib.ssd_decode_step(QC(), p, x[:, 16:17], cv, s, self.cfg)
        np.testing.assert_allclose(np.asarray(y_step, np.float32),
                                   np.asarray(y_ref[:, 16:17], np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


class TestRGLRU:
    cfg = get_smoke_config("recurrentgemma-2b")

    def _params(self, seed=0):
        return rglru_lib.init_rglru(jax.random.PRNGKey(seed), self.cfg)

    def test_scan_matches_stepwise(self):
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, self.cfg.d_model)) * 0.5
        y_all, (cv, h) = rglru_lib.rglru_forward(QC(), p, x, self.cfg)
        cache = rglru_lib.init_rglru_cache(self.cfg, 2)
        ys = []
        cv_s, h_s = cache["conv"], cache["h"]
        for t in range(12):
            y, (cv_s, h_s) = rglru_lib.rglru_decode_step(
                QC(), p, x[:, t : t + 1], cv_s, h_s, self.cfg)
            ys.append(y)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate(ys, 1), np.float32),
            np.asarray(y_all, np.float32), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(h_s), np.asarray(h),
                                   rtol=1e-3, atol=1e-3)

    def test_decay_bounded(self):
        """a_t in (0, 1): the recurrence is contractive."""
        p = self._params(1)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, self.cfg.lru_width))
        a, b = rglru_lib._gates(QC(), p, x)
        assert float(jnp.min(a)) > 0.0
        assert float(jnp.max(a)) < 1.0


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


class TestMoE:
    cfg = get_smoke_config("mixtral-8x22b")

    def _params(self, seed=0):
        return moe_lib.init_moe(jax.random.PRNGKey(seed), self.cfg)

    def test_capacity_matches_dense_with_big_capacity(self):
        """With capacity >= group, no token drops: impls must agree."""
        cfg = dataclasses.replace(self.cfg, capacity_factor=float(self.cfg.n_experts))
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
        y_dense = moe_lib.moe_ffn(QC(), p, x, cfg, impl="dense_all")
        y_cap = moe_lib.moe_ffn(QC(), p, x, cfg, impl="capacity")
        np.testing.assert_allclose(np.asarray(y_cap, np.float32),
                                   np.asarray(y_dense, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_router_topk_weights_normalized(self):
        p = self._params(2)
        x = jax.random.normal(jax.random.PRNGKey(3), (16, self.cfg.d_model))
        w, idx = moe_lib._router(QC(), p, x, self.cfg)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
        assert int(idx.max()) < self.cfg.n_experts

    def test_capacity_drops_overflow(self):
        """Tiny capacity forces drops; output stays finite and bounded."""
        cfg = dataclasses.replace(self.cfg, capacity_factor=0.25)
        p = self._params(4)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, cfg.d_model)) * 0.5
        y = moe_lib.moe_ffn(QC(), p, x, cfg, impl="capacity")
        assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# Attention decode vs train consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,kind", [
    ("tinyllama-1.1b", "global"),
    ("gemma2-2b", "local"),
    ("recurrentgemma-2b", "local"),   # MQA kv=1
    ("musicgen-large", "global"),     # MHA kv=H
])
def test_attention_decode_matches_train(arch, kind):
    cfg = get_smoke_config(arch)
    p = attn.init_attn(jax.random.PRNGKey(0), cfg)
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, (k, v) = attn.attention_train(QC(), p, x, cfg, kind)

    cache = attn.init_attn_cache(cfg, kind, 2, max_seq=16, dtype=jnp.float32)
    ys = []
    for t in range(s):
        y, cache = attn.attention_decode(
            QC(), p, x[:, t : t + 1], cache, jnp.asarray(t), cfg, kind)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_local_attention_masks_beyond_window():
    """A token > window away must not influence the output."""
    cfg = get_smoke_config("mixtral-8x22b")  # window=8
    p = attn.init_attn(jax.random.PRNGKey(0), cfg)
    s = 12
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, s, cfg.d_model)) * 0.5
    x2 = x1.at[:, 0].set(x1[:, 0] + 10.0)  # perturb a token outside the window
    y1, _ = attn.attention_train(QC(), p, x1, cfg, "local")
    y2, _ = attn.attention_train(QC(), p, x2, cfg, "local")
    # last position: distance 11 >= window 8 -> unaffected
    np.testing.assert_allclose(np.asarray(y1[:, -1], np.float32),
                               np.asarray(y2[:, -1], np.float32),
                               rtol=1e-3, atol=1e-3)
    # position 3: distance 3 < 8 -> affected
    assert float(jnp.abs(y1[:, 3] - y2[:, 3]).max()) > 1e-3


def test_ring_buffer_cache_long_decode():
    """Decode far past the window: ring cache must equal full-cache result."""
    cfg = get_smoke_config("mixtral-8x22b")  # window=8
    p = attn.init_attn(jax.random.PRNGKey(0), cfg)
    s = 24
    x = jax.random.normal(jax.random.PRNGKey(2), (1, s, cfg.d_model)) * 0.5
    y_full, _ = attn.attention_train(QC(), p, x, cfg, "local")
    cache = attn.init_attn_cache(cfg, "local", 1, max_seq=s, dtype=jnp.float32)
    assert cache["k"].shape[1] == cfg.window  # ring: window slots only
    ys = []
    for t in range(s):
        y, cache = attn.attention_decode(
            QC(), p, x[:, t : t + 1], cache, jnp.asarray(t), cfg, "local")
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1), np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=4e-2, atol=4e-2)
