"""Docs/code contract tests.

Two invariants keep the documentation load-bearing instead of decorative:

  * every ``DESIGN.md §N`` reference in ``src/`` and ``tests/`` must resolve
    to an existing ``## §N`` section header in DESIGN.md (section numbers are
    cited from code comments, so a renumber must sweep the repo);
  * every ``path.py:symbol`` site named in ``docs/paper_map.md`` must exist —
    the file is real, the symbol is defined in it, the referenced test
    function exists, and every ``src/`` module in the table imports cleanly.
"""

import ast
import importlib
import re
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DESIGN = ROOT / "DESIGN.md"
PAPER_MAP = ROOT / "docs" / "paper_map.md"


def _design_sections() -> set[str]:
    return set(re.findall(r"^## §(\d+)", DESIGN.read_text(), re.MULTILINE))


def _code_refs():
    """(path, section) for every DESIGN.md §N mention under src/ and tests/
    (and the docs themselves)."""
    refs = []
    files = [*(ROOT / "src").rglob("*.py"), *(ROOT / "tests").rglob("*.py"),
             PAPER_MAP, ROOT / "README.md"]
    for path in files:
        for m in re.finditer(r"DESIGN\.md §(\d+)|§(\d+)\b",
                             path.read_text()):
            sec = m.group(1) or m.group(2)
            refs.append((path.relative_to(ROOT), sec))
    return refs


def test_design_section_references_resolve():
    sections = _design_sections()
    assert sections, "DESIGN.md has no ## §N headers?"
    dangling = [(str(p), f"§{s}") for p, s in _code_refs()
                if s not in sections]
    assert not dangling, f"dangling DESIGN.md references: {dangling}"


# ---------------------------------------------------------------------------
# paper_map.md rows
# ---------------------------------------------------------------------------


def _map_rows():
    """Every code/test site referenced from a paper_map.md TABLE row."""
    rows = []
    for line in PAPER_MAP.read_text().splitlines():
        if line.startswith("|"):
            rows.extend(re.findall(r"`([\w/.]+\.py):(\w+)`", line))
    assert rows, "docs/paper_map.md has no table site references?"
    return rows


def _defined_symbols(path: Path) -> set[str]:
    tree = ast.parse(path.read_text())
    names = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
    return names


@pytest.mark.parametrize("relpath,symbol", sorted(set(_map_rows())))
def test_paper_map_site_exists(relpath, symbol):
    path = ROOT / relpath
    assert path.is_file(), f"paper_map names missing file {relpath}"
    assert symbol in _defined_symbols(path), \
        f"{relpath} does not define `{symbol}`"


@pytest.mark.parametrize(
    "relpath",
    sorted({r for r, _ in _map_rows() if r.startswith("src/repro/")}))
def test_paper_map_module_imports(relpath):
    sys.path.insert(0, str(ROOT / "src"))
    try:
        module = relpath[len("src/"):-len(".py")].replace("/", ".")
        mod = importlib.import_module(module)
        for r, symbol in _map_rows():
            if r == relpath:
                assert hasattr(mod, symbol), f"{module} lacks {symbol}"
    finally:
        sys.path.remove(str(ROOT / "src"))
