"""End-to-end driver: train a decoder LM under a CGMQ BOP budget.

The production loop in miniature: synthetic token pipeline -> sharded-or-not
CGMQ train step (fake-quant forward, Adam, gate controller) -> supervised
loop with async checkpointing, crash recovery and straggler detection.

Defaults are CPU-sized (a ~10M-param tinyllama-family model, 200 steps,
minutes). ``--preset 100m`` selects a ~100M-param model for a real machine;
``--arch`` accepts any registry architecture (reduced with --smoke).

    PYTHONPATH=src python examples/train_llm_cgmq.py --steps 200
    PYTHONPATH=src python examples/train_llm_cgmq.py --preset 100m --steps 300
"""

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import bop as bop_lib
from repro.data.synthetic import lm_tokens
from repro.distributed.fault_tolerance import SupervisorConfig, TrainSupervisor
from repro.launch import steps as steps_lib

PRESETS = {
    # ~10M params: CPU-friendly default
    "10m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
                d_ff=704, vocab_size=2048),
    # ~100M params (the deliverable-scale run; heavy on 1 CPU core)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="registry arch (smoke-reduced)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--budget-rbop", type=float, default=0.0625,
                    help="deployment BOP bound (0.0625 == W8A8)")
    ap.add_argument("--direction", default="dir2")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_llm_ckpt")
    ap.add_argument("--resume", action="store_true",
                    help="keep existing checkpoints (default: start fresh)")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    if args.arch:
        cfg = get_smoke_config(args.arch)
    else:
        base = get_config("tinyllama-1.1b")
        cfg = dataclasses.replace(base, name=f"lm-{args.preset}",
                                  vocab_pad_multiple=64, **PRESETS[args.preset])
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    recipe = steps_lib.make_recipe(
        cfg, shape, direction=args.direction, budget_rbop=args.budget_rbop,
        check_every=20)
    # gentler gate dynamics than the dry-run default: the paper anneals over
    # hundreds of epochs; at a few hundred steps we cap dir at 2 (0.02/step)
    recipe = dataclasses.replace(
        recipe, ccfg=dataclasses.replace(recipe.ccfg, dir_clip=2.0))
    state = steps_lib.init_train_state(recipe, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_lib.make_train_step(recipe, None),
                      donate_argnums=(0,))
    # FP32 warmup step (paper stage 1): same state, quantization off
    fp_recipe = dataclasses.replace(recipe, quant_enabled=False)
    fp_step_fn = jax.jit(steps_lib.make_train_step(fp_recipe, None),
                         donate_argnums=(0,))

    data = lm_tokens(4096, args.seq, cfg.vocab_size, seed=0, noise=0.05)

    def batches(step):
        if step >= args.steps:
            return None
        rng = np.random.default_rng(step)
        idx = rng.integers(0, data.shape[0], args.batch)
        chunk = data[idx]
        return {
            "tokens": jnp.asarray(chunk[:, :-1]),
            "targets": jnp.asarray(chunk[:, 1:]),
        }

    fp_bop = bop_lib.fp32_bop(recipe.sites)
    hist = []

    def metrics_cb(step, metrics):
        if step % 20 == 0 or step == args.steps:
            m = jax.device_get(metrics)
            hist.append(m)
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"rbop {float(m['bop'])/fp_bop*100:6.2f}% "
                  f"sat={bool(m['sat'])}")

    # ---- stage 1/2: FP32 warmup + range calibration (paper §2.4) ----
    warmup = max(10, args.steps // 10)
    t0 = time.time()
    for i in range(warmup):
        state, m = fp_step_fn(state, batches(i))
    print(f"[warmup] {warmup} fp32 steps, loss {float(m['loss']):.4f}")

    from repro.core.calibration import apply_act_calibration, calibrate_activations
    from repro.core.sites import init_ranges_from_weights, split_learnable_ranges
    from repro.models import transformer as tfm

    calib = calibrate_activations(
        lambda qc, b: tfm.forward_train(qc, state.params, b["tokens"], cfg),
        (batches(i) for i in range(3)), recipe.qcfg)
    ranges = init_ranges_from_weights(recipe.sites, recipe.qcfg, lambda n: None)
    ranges = apply_act_calibration(ranges, calib)
    betas, _ = split_learnable_ranges(ranges)
    # activation ranges carry the calibration; weight betas are learnable and
    # adapt from their placeholder during the CGMQ stage
    state = dataclasses.replace(state, betas=betas)
    print(f"[calibrate] {len(calib)} activation ranges set")

    # ---- stage 4: CGMQ under the supervisor ----
    sup = TrainSupervisor(
        SupervisorConfig(args.ckpt_dir, checkpoint_every=50), log=print)
    if args.inject_failure_at is not None:
        sup.inject_failure_at = args.inject_failure_at

    state, step, status = sup.run(state, step_fn, batches,
                                  metrics_cb=metrics_cb)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{status} at step {step} in {dt:.1f}s "
          f"({toks/dt:.0f} tok/s on CPU)")
    final_rbop = float(jax.device_get(state.cgmq.bop)) / fp_bop
    print(f"final RBOP {final_rbop*100:.2f}% (bound "
          f"{args.budget_rbop*100:.2f}%) "
          f"best-certified={bool(jax.device_get(state.cgmq.best_valid))}")


if __name__ == "__main__":
    main()
