"""Serving example: batched requests against a (smoke) LM with the
continuous-batching engine, plus the CGMQ int-code export path.

    PYTHONPATH=src python examples/serve_quantized.py --arch tinyllama-1.1b
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving.engine import Request, ServingEngine, export_int_codes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, (plen,)),
            max_new=args.max_new))
    finished = eng.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in finished)
    print(f"served {len(finished)} requests / {total_new} tokens "
          f"in {dt:.1f}s with {args.slots} slots")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: {list(r.output)}")

    # CGMQ export path: int8 codes for the serving GEMM
    w = params["blocks"][0]["attn"]["wq"][0]
    q = export_int_codes(w, gate=jnp.asarray(2.5),
                         beta=jnp.max(jnp.abs(w)), signed=True)
    deq_err = float(jnp.abs(
        q["codes"].astype(jnp.float32) * q["scale"] + q["bias"] - w).max())
    print(f"\nexported wq[0] at {q['bits']} bits; max dequant error "
          f"{deq_err:.4f} (|w|max {float(jnp.abs(w).max()):.3f})")


if __name__ == "__main__":
    main()
