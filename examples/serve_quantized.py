"""Serving example: batched requests against a (smoke) LM with the
continuous-batching engine — batched prefill, device-resident generation
loop, and the CGMQ mixed-precision packed-int decode path (DESIGN.md
§8/§11).

    PYTHONPATH=src python examples/serve_quantized.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_quantized.py --mixed  # 2/4/8-bit
    PYTHONPATH=src python examples/serve_quantized.py --fp32   # skip int
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving.engine import (Request, ServingEngine, export_int_codes,
                                  make_mixed_quant_state,
                                  make_uniform_quant_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fp32", action="store_true",
                    help="serve fp32 instead of the quantized export")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed 2/4/8-bit gates (packed sub-byte storage) "
                         "instead of uniform 8-bit")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "ring"],
                    help="KV cache substrate (DESIGN.md §10); auto = paged "
                         "for attention archs")
    ap.add_argument("--same-prefix", action="store_true",
                    help="submit every request with one shared prompt to "
                         "demo paged prefix sharing (N admissions ~ 1 "
                         "prefill)")
    ap.add_argument("--prefix-lru-blocks", type=int, default=0,
                    help="retain up to this many fully-retired prefix "
                         "blocks in an LRU pool (0 = evict at zero refs)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    if args.fp32:
        qs = None
    elif args.mixed:
        qs = make_mixed_quant_state(cfg, params)
    else:
        qs = make_uniform_quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128,
                        quant_state=qs, kv_layout=args.kv_layout,
                        prefix_lru_blocks=args.prefix_lru_blocks)
    if eng.qweights:
        storages = sorted({qt.storage_bits for qt in eng.qweights.values()})
        print(f"serving quantized export: {len(eng.qweights)} sites at "
              f"{storages}-bit packed storage")
        rep = eng.quant_report()
        t = rep["totals"]
        print(f"  device bytes/weight {t['bytes_per_weight']:.3f} incl. "
              f"affine aux (uniform int8 = "
              f"{t['uniform_int8_bytes_per_weight']:.3f}, fp32 = 4.0); "
              f"{t['fallback_sites']} fake-quant fallback sites; "
              f"RBOP {rep['bops']['rbop']*100:.2f}%")
    print(f"kv layout: {eng.kv_layout}"
          + (f" ({eng.num_blocks} blocks x {eng.block_size} tokens, "
             f"prefix sharing {'on' if eng.prefix_sharing else 'off'}, "
             f"LRU retention {eng.lru_capacity} blocks)"
             if eng.paged else ""))

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (16,))
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        prompt = (shared if args.same_prefix
                  else rng.integers(0, cfg.vocab_size, (plen,)))
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    finished = eng.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in finished)
    st = eng.stats
    print(f"served {len(finished)} requests / {total_new} tokens "
          f"in {dt:.1f}s with {args.slots} slots")
    print(f"  batched prefill: {st['prefill_forwards']} forwards for "
          f"{st['prompt_tokens']} prompt tokens (seed scan-of-decode-steps "
          f"would have run {st['seed_equiv_forwards']} x {args.slots}-wide)")
    if eng.paged:
        ps = eng.pool_stats()
        print(f"  paged KV: prefix-hit rate {ps['prefix_hit_rate']:.2f}, "
              f"{st['shared_admissions']} shared admissions, "
              f"{st['cow_copies']} CoW copies, "
              f"{ps['blocks_in_use']} blocks still in use "
              f"({ps['retained_blocks']} LRU-retained)")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: {list(r.output)}")

    # single-tensor export path: packed codes for one weight
    b0 = params["blocks"][0]
    if "attn" in b0:
        w = b0["attn"]["wq"][0]
    elif "ssd" in b0:
        w = b0["ssd"]["in_proj"][0]
    else:
        w = b0["rglru"]["wx"][0]
    for gate, label in ((2.5, "8-bit"), (1.5, "4-bit packed")):
        q = export_int_codes(w, gate=jnp.asarray(gate),
                             beta=jnp.max(jnp.abs(w)), signed=True)
        deq_err = float(jnp.abs(q.dequantize() - w).max())
        print(f"exported wq[0] {label}: {q.codes_bytes()} code bytes for "
              f"{q.weight_count()} weights; max dequant error {deq_err:.4f} "
              f"(|w|max {float(jnp.abs(w).max()):.3f})")


if __name__ == "__main__":
    main()
