"""Serving example: batched requests against a (smoke) LM with the
continuous-batching engine — batched prefill, device-resident generation
loop, and the CGMQ int8 fused-dequant decode path (DESIGN.md §8).

    PYTHONPATH=src python examples/serve_quantized.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_quantized.py --fp32   # skip int8
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving.engine import (Request, ServingEngine, export_int_codes,
                                  make_uniform_quant_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fp32", action="store_true",
                    help="serve fp32 instead of the int8 export")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "ring"],
                    help="KV cache substrate (DESIGN.md §10); auto = paged "
                         "for attention archs")
    ap.add_argument("--same-prefix", action="store_true",
                    help="submit every request with one shared prompt to "
                         "demo paged prefix sharing (N admissions ~ 1 "
                         "prefill)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    qs = None if args.fp32 else make_uniform_quant_state(cfg, params)
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128,
                        quant_state=qs, kv_layout=args.kv_layout)
    if eng.qweights:
        bits = sorted(set(eng.int8_report.values()))
        print(f"serving int8 export: {len(eng.qweights)} sites at {bits} bits")
    print(f"kv layout: {eng.kv_layout}"
          + (f" ({eng.num_blocks} blocks x {eng.block_size} tokens, "
             f"prefix sharing {'on' if eng.prefix_sharing else 'off'})"
             if eng.paged else ""))

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (16,))
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        prompt = (shared if args.same_prefix
                  else rng.integers(0, cfg.vocab_size, (plen,)))
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    finished = eng.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in finished)
    st = eng.stats
    print(f"served {len(finished)} requests / {total_new} tokens "
          f"in {dt:.1f}s with {args.slots} slots")
    print(f"  batched prefill: {st['prefill_forwards']} forwards for "
          f"{st['prompt_tokens']} prompt tokens (seed scan-of-decode-steps "
          f"would have run {st['seed_equiv_forwards']} x {args.slots}-wide)")
    if eng.paged:
        ps = eng.pool_stats()
        print(f"  paged KV: prefix-hit rate {ps['prefix_hit_rate']:.2f}, "
              f"{st['shared_admissions']} shared admissions, "
              f"{st['cow_copies']} CoW copies, "
              f"{ps['blocks_in_use']} blocks still in use")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: {list(r.output)}")

    # single-tensor export path: int8 codes for one weight
    b0 = params["blocks"][0]
    if "attn" in b0:
        w = b0["attn"]["wq"][0]
    elif "ssd" in b0:
        w = b0["ssd"]["in_proj"][0]
    else:
        w = b0["rglru"]["wx"][0]
    q = export_int_codes(w, gate=jnp.asarray(2.5),
                         beta=jnp.max(jnp.abs(w)), signed=True)
    deq_err = float(jnp.abs(
        q["codes"].astype(jnp.float32) * q["scale"] + q["bias"] - w).max())
    print(f"\nexported wq[0] at {q['bits']} bits; max dequant error "
          f"{deq_err:.4f} (|w|max {float(jnp.abs(w).max()):.3f})")


if __name__ == "__main__":
    main()
