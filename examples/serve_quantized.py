"""Serving example: batched requests against a (smoke) LM through the
request-lifecycle API — ``engine.generate(prompts, SamplingParams(...))``
over batched prefill, the device-resident sampled generation loop, and the
CGMQ mixed-precision packed-int decode path (DESIGN.md §8/§11/§12).

    PYTHONPATH=src python examples/serve_quantized.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_quantized.py --mixed  # 2/4/8-bit
    PYTHONPATH=src python examples/serve_quantized.py --fp32   # skip int
    PYTHONPATH=src python examples/serve_quantized.py --act-bits 8  # int8×int8
    PYTHONPATH=src python examples/serve_quantized.py \\
        --temperature 0.8 --top-p 0.9 --seed 7 --stream
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as tfm
from repro.serving import (SamplingParams, ServingEngine, WindowSpec,
                           export_int_codes, make_mixed_quant_state,
                           make_uniform_quant_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fp32", action="store_true",
                    help="serve fp32 instead of the quantized export")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed 2/4/8-bit gates (packed sub-byte storage) "
                         "instead of uniform 8-bit")
    ap.add_argument("--act-bits", default="none", choices=["8", "4", "none"],
                    help="quantize GEMM input activations at this width and "
                         "serve fully-integer int8×int8 MACs (DESIGN.md "
                         "§16); none = int-weight × fp32-act GEMMs")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "ring"],
                    help="KV cache substrate (DESIGN.md §10); auto = paged "
                         "for attention archs")
    ap.add_argument("--same-prefix", action="store_true",
                    help="submit every request with one shared prompt to "
                         "demo paged prefix sharing (N admissions ~ 1 "
                         "prefill)")
    ap.add_argument("--prefix-lru-blocks", type=int, default=0,
                    help="retain up to this many fully-retired prefix "
                         "blocks in an LRU pool (0 = evict at zero refs)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "bit-exact oracle path)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest-probability tokens "
                         "(0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed (requests get "
                         "seed, seed+1, ... so streams are reproducible "
                         "yet distinct)")
    ap.add_argument("--stream", action="store_true",
                    help="use generate_stream() and print tokens as the "
                         "ticks emit them")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous-batching chunked prefill: split each "
                         "prompt into chunks of this many tokens and "
                         "interleave them with decode ticks (DESIGN.md "
                         "§15; default = legacy whole-prompt waves)")
    ap.add_argument("--window", type=int, default=None,
                    help="long-context sliding attention window in tokens "
                         "(DESIGN.md §17): every attention site masks to "
                         "the last WINDOW positions and, on the paged "
                         "layout, out-of-window KV blocks are evicted "
                         "in-tick so residency stays O(window)")
    ap.add_argument("--sink-blocks", type=int, default=0,
                    help="with --window: leading paged KV blocks pinned "
                         "forever (attention sinks) — always attended, "
                         "never evicted")
    args = ap.parse_args()
    if args.sink_blocks and args.window is None:
        ap.error("--sink-blocks requires --window")

    cfg = get_smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    if args.fp32:
        qs = None
    elif args.mixed:
        qs = make_mixed_quant_state(cfg, params)
    else:
        qs = make_uniform_quant_state(cfg, params)
    act_bits = None if args.act_bits == "none" else int(args.act_bits)
    if act_bits is not None and qs is None:
        ap.error("--act-bits requires a quantized export (drop --fp32)")
    window = None
    if args.window is not None:
        window = WindowSpec(window=args.window,
                            sink_blocks=args.sink_blocks)
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=128,
                        quant_state=qs, act_bits=act_bits,
                        kv_layout=args.kv_layout,
                        prefix_lru_blocks=args.prefix_lru_blocks,
                        prefill_chunk_tokens=args.prefill_chunk,
                        attention_window=window)
    if eng.qweights:
        storages = sorted({qt.storage_bits for qt in eng.qweights.values()})
        print(f"serving quantized export: {len(eng.qweights)} sites at "
              f"{storages}-bit packed storage")
        rep = eng.quant_report()
        t = rep["totals"]
        print(f"  device bytes/weight {t['bytes_per_weight']:.3f} incl. "
              f"affine aux (uniform int8 = "
              f"{t['uniform_int8_bytes_per_weight']:.3f}, fp32 = 4.0); "
              f"{t['fallback_sites']} fake-quant fallback sites; "
              f"RBOP {rep['bops']['rbop']*100:.2f}%")
        if act_bits is not None:
            a = rep["acts"]
            print(f"  fully-integer GEMMs: {a['covered']}/{a['total']} "
                  f"activation sites calibrated at {act_bits}-bit "
                  f"(int8×int8 integer accumulation; "
                  f"{len(a['fallback_sites'])} float-input fallbacks)")
    print(f"kv layout: {eng.kv_layout}"
          + (f" ({eng.num_blocks} blocks x {eng.block_size} tokens, "
             f"prefix sharing {'on' if eng.prefix_sharing else 'off'}, "
             f"LRU retention {eng.lru_capacity} blocks)"
             if eng.paged else ""))

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (16,))
    prompts, plist = [], []
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        prompts.append(shared if args.same_prefix
                       else rng.integers(0, cfg.vocab_size, (plen,)))
        plist.append(SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, max_new=args.max_new,
            seed=None if args.seed is None else args.seed + i))
    t0 = time.time()
    if args.stream:
        # FIFO admission means first-seen event order == submission order,
        # so number the requests as their first tokens arrive
        ridx, streamed = {}, {}
        for ev in eng.generate_stream(prompts, plist):
            i = ridx.setdefault(ev.rid, len(ridx))
            print(f"  tick -> req {i} token {ev.token}"
                  + (f" [{ev.finish_reason}]" if ev.done else ""))
            streamed.setdefault(i, []).append(ev.token)
        results = None
    else:
        results = eng.generate(prompts, plist)
    dt = time.time() - t0
    st = eng.stats
    total_new = st["generated_tokens"]
    print(f"served {args.requests} requests / {total_new} tokens "
          f"in {dt:.1f}s with {args.slots} slots "
          f"({'sampled t=%.2f' % args.temperature if args.temperature > 0 else 'greedy'}; "
          f"{st['tick_syncs']} host syncs over {st['decode_ticks']} ticks)")
    print(f"  batched prefill: {st['prefill_forwards']} forwards for "
          f"{st['prompt_tokens']} prompt tokens (seed scan-of-decode-steps "
          f"would have run {st['seed_equiv_forwards']} x {args.slots}-wide)")
    if args.prefill_chunk:
        print(f"  chunked prefill: {st['prefill_chunks']} chunks of "
              f"<= {args.prefill_chunk} tokens interleaved with decode ticks")
    if eng.paged:
        ps = eng.pool_stats()
        print(f"  paged KV: prefix-hit rate {ps['prefix_hit_rate']:.2f}, "
              f"{st['shared_admissions']} shared admissions, "
              f"{st['cow_copies']} CoW copies, "
              f"{ps['blocks_in_use']} blocks still in use "
              f"({ps['retained_blocks']} LRU-retained)")
        if "window" in ps:
            w = ps["window"]
            print(f"  attention window: {w['window']} tokens + "
                  f"{w['sink_blocks']} sink blocks -> "
                  f"{w['live_blocks_per_slot']} live blocks/slot of "
                  f"{w['table_blocks_per_slot']} table blocks "
                  f"(residency {w['residency_ratio']:.2f}x)")
    if results is not None:
        for i, r in enumerate(results):
            print(f"  req {i}: {r.tokens} [{r.finish_reason}]")
    else:
        for i in sorted(streamed):
            print(f"  req {i}: {streamed[i]}")

    # single-tensor export path: packed codes for one weight
    b0 = params["blocks"][0]
    if "attn" in b0:
        w = b0["attn"]["wq"][0]
    elif "ssd" in b0:
        w = b0["ssd"]["in_proj"][0]
    else:
        w = b0["rglru"]["wx"][0]
    for gate, label in ((2.5, "8-bit"), (1.5, "4-bit packed")):
        q = export_int_codes(w, gate=jnp.asarray(gate),
                             beta=jnp.max(jnp.abs(w)), signed=True)
        deq_err = float(jnp.abs(q.dequantize() - w).max())
        print(f"exported wq[0] {label}: {q.codes_bytes()} code bytes for "
              f"{q.weight_count()} weights; max dequant error {deq_err:.4f} "
              f"(|w|max {float(jnp.abs(w).max()):.3f})")


if __name__ == "__main__":
    main()
